"""MoE training fed BY the BlobShuffle engine — the two halves of the
repo as one system.

The paper's shuffle is the *input pipeline* here, not just the expert
dispatch: step-keyed token records flow source -> Batcher -> blob ->
zonal object store -> notification log -> Debatcher, and
``repro.train_input.ShuffleFedInput`` reassembles the deliveries into
sharded device batches, double-buffered ahead of a real jitted
``make_train_step`` on an 8-device (pod=2, data=2, model=2) mesh. The
MoE layer itself can additionally use the hierarchical blob shuffle for
expert dispatch (``--mode blob``) and blob-bucketed int8 cross-pod
gradient sync (``--grad-sync blob_int8``, current-jax only).

Model/optimizer state checkpoints through ``BlobCheckpointer`` over the
same simulated object-store tiers, with the pipeline's committed
per-partition offsets riding in the manifest — so ``--crash-at N``
followed by ``--resume`` restores the last manifest, replays the
engine's virtual clock past the committed prefix, and continues with a
loss trajectory bit-identical to an uninterrupted run (the
``benchmarks/train_input.py`` gates, interactively).

    python examples/moe_blobshuffle_train.py --steps 12
    python examples/moe_blobshuffle_train.py --steps 12 --crash-at 6
    python examples/moe_blobshuffle_train.py --steps 12 --resume

See docs/architecture.md for the full data-flow narrative.
"""

import _bootstrap

_bootstrap.setup(fake_devices=8)

import argparse   # noqa: E402
import pickle     # noqa: E402

from repro.checkpoint import BlobCheckpointer, TieredCheckpointStore  # noqa: E402
from repro.cluster import ElasticCluster                  # noqa: E402
from repro.configs import get_config                      # noqa: E402
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,  # noqa: E402
                        EngineConfig)
from repro.core.stores import ExpressOneZoneStore, SimulatedS3  # noqa: E402
from repro.launch import make_test_mesh                   # noqa: E402
from repro.shuffle import ShuffleConfig                   # noqa: E402
from repro.train_input import (TokenStreamConfig,         # noqa: E402
                               train_shuffle_fed)
from repro.training import OptConfig, TrainConfig         # noqa: E402

# the simulated ckpt store lives in-process; persist it so --resume (a
# fresh process) sees the manifests the crashed run committed. A real
# deployment points TieredCheckpointStore at a durable bucket instead.
_CKPT_FILE = "/tmp/moe_blobshuffle_ckpt.pkl"


def make_engine():
    """Fresh deterministic shuffle engine: zonal store, 3 instances,
    exactly-once, with an AZ-1 outage mid-stream for flavor."""
    eng = AsyncShuffleEngine(
        BlobShuffleConfig(batch_bytes=4096, max_interval_s=0.02,
                          num_partitions=9, num_az=3),
        EngineConfig(commit_interval_s=0.15), n_instances=3,
        store=ExpressOneZoneStore(seed=7, num_az=3), seed=5,
        exactly_once=True)
    ElasticCluster(eng, mode="cooperative").az_outage_at(0.3, 1)
    return eng


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--mode", default="blob",
                    choices=["dense", "direct", "blob"])
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "blob", "blob_int8"])
    ap.add_argument("--crash-at", type=int, default=None,
                    help="die mid-step N (then rerun with --resume)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the last manifest and continue")
    args = ap.parse_args()

    mesh = make_test_mesh(devices=8)
    print(f"mesh: {dict(mesh.shape)}  devices: {mesh.devices.size}")
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    shuf = ShuffleConfig(mode=args.mode,
                         token_axes=("pod", "data", "model"),
                         expert_axes=("pod", "model"),
                         capacity_factor=2.0)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=3e-3, warmup_steps=5,
                                     total_steps=args.steps),
                       shuffle=shuf, grad_sync=args.grad_sync,
                       grad_sync_blob_bytes=1 << 16)
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, batch=8,
                               seq_len=32, seed=0)
    if args.resume:
        with open(_CKPT_FILE, "rb") as f:
            store = pickle.load(f)
    else:
        store = SimulatedS3(seed=404)
    ckpt = BlobCheckpointer(TieredCheckpointStore(store),
                            async_upload=False)

    res = train_shuffle_fed(
        cfg, tcfg, mesh, stream, steps=args.steps,
        engine_factory=make_engine, ckpt=ckpt, ckpt_every=4,
        resume=args.resume, crash_at_step=args.crash_at,
        pipeline_kwargs={"step_interval_s": 0.05, "prefetch_steps": 2})

    st = res.input_stats
    for s, loss in zip(res.steps, res.losses):
        if s % 4 == 0 or s == args.steps - 1:
            print(f"step {s:3d} loss {loss:.4f}")
    print(f"input: {st['records_delivered']} records delivered, "
          f"{st['records_replayed']} replayed across the AZ outage, "
          f"overlap {st['overlap_fraction']:.0%}")
    if res.crashed:
        with open(_CKPT_FILE, "wb") as f:
            pickle.dump(store, f)
        print(f"CRASHED mid-step {args.crash_at} — rerun with --resume")
    elif res.losses:
        assert res.losses[-1] < res.losses[0], "loss did not decrease"
        print(f"OK mode={args.mode} grad_sync={args.grad_sync} "
              f"start_step={res.start_step} "
              f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
