"""MoE training with the BlobShuffle expert dispatch on a multi-pod mesh.

Runs a reduced DeepSeek-V2-style MoE on 8 simulated devices
(2 pods x 2 data x 2 model) with the hierarchical blob shuffle and
blob-bucketed int8 cross-pod gradient sync — the full paper technique,
end to end, with loss decreasing.

    PYTHONPATH=src python examples/moe_blobshuffle_train.py --steps 30
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse   # noqa: E402
import sys        # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax                      # noqa: E402

from repro.configs import get_config                      # noqa: E402
from repro.data import lm_batch_stream                    # noqa: E402
from repro.launch import make_test_mesh                   # noqa: E402
from repro.models import init_params, lm                  # noqa: E402
from repro.shuffle import ShuffleConfig                   # noqa: E402
from repro.training import (OptConfig, TrainConfig, adamw_init,  # noqa: E402
                            make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--mode", default="blob",
                    choices=["dense", "direct", "blob"])
    ap.add_argument("--grad-sync", default="blob_int8",
                    choices=["auto", "blob", "blob_int8"])
    args = ap.parse_args()

    mesh = make_test_mesh(devices=8)
    print(f"mesh: {dict(mesh.shape)}  devices: {mesh.devices.size}")
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    shuf = ShuffleConfig(mode=args.mode,
                         token_axes=("pod", "data", "model"),
                         expert_axes=("pod", "model"),
                         capacity_factor=2.0)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=3e-3, warmup_steps=5,
                                     total_steps=args.steps),
                       shuffle=shuf, grad_sync=args.grad_sync,
                       grad_sync_blob_bytes=1 << 16)
    step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh))
    batch_fn = lm_batch_stream(cfg.vocab_size, 8, 32)

    losses = []
    for i in range(args.steps):
        params, opt, metrics = step(params, opt, batch_fn(i))
        losses.append(float(metrics["loss"]))
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:3d} loss {losses[-1]:.4f} "
                  f"aux {float(metrics['aux_loss']):.5f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
    assert sum(losses[-5:]) < sum(losses[:5]), "loss did not decrease"
    print(f"OK mode={args.mode} grad_sync={args.grad_sync} "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
