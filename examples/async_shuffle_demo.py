"""Async BlobShuffle engine demo: one command that reproduces the paper's
latency/cost tradeoff on the event-driven simulator.

    python examples/async_shuffle_demo.py

Prints p50/p95/p99 shuffle latency and $/GiB for two batch-interval
settings. Longer batching always means fewer requests -> cheaper per
GiB; latency is U-shaped in the interval: at this load the 0.1s setting
is actually SLOWER than 1.0s because a flood of tiny blobs saturates the
bounded upload lanes (queueing dominates the batching wait). Then shows
that overlapping in-flight PUTs/GETs (upload parallelism 4) beats the
synchronous single-in-flight execution of the same engine on a fixed
workload.
"""

import _bootstrap

_bootstrap.setup()

from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,  # noqa: E402
                        EngineConfig, WorkloadConfig, drive)


def run_once(batch_interval_s, upload_par, fetch_par, seed=1):
    cfg = BlobShuffleConfig(batch_bytes=256 * 1024,
                            max_interval_s=batch_interval_s,
                            num_partitions=9, num_az=3)
    eng = AsyncShuffleEngine(
        cfg, EngineConfig(upload_parallelism=upload_par,
                          fetch_parallelism=fetch_par),
        n_instances=6, seed=seed, exactly_once=False)
    drive(eng, WorkloadConfig(arrival_rate=4000, duration_s=3.0,
                              record_bytes=1024, key_skew=0.5, seed=seed))
    metrics = eng.run()
    return metrics, metrics.summary(eng.store)


def main():
    print("latency vs batch interval (4k rec/s open workload, 6 instances)")
    for interval in (0.1, 1.0):
        m, s = run_once(interval, upload_par=4, fetch_par=8)
        assert m.records_delivered == m.records_in, "lost records!"
        print(f"  interval={interval:4.1f}s  p50={s['p50_s']:.3f}s  "
              f"p95={s['p95_s']:.3f}s  p99={s['p99_s']:.3f}s  "
              f"cost=${s['cost_per_gib']:.4f}/GiB")

    print("\noverlap: in-flight I/O vs synchronous single-in-flight")
    _, serial = run_once(0.5, upload_par=1, fetch_par=1)
    _, overlap = run_once(0.5, upload_par=4, fetch_par=8)
    print(f"  serial   makespan={serial['makespan_s']:.3f}s "
          f"p95={serial['p95_s']:.3f}s")
    print(f"  overlap  makespan={overlap['makespan_s']:.3f}s "
          f"p95={overlap['p95_s']:.3f}s "
          f"({serial['makespan_s'] / overlap['makespan_s']:.2f}x faster)")
    assert overlap["makespan_s"] < serial["makespan_s"], \
        "async engine failed to overlap I/O"
    print("OK")


if __name__ == "__main__":
    main()
