"""Paper walkthrough: the Listing-1 pipeline + the §5 evaluation in
miniature — shuffle real records through Batcher→S3→Debatcher, then
reproduce the headline numbers with the calibrated simulator.

    python examples/stream_shuffle_sim.py
"""

import _bootstrap

_bootstrap.setup()

from repro.core import (BlobShuffleConfig, BlobShufflePipeline,  # noqa: E402
                        SimConfig, simulate)
from repro.data import shufflebench_records  # noqa: E402


def main():
    # --- functional pipeline (Listing 1 analogue) -----------------------
    cfg = BlobShuffleConfig(batch_bytes=64 * 1024, num_partitions=9,
                            num_az=3)
    pipe = BlobShufflePipeline(cfg, n_instances=6)
    records = shufflebench_records(2000, value_bytes=512)
    out = pipe.run(records, commit_every=500)
    n_out = sum(len(v) for v in out.values())
    store = pipe.store.stats
    print(f"shuffled {n_out}/{len(records)} records across "
          f"{len(out)} partitions")
    print(f"store: {store.puts} PUTs, {store.gets} GETs "
          f"(GET:PUT = {store.gets / store.puts:.2f}, model: 0.67)")

    # --- calibrated §5 simulation ---------------------------------------
    r = simulate(SimConfig())
    print(f"\n24 instances, 16 MiB batches (paper Fig. 5/7):")
    print(f"  throughput        {r.throughput_bytes_s / 2**30:.2f} GiB/s")
    print(f"  shuffle latency   p50={r.latency_p(50):.2f}s "
          f"p95={r.latency_p(95):.2f}s p99={r.latency_p(99):.2f}s")
    print(f"  cost @1GiB/s      S3 ${r.s3_cost_per_hour_at_1gib:.2f}/h + "
          f"EC2 ${r.infra_cost_per_hour_at_1gib:.2f}/h "
          f"= ${r.total_cost_at_1gib:.2f}/h")
    print(f"  native Kafka      ${r.kafka_cost_per_hour_at_1gib:.0f}/h "
          f"-> saving {r.kafka_cost_per_hour_at_1gib / r.total_cost_at_1gib:.0f}x"
          f" (paper: >40x)")
    assert r.kafka_cost_per_hour_at_1gib / r.total_cost_at_1gib > 40
    print("OK")


if __name__ == "__main__":
    main()
