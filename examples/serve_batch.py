"""Batched serving: prefill a batch of prompts, then decode with KV cache.

    python examples/serve_batch.py --arch gemma-2b --tokens 32
"""

import _bootstrap

_bootstrap.setup()

import argparse   # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config
from repro.models import init_params, lm
from repro.serving import ServeConfig, make_decode_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    assert cfg.has_decode, f"{args.arch} is encoder-only"
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    B = args.batch
    max_seq = args.prompt_len + args.tokens
    cache = jax.tree.map(
        jnp.zeros_like,
        init_params(lm.cache_defs(cfg, B, max_seq), jax.random.key(1)))
    serve_step = jax.jit(make_decode_step(cfg, ServeConfig()))

    prompts = jax.random.randint(jax.random.key(2), (B, args.prompt_len),
                                 0, cfg.vocab_size)
    # prefill via the decode path (teacher-forced) to fill the cache
    for t in range(args.prompt_len):
        cache, nxt, _ = serve_step(params, cache,
                                   {"tokens": prompts[:, t:t + 1],
                                    "pos": jnp.int32(t)})
    # autoregressive decode
    out = [nxt]
    t0 = time.time()
    for t in range(args.prompt_len, max_seq - 1):
        cache, nxt, _ = serve_step(params, cache,
                                   {"tokens": out[-1][:, None],
                                    "pos": jnp.int32(t)})
        out.append(nxt)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    tps = (gen.shape[1] - 1) * B / dt
    print(f"arch={cfg.name} batch={B} generated {gen.shape[1]} tokens/seq "
          f"({tps:.1f} tok/s on CPU)")
    print("sample token ids:", gen[0, :16].tolist())
    assert bool(jnp.all(gen >= 0)) and bool(jnp.all(gen < cfg.vocab_size))
    print("OK")


if __name__ == "__main__":
    main()
