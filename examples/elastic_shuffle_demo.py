"""Elastic BlobShuffle demo: scale-out under a spike, crash recovery,
and AZ outage — with exactly-once delivery verified record by record.

Runs three scripted scenarios on the virtual clock:

  1. join + crash (cooperative): a worker joins mid-stream, an original
     worker crashes — output is compared bit-for-bit against a static
     cluster run of the identical workload;
  2. the same join in eager (stop-the-world) mode, showing the pause;
  3. a 3x load spike through the lag/queue-driven autoscaler, with the
     infra $ actually paid vs a statically peak-provisioned cluster.

Usage:  python examples/elastic_shuffle_demo.py
"""

import _bootstrap

_bootstrap.setup()

import numpy as np  # noqa: E402

from repro.cluster import ElasticCluster  # noqa: E402
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,  # noqa: E402
                        EngineConfig, Record, SimConfig, simulate_elastic)

CFG = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                        num_partitions=18, num_az=3)


def records(n=4000, seed=11):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(300), timestamp_us=i)
            for i in range(n)]


def engine():
    return AsyncShuffleEngine(CFG, EngineConfig(commit_interval_s=0.1),
                              n_instances=4, seed=7, exactly_once=True)


def multiset(eng):
    return {p: sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                      for r in rs)
            for p, rs in eng.out.items() if rs}


def run(mode=None):
    eng = engine()
    cluster = None
    if mode is not None:
        cluster = ElasticCluster(eng, mode=mode, heartbeat_timeout_s=0.15)
        eng.loop.at(0.4, cluster.add_worker)
        cluster.crash_worker_at(1.0, "w1")
    for i, rec in enumerate(records()):
        eng.submit(i / 2500.0, rec)
    return eng, cluster, eng.run()


def main():
    print("=== 1. cooperative join + crash vs static baseline ===")
    static_eng, _, sm = run(None)
    eng, cl, m = run("cooperative")
    print(f"  static : {sm.records_delivered} records, "
          f"makespan={sm.makespan_s:.2f}s p95={sm.latency_p(95):.3f}s")
    print(f"  elastic: {m.records_delivered} records, "
          f"makespan={m.makespan_s:.2f}s p95={m.latency_p(95):.3f}s, "
          f"{m.records_replayed} replayed after the crash")
    for e in cl.rebalancer.events:
        if e.superseded:
            continue
        print(f"  rebalance[{e.reason}/{e.mode}] t={e.started_at:.2f}s"
              f"->{e.ended_at:.2f}s moved={len(e.moved)} "
              f"replayed={e.replayed} log entries")
    ok = multiset(eng) == multiset(static_eng)
    print(f"  exactly-once, bit-identical payload multiset: {ok}")
    print(f"  cache entries re-routed (never flushed): "
          f"{cl.stats.cache_reroutes}")
    assert ok and m.duplicates_delivered == 0

    print("\n=== 2. the same join, eager (stop-the-world) ===")
    eng2, cl2, m2 = run("eager")
    print(f"  delivered={m2.records_delivered} "
          f"makespan={m2.makespan_s:.2f}s")
    print(f"  entries that found no owner during the barrier: "
          f"{cl2.stats.undeliverable} (replayed on resume: "
          f"{cl2.stats.replayed_entries})")
    assert multiset(eng2) == multiset(static_eng)

    print("\n=== 3. load spike through the autoscaler ===")
    cfg = SimConfig(n_nodes=2, inst_per_node=2, partitions_factor=3,
                    duration_s=3.0, max_interval_s=0.25,
                    commit_interval_s=0.25, seed=3)
    eng3, cl3, s = simulate_elastic(cfg, scale=0.001, spike_factor=3.0)
    for d in cl3.autoscaler.decisions:
        print(f"  t={d.t:5.2f}s {d.action:<9} -> {d.workers_after} workers"
              f"  ({d.reason})")
    peak = max([d.workers_after for d in cl3.autoscaler.decisions],
               default=4)
    hourly = cl3.autoscaler.policy.worker_cost_per_hour
    static_cost = peak * eng3.loop.now / 3600.0 * hourly
    print(f"  lag drained to {s['lag_final']:.0f}; "
          f"infra $ {s['infra_cost_usd']:.4f} elastic vs "
          f"{static_cost:.4f} static-at-peak "
          f"({100 * (1 - s['infra_cost_usd'] / static_cost):.0f}% saved)")
    assert eng3.metrics.duplicates_delivered == 0
    print("\nOK")


if __name__ == "__main__":
    main()
