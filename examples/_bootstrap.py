"""Shared bootstrap for the runnable examples.

Every example needs the same two lines of environment setup, and both
are order-sensitive, so they live here instead of being copy-pasted:

* put ``<repo>/src`` on ``sys.path`` so ``import repro`` works when the
  example is run straight from a checkout (``python examples/foo.py``)
  without an editable install or ``PYTHONPATH``;
* optionally pin ``XLA_FLAGS`` to fake N host devices — this MUST
  happen before the first ``import jax`` anywhere in the process
  (device count locks on first backend init), which is why examples
  call ``setup()`` at the very top, before their jax-importing imports.

Usage (first lines of an example)::

    import _bootstrap
    _bootstrap.setup()                  # path only
    _bootstrap.setup(fake_devices=8)    # path + 8 simulated devices
"""

import os
import sys


def setup(fake_devices: int = 0) -> None:
    if fake_devices:
        assert "jax" not in sys.modules, \
            "setup(fake_devices=...) must run before the first jax import"
        os.environ.setdefault(
            "XLA_FLAGS",
            f"--xla_force_host_platform_device_count={fake_devices}")
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       os.pardir, "src")
    src = os.path.normpath(src)
    if src not in sys.path:
        sys.path.insert(0, src)
