"""Quickstart: end-to-end training driver on CPU (reduced config).

Trains a ~small decoder LM for a few hundred steps with the full substrate:
data pipeline -> train_step (AdamW, remat, bf16 compute) -> blob-store
checkpoints w/ fault-tolerant restart. Verifies the loss decreases.

    python examples/quickstart.py --steps 300
"""

import _bootstrap

_bootstrap.setup()

import argparse   # noqa: E402
import tempfile   # noqa: E402
import time       # noqa: E402

import jax        # noqa: E402

from repro.checkpoint import FileStore
from repro.configs import get_config
from repro.data import lm_batch_stream
from repro.models import init_params, lm
from repro.runtime import FaultTolerantTrainer
from repro.training import OptConfig, TrainConfig, adamw_init, \
    make_train_step
from repro.utils import tree_num_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a node failure at this step")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    print(f"arch={cfg.name} params={tree_num_params(params):,}")
    opt = adamw_init(params)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=args.lr,
                                     warmup_steps=20,
                                     total_steps=args.steps))
    step = jax.jit(make_train_step(cfg, tcfg))
    batch_fn = lm_batch_stream(cfg.vocab_size, args.batch, args.seq,
                               multimodal=cfg.multimodal,
                               d_model=cfg.d_model)

    with tempfile.TemporaryDirectory() as tmp:
        trainer = FaultTolerantTrainer(FileStore(tmp), step, batch_fn,
                                       ckpt_every=50)
        fail = {args.fail_at: 1} if args.fail_at else None
        t0 = time.time()
        params, opt, losses = trainer.run(params, opt, steps=args.steps,
                                          fail_at=fail)
        dt = time.time() - t0
    first = sum(losses[:10]) / 10
    last = sum(losses[-10:]) / 10
    print(f"steps={args.steps} time={dt:.1f}s "
          f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "loss did not decrease"
    print("OK")


if __name__ == "__main__":
    main()
