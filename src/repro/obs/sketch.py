"""Bounded-memory streaming quantile sketch (DDSketch-style).

Log-spaced buckets with relative accuracy ``alpha``: bucket ``i`` covers
``(gamma**(i-1), gamma**i]`` for ``gamma = (1+alpha)/(1-alpha)``, and every
value in a bucket is estimated by ``2*gamma**i/(gamma+1)`` — within
``alpha`` relative error of the true value. Quantiles interpolate linearly
between the estimates of the two adjacent order statistics (the same
convention as ``np.percentile(..., method="linear")``), so for any
non-negative data the reported quantile is within ``alpha`` relative error
of the exact linear-interpolated percentile: both endpoints of the convex
combination carry at most ``alpha`` relative error and all terms are
non-negative.

Memory is bounded by ``max_bins``: when exceeded, the lowest buckets are
collapsed together (sacrificing low-quantile accuracy first, like
DDSketch). With the default ``alpha=0.01`` a single bucket spans ~2% of a
decade, so 4096 bins cover ~35 orders of magnitude — collapse never
triggers for simulated latencies; it is purely a safety bound.

The sketch is deterministic, mergeable, and never touches an RNG, so it
is safe to maintain inside the bit-reproducible event engine.
"""

from __future__ import annotations

import math
from typing import Dict, Iterable, Optional, Sequence

import numpy as np


class QuantileSketch:
    """Streaming quantile estimator with guaranteed relative error.

    ``add``/``add_weighted`` are O(1); ``add_many`` is vectorized over a
    numpy array; ``percentile`` is O(bins log bins). Values must be
    non-negative (latencies, sizes); values at or below ``min_value``
    land in a dedicated zero bucket estimated as 0.0.
    """

    __slots__ = ("alpha", "gamma", "_log_gamma", "min_value", "max_bins",
                 "_bins", "zero_count", "count", "_sum", "_min", "_max")

    def __init__(self, alpha: float = 0.01, min_value: float = 1e-9,
                 max_bins: int = 4096):
        if not 0.0 < alpha < 1.0:
            raise ValueError(f"alpha must be in (0, 1), got {alpha}")
        self.alpha = alpha
        self.gamma = (1.0 + alpha) / (1.0 - alpha)
        self._log_gamma = math.log(self.gamma)
        self.min_value = min_value
        self.max_bins = max_bins
        self._bins: Dict[int, int] = {}
        self.zero_count = 0
        self.count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # -- ingestion ----------------------------------------------------------
    def add(self, x: float) -> None:
        self.add_weighted(x, 1)

    def add_weighted(self, x: float, n: int) -> None:
        if x < 0.0:
            raise ValueError(f"sketch values must be >= 0, got {x}")
        if x <= self.min_value:
            self.zero_count += n
        else:
            key = math.ceil(math.log(x) / self._log_gamma)
            self._bins[key] = self._bins.get(key, 0) + n
            if len(self._bins) > self.max_bins:
                self._collapse()
        self.count += n
        self._sum += x * n
        if x < self._min:
            self._min = x
        if x > self._max:
            self._max = x

    def add_many(self, xs) -> None:
        xs = np.asarray(xs, dtype=np.float64)
        if xs.size == 0:
            return
        if xs.size < 32:
            # scalar path: for the tiny per-delivery arrays on the hot
            # path, np.unique costs ~10x the handful of dict updates
            for x in xs.tolist():
                self.add_weighted(x, 1)
            return
        if float(xs.min()) < 0.0:
            raise ValueError("sketch values must be >= 0")
        small = xs <= self.min_value
        n_small = int(np.count_nonzero(small))
        self.zero_count += n_small
        if n_small < xs.size:
            nz = xs[~small] if n_small else xs
            keys = np.ceil(np.log(nz) / self._log_gamma).astype(np.int64)
            uniq, cnts = np.unique(keys, return_counts=True)
            bins = self._bins
            for k, c in zip(uniq.tolist(), cnts.tolist()):
                bins[k] = bins.get(k, 0) + c
            if len(bins) > self.max_bins:
                self._collapse()
        self.count += int(xs.size)
        self._sum += float(xs.sum())
        self._min = min(self._min, float(xs.min()))
        self._max = max(self._max, float(xs.max()))

    def merge(self, other: "QuantileSketch") -> None:
        if abs(other.gamma - self.gamma) > 1e-12:
            raise ValueError("cannot merge sketches with different gamma")
        for k, c in other._bins.items():
            self._bins[k] = self._bins.get(k, 0) + c
        self.zero_count += other.zero_count
        self.count += other.count
        self._sum += other._sum
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        if len(self._bins) > self.max_bins:
            self._collapse()

    def _collapse(self) -> None:
        # collapse the lowest buckets together (low quantiles lose
        # accuracy first; the high tail — what hedging and p95 gates
        # read — is preserved exactly as sketched).
        keys = sorted(self._bins)
        spill = 0
        while len(keys) > self.max_bins:
            spill += self._bins.pop(keys.pop(0))
        if spill:
            self._bins[keys[0]] += spill

    # -- queries ------------------------------------------------------------
    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self.count if self.count else 0.0

    @property
    def min(self) -> float:
        return self._min if self.count else 0.0

    @property
    def max(self) -> float:
        return self._max if self.count else 0.0

    def _bucket_value(self, key: int) -> float:
        return 2.0 * self.gamma ** key / (self.gamma + 1.0)

    def percentile(self, q: float) -> Optional[float]:
        """Estimate the q-th percentile (q in [0, 100], linear
        interpolation — same convention as ``np.percentile``). None when
        the sketch is empty."""
        out = self.percentiles([q])
        return out[0] if out else None

    def percentiles(self, qs: Sequence[float]) -> list:
        if self.count == 0:
            return [None] * len(qs)
        n = self.count
        keys = sorted(self._bins)
        cum = self.zero_count
        cums = []
        for k in keys:
            cum += self._bins[k]
            cums.append(cum)

        def order_stat(r: int) -> float:
            # value of the r-th (0-based) order statistic, within alpha
            if r < self.zero_count:
                return 0.0
            idx = int(np.searchsorted(cums, r, side="right"))
            return self._bucket_value(keys[idx])

        out = []
        for q in qs:
            if not 0.0 <= q <= 100.0:
                raise ValueError(f"percentile must be in [0, 100], got {q}")
            h = q / 100.0 * (n - 1)
            k = math.floor(h)
            frac = h - k
            lo = order_stat(k)
            est = lo if frac == 0.0 else (1.0 - frac) * lo \
                + frac * order_stat(min(k + 1, n - 1))
            # the tracked extrema are exact; clamping only moves the
            # estimate toward the true value
            out.append(min(max(est, self._min), self._max))
        return out

    def quantile(self, f: float) -> Optional[float]:
        """``percentile`` with f in [0, 1]."""
        return self.percentile(f * 100.0)

    def to_dict(self) -> dict:
        return {"count": self.count, "sum": self._sum,
                "min": self.min, "max": self.max,
                "alpha": self.alpha, "bins": len(self._bins),
                "zero_count": self.zero_count}

    def __len__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return (f"QuantileSketch(count={self.count}, bins={len(self._bins)},"
                f" alpha={self.alpha})")
