"""Unified observability layer for the BlobShuffle engine.

One opt-in object (``AsyncShuffleEngine(..., obs=True)`` or
``obs=ObsConfig(...)``) provides four views of a run:

  * a :class:`~repro.obs.registry.MetricsRegistry` of counters / gauges /
    histograms keyed by component and AZ, windowed on the virtual clock
    ("p95 during the rebalance" is a query, not bespoke code);
  * per-record **latency decomposition**: end-to-end latency is split
    exactly into batch_wait + upload + commit_wait + notify + fetch at
    the delivery point (the stage sums reconcile with the end-to-end
    samples by construction — each stage is a difference of adjacent
    lifecycle timestamps);
  * per-blob **lifecycle traces** (deterministically sampled) emitted as
    a Chrome-trace JSON artifact (``chrome://tracing`` / Perfetto);
  * a **conservation-law checker** reconciling every *Stats* dataclass
    at end of run (see ``repro.obs.conservation``).

Disabled (the default, ``obs=None``) the engine takes a single
``is not None`` branch per hook — no allocation, no RNG use, no event
scheduled — so disabled runs stay bit-identical. Enabled, the layer
still never schedules events or consumes engine RNG, so enabling
observability does not change delivery order, latencies, or any digest:
it is a pure side-table of the run.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.obs.conservation import (ConservationError, ConservationReport,
                                    LawResult, check_conservation)
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.sketch import QuantileSketch
from repro.obs.trace import BlobTracer

#: the exact latency decomposition recorded at every delivery; stage
#: boundaries are adjacent lifecycle timestamps, so per-record sums equal
#: the end-to-end latency to float precision
STAGES = ("batch_wait", "upload", "commit_wait", "notify", "fetch")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Knobs of the observability layer (all virtual-clock units)."""
    window_s: float = 0.25             # time-series window width
    sketch_alpha: float = 0.01         # histogram relative-error bound
    trace_sample_every: int = 8        # 1-in-N blobs traced (crc32 of id)
    trace_max_events: int = 20000      # trace artifact cap
    check_conservation: bool = True    # reconcile stats at end of run()
    strict_conservation: bool = False  # raise ConservationError on violation


class Observability:
    """Side-table of one engine run: registry + tracer + blob timelines.

    Every hook is called from the engine with plain values already in
    hand — hooks never schedule events, never call into the store or
    caches, and never consume randomness, so an observed run replays
    the exact event sequence of an unobserved one.
    """

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg or ObsConfig()
        self.registry = MetricsRegistry(window_s=self.cfg.window_s,
                                        alpha=self.cfg.sketch_alpha)
        self.tracer = BlobTracer(self.cfg.trace_sample_every,
                                 self.cfg.trace_max_events)
        self.report: Optional[ConservationReport] = None
        # blob lifecycle timelines (virtual timestamps)
        self._first_t0: Dict[str, float] = {}      # earliest buffered record
        self._finalized: Dict[str, float] = {}     # blob built
        self._durable: Dict[str, float] = {}       # PUT completed
        self._published: Dict[Tuple[str, int], float] = {}  # note published
        r = self.registry
        self._h_e2e = r.histogram("e2e", "latency")
        self._h_stage = {s: r.histogram(s, "stage") for s in STAGES}
        self._unattributed = r.counter("unattributed_records", "stage")
        # memoized handles for the per-delivery hooks (the registry
        # lookup builds a tuple key per call; these paths run once or
        # more per delivered record range)
        self._c_in: Dict[int, Counter] = {}
        self._c_delivered: Dict[int, Counter] = {}
        self._c_reads: Dict[Tuple[str, int], Tuple[Counter, ...]] = {}
        self._m_finalized: Dict[Tuple[str, int], tuple] = {}
        self._m_durable: Dict[int, tuple] = {}
        self._m_get: Dict[int, tuple] = {}
        # raw rows pending bulk application — the two per-delivery hooks
        # are O(1) appends; _drain_deliveries() expands them into the
        # stage/e2e sketches and windowed counters in bulk
        self._pending_deliveries: list = []
        self._pending_reads: list = []

    # -- ingest / producer side -------------------------------------------
    def on_ingest(self, az: int, n: int, now: float) -> None:
        c = self._c_in.get(az)
        if c is None:
            c = self._c_in[az] = self.registry.counter(
                "records_in", "engine", az)
        c.inc(n, now)

    def on_batch_finalized(self, az: int, blob, why: str,
                           now: float) -> None:
        """Batcher hook: a buffer became a blob (why: size/interval/
        commit)."""
        m = self._m_finalized.get((why, az))
        if m is None:
            r = self.registry
            m = self._m_finalized[(why, az)] = (
                r.counter(f"finalize_{why}", "batcher", az),
                r.histogram("blob_bytes", "batcher", az))
        m[0].inc(1, now)
        m[1].observe(blob.size, now)

    def on_blob_handed_off(self, blob, az: int, first_t0: Optional[float],
                           now: float) -> None:
        """Engine uploader hook: blob entered the upload lane with its
        arrival FIFOs captured."""
        self._finalized[blob.blob_id] = now
        if first_t0 is not None:
            self._first_t0[blob.blob_id] = first_t0

    def on_blob_durable(self, blob_id: str, size: int, az: int, lat: float,
                        now: float) -> None:
        m = self._m_durable.get(az)
        if m is None:
            r = self.registry
            m = self._m_durable[az] = (
                r.counter("uploads", "engine", az),
                r.histogram("put_latency", "store", az))
        m[0].inc(1, now)
        m[1].observe(lat, now)
        self._durable[blob_id] = now
        if self.tracer.sampled(blob_id):
            t_fin = self._finalized.get(blob_id, now - lat)
            t0 = self._first_t0.get(blob_id, t_fin)
            self.tracer.span("pack", blob_id, t0, t_fin,
                             args={"bytes": size})
            self.tracer.span("upload", blob_id, t_fin, now,
                             args={"put_s": lat})

    def on_note_published(self, note, now: float) -> None:
        self._published[(note.blob_id, note.partition)] = now

    # -- consumer side -----------------------------------------------------
    def on_store_get(self, az: int, size: int, lat: float,
                     now: float) -> None:
        m = self._m_get.get(az)
        if m is None:
            r = self.registry
            m = self._m_get[az] = (
                r.counter("store_gets", "cache", az),
                r.histogram("get_latency", "store", az))
        m[0].inc(1, now)
        m[1].observe(lat, now)

    def on_extract(self, az: int, src: str, n_records: int, nbytes: int,
                   now: float) -> None:
        """Debatcher hook: one admitted notification extracted (extract
        itself is instantaneous on the virtual clock — it is the tail of
        the ``fetch`` stage). O(1): the three windowed counters are
        applied in bulk by :meth:`_drain_deliveries`."""
        self._pending_reads.append((src, az, n_records, nbytes, now))

    def on_duplicate_delivery(self, az: int, n: int, now: float) -> None:
        self.registry.counter("duplicates", "engine", az).inc(n, now)

    def on_delivery(self, note, enqueued_at: float, arrivals, src: str,
                    az: int, now: float) -> None:
        """The delivery point: one O(1) append of the raw row — the
        ``len(arrivals)``-record stage decomposition happens vectorized
        in :meth:`_drain_deliveries` (the arrivals list is the engine's
        popped FIFO; it is never mutated after delivery)."""
        n = len(arrivals)
        if n == 0:
            return
        bid = note.blob_id
        self._pending_deliveries.append(
            (bid, note.partition, enqueued_at, now, arrivals, az))
        if len(self._pending_deliveries) >= 4096:
            self._drain_deliveries()
        if self.tracer.sampled(bid):
            t_pub = self._published.get((bid, note.partition), enqueued_at)
            self.tracer.span("notify", bid, t_pub, enqueued_at,
                             pid=note.partition)
            self.tracer.span("fetch", bid, enqueued_at, now,
                             pid=note.partition,
                             args={"src": src, "records": n})
            self.tracer.instant("deliver", now, blob_id=bid,
                                pid=note.partition,
                                args={"records": n, "az": az})

    def _drain_deliveries(self) -> None:
        """Expand pending delivery/extract rows into the e2e + stage
        sketches and windowed counters, one vectorized pass per
        virtual-clock window. Lifecycle timestamps only ever precede the
        delivery that reads them, so resolving them here is equivalent
        to resolving at delivery."""
        ws = self.cfg.window_s
        reads = self._pending_reads
        if reads:
            self._pending_reads = []
            agg: Dict[Tuple[str, int, int], list] = {}
            for src, az, n, nb, now in reads:
                key = (src, az, int(now // ws))
                a = agg.get(key)
                if a is None:
                    agg[key] = [1, n, nb]
                else:
                    a[0] += 1
                    a[1] += n
                    a[2] += nb
            for (src, az, idx), (n_reads, n_recs, n_bytes) in agg.items():
                cs = self._c_reads.get((src, az))
                if cs is None:
                    r = self.registry
                    cs = self._c_reads[(src, az)] = (
                        r.counter(f"reads_{src}", "debatcher", az),
                        r.counter("records_out", "debatcher", az),
                        r.counter("bytes_out", "debatcher", az))
                cs[0]._inc_window(idx, n_reads)
                cs[1]._inc_window(idx, n_recs)
                cs[2]._inc_window(idx, n_bytes)
        pend = self._pending_deliveries
        if not pend:
            return
        self._pending_deliveries = []
        fin, dur, pub = self._finalized, self._durable, self._published
        dlv: Dict[Tuple[int, int], int] = {}   # (az, window) -> records
        nows_l, enqs_l, fins_l, durs_l, pubs_l, ns_l = [], [], [], [], [], []
        t0s_l: list = []
        for bid, part, enq, now, arr, az in pend:
            key = (az, int(now // ws))
            dlv[key] = dlv.get(key, 0) + len(arr)
            t_fin = fin.get(bid)
            t_dur = dur.get(bid)
            t_pub = pub.get((bid, part))
            if t_fin is None or t_dur is None or t_pub is None:
                # incomplete timeline (hook attached mid-run): count the
                # records and keep their e2e, don't guess stages
                self._unattributed.inc(len(arr), now)
                self._h_e2e.observe_many([now - t for t in arr], now)
                continue
            nows_l.append(now)
            enqs_l.append(enq)
            fins_l.append(t_fin)
            durs_l.append(t_dur)
            pubs_l.append(t_pub)
            ns_l.append(len(arr))
            t0s_l.extend(arr)
        for (az, idx), n in dlv.items():
            c = self._c_delivered.get(az)
            if c is None:
                c = self._c_delivered[az] = self.registry.counter(
                    "records_delivered", "engine", az)
            c._inc_window(idx, n)
        if not ns_l:
            return
        nows = np.array(nows_l)
        enqs = np.array(enqs_l)
        fins = np.array(fins_l)
        durs = np.array(durs_l)
        pubs = np.array(pubs_l)
        ns = np.array(ns_l, np.int64)
        t0s = np.array(t0s_l)
        # one expansion pass for the whole batch, then sliced per window:
        # deliveries arrive in virtual-time order, so the window index is
        # nondecreasing and windows are contiguous runs
        per_stage = (
            (self._h_e2e, np.repeat(nows, ns) - t0s),
            (self._h_stage["batch_wait"], np.repeat(fins, ns) - t0s),
            (self._h_stage["upload"], np.repeat(durs - fins, ns)),
            (self._h_stage["commit_wait"], np.repeat(pubs - durs, ns)),
            (self._h_stage["notify"], np.repeat(enqs - pubs, ns)),
            (self._h_stage["fetch"], np.repeat(nows - enqs, ns)),
        )
        idxs = (nows // ws).astype(np.int64)
        bounds = np.flatnonzero(np.diff(idxs)) + 1
        starts = np.concatenate(([0], bounds))
        ends = np.concatenate((bounds, [idxs.size]))
        rec_off = np.concatenate(([0], np.cumsum(ns)))
        for s, e in zip(starts.tolist(), ends.tolist()):
            idx = int(idxs[s])
            r0, r1 = int(rec_off[s]), int(rec_off[e])
            for h, vals in per_stage:
                h._window_sketch(idx).add_many(vals[r0:r1])

    # -- control-plane marks ----------------------------------------------
    def mark(self, label: str, now: float) -> None:
        """Named instant (crash, rebalance trigger/complete, AZ outage)
        — the anchors for windowed metric queries."""
        self.registry.mark(label, now)
        self.tracer.instant(label, now)

    # -- end of run --------------------------------------------------------
    def finalize_run(self, engine) -> None:
        """Engine ``run()`` hook: snapshot end-of-run gauges and run the
        conservation checker."""
        now = engine.loop.now
        self._drain_deliveries()
        r = self.registry
        st = engine.store.stats
        r.gauge("puts", "store").set(st.puts, now)
        r.gauge("gets", "store").set(st.gets, now)
        r.gauge("put_bytes", "store").set(st.put_bytes, now)
        r.gauge("byte_seconds", "store").set(st.byte_seconds, now)
        for az, c in enumerate(engine.caches):
            r.gauge("hits", "cache", az).set(c.stats.hits, now)
            r.gauge("misses", "cache", az).set(c.stats.misses, now)
            r.gauge("coalesced", "cache", az).set(c.stats.coalesced, now)
        if self.cfg.check_conservation:
            self.report = check_conservation(
                engine, strict=self.cfg.strict_conservation)

    # -- queries -----------------------------------------------------------
    def stage_decomposition(self, qs=(50, 95)) -> dict:
        """Per-stage quantiles + means; ``sum_check`` carries the mean
        sums so callers can assert stage ⟂ e2e reconciliation."""
        self._drain_deliveries()
        out = {}
        for s in STAGES:
            h = self._h_stage[s]
            if h.count:
                vals = h.percentiles(list(qs))
                out[s] = {f"p{int(q)}_s": v for q, v in zip(qs, vals)}
                out[s]["mean_s"] = h.mean
            else:
                out[s] = {f"p{int(q)}_s": 0.0 for q in qs}
                out[s]["mean_s"] = 0.0
        e2e = self._h_e2e
        out["e2e"] = ({f"p{int(q)}_s": v for q, v in
                       zip(qs, e2e.percentiles(list(qs)))}
                      if e2e.count else {f"p{int(q)}_s": 0.0 for q in qs})
        out["e2e"]["mean_s"] = e2e.mean if e2e.count else 0.0
        out["sum_check"] = {
            "stage_mean_sum_s": sum(out[s]["mean_s"] for s in STAGES),
            "e2e_mean_s": out["e2e"]["mean_s"],
            "stage_records": self._h_stage["upload"].count,
            "e2e_records": e2e.count,
            "unattributed_records": self._unattributed.total,
        }
        return out

    def e2e_percentile(self, q: float, t0: Optional[float] = None,
                       t1: Optional[float] = None) -> Optional[float]:
        """Windowed end-to-end percentile — e.g. "p95 during the
        rebalance": pass the [t0, t1) window from two marks."""
        self._drain_deliveries()
        return self._h_e2e.percentile(q, t0, t1)


def make_observability(obs) -> Optional[Observability]:
    """Resolve the engine's ``obs=`` argument: None | True | ObsConfig |
    Observability."""
    if obs is None or obs is False:
        return None
    if isinstance(obs, Observability):
        return obs
    if isinstance(obs, ObsConfig):
        return Observability(obs)
    if obs is True:
        return Observability()
    raise TypeError(f"obs must be None, True, ObsConfig or Observability; "
                    f"got {type(obs).__name__}")


__all__ = [
    "STAGES", "ObsConfig", "Observability", "make_observability",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "QuantileSketch",
    "BlobTracer", "ConservationReport", "ConservationError", "LawResult",
    "check_conservation",
]
