"""Per-blob lifecycle traces in Chrome trace-event format.

A sampled blob becomes one "thread" in the trace (pid = partition of the
first note, tid = a small per-blob lane id, named after the blob id via a
thread_name metadata event), carrying complete spans (``ph: "X"``):

    pack    first buffered record -> blob finalized
    upload  finalized -> durable in the object store
    notify  note published -> fetch enqueued at the consumer
    fetch   fetch enqueued -> records delivered (includes cache race,
            store GET or cache hit, and the extract, which is
            instantaneous on the virtual clock)

plus instant events (``ph: "i"``) for deliveries and engine-level marks
(crashes, rebalance trigger/complete). Timestamps are virtual-clock
seconds scaled to microseconds, so a 2 s simulation reads as 2 s in the
viewer. Load the artifact in ``chrome://tracing`` or
https://ui.perfetto.dev.

Sampling is deterministic (crc32 of the blob id, 1-in-``sample_every``),
never consuming engine RNG; the event list is capped at ``max_events``.
"""

from __future__ import annotations

import json
import zlib
from typing import Dict, List, Optional


class BlobTracer:
    def __init__(self, sample_every: int = 8, max_events: int = 20000):
        self.sample_every = max(1, sample_every)
        self.max_events = max_events
        self.events: List[dict] = []
        self.dropped = 0
        self._lanes: Dict[str, int] = {}   # blob_id -> tid
        self._sampled: Dict[str, bool] = {}

    def sampled(self, blob_id: str) -> bool:
        s = self._sampled.get(blob_id)
        if s is None:
            s = self._sampled[blob_id] = (
                zlib.crc32(blob_id.encode()) % self.sample_every == 0)
        return s

    def _lane(self, blob_id: str, pid: int) -> int:
        tid = self._lanes.get(blob_id)
        if tid is None:
            tid = self._lanes[blob_id] = len(self._lanes) + 1
            self._emit({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": blob_id}})
        return tid

    def _emit(self, ev: dict) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def span(self, name: str, blob_id: str, t0: float, t1: float,
             pid: int = 0, args: Optional[dict] = None) -> None:
        """Complete span [t0, t1] (virtual seconds) on the blob's lane."""
        ev = {"ph": "X", "name": name, "pid": pid,
              "tid": self._lane(blob_id, pid),
              "ts": t0 * 1e6, "dur": max(0.0, t1 - t0) * 1e6}
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, t: float, blob_id: Optional[str] = None,
                pid: int = 0, args: Optional[dict] = None) -> None:
        ev = {"ph": "i", "name": name, "pid": pid, "ts": t * 1e6,
              "s": "g" if blob_id is None else "t"}
        if blob_id is not None:
            ev["tid"] = self._lane(blob_id, pid)
        if args:
            ev["args"] = args
        self._emit(ev)

    def to_chrome(self) -> dict:
        return {"traceEvents": self.events,
                "displayTimeUnit": "ms",
                "otherData": {"sample_every": self.sample_every,
                              "dropped_events": self.dropped,
                              "clock": "virtual (1 us trace = 1 us sim)"}}

    def dump(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
            f.write("\n")
