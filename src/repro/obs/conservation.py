"""Conservation-law checker: reconcile every *Stats* dataclass at end of
run.

The engine carries eight disconnected stats structures (batcher,
debatcher, commit, store, cache, fault, strategy, cluster). Each law
below states an exact flow identity between them, derived from the code
paths that bump the counters — records cannot appear or vanish between
operators, every store GET is led by exactly one cache cluster, every
byte PUT is a finalized blob byte that neither aborted nor died with a
crashed lane, and so on. A violated law means double counting, silent
loss, or a stats regression — the classes of bug that latency averages
hide.

Laws carry an applicability guard: some identities only hold for fully
drained runs without aborts or injected failures (a crash double-counts
replayed records in ``records_in`` by design), so those laws report
``skipped`` instead of failing when their preconditions don't hold.
``check_conservation(engine)`` works on any finished
``AsyncShuffleEngine`` — with or without an attached cluster, for every
shuffle strategy — and is run automatically from ``engine.run()`` when
observability is enabled.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass
class LawResult:
    name: str
    lhs: float
    rhs: float
    ok: bool
    skipped: bool = False
    detail: str = ""

    def __str__(self) -> str:
        state = "SKIP" if self.skipped else ("ok" if self.ok else "VIOLATED")
        return (f"{state:8s} {self.name}: {self.lhs} == {self.rhs}"
                + (f"  ({self.detail})" if self.detail else ""))


@dataclasses.dataclass
class ConservationReport:
    results: List[LawResult] = dataclasses.field(default_factory=list)

    @property
    def violations(self) -> List[LawResult]:
        return [r for r in self.results if not r.ok and not r.skipped]

    @property
    def checked(self) -> int:
        return sum(1 for r in self.results if not r.skipped)

    def summary(self) -> str:
        head = (f"conservation: {self.checked}/{len(self.results)} laws "
                f"checked, {len(self.violations)} violated")
        if not self.violations:
            return head
        return "\n".join([head] + [str(v) for v in self.violations])

    def to_dict(self) -> dict:
        return {"checked": self.checked, "laws": len(self.results),
                "violations": [str(v) for v in self.violations],
                "skipped": [r.name for r in self.results if r.skipped]}


class ConservationError(AssertionError):
    pass


def check_conservation(engine,
                       strict: bool = False) -> ConservationReport:
    """Evaluate every law against a finished engine run. ``strict``
    raises :class:`ConservationError` on the first report with
    violations instead of returning it."""
    rep = ConservationReport()
    m = engine.metrics
    st = engine.strategy.stats
    store = engine.store.stats
    caches = [c.stats for c in engine.caches]
    debs = [d.stats for d in engine.debatchers]
    bats = [b.stats for b in engine.batchers]
    cluster = engine.cluster

    def law(name, lhs, rhs, skipped=False, detail=""):
        rep.results.append(LawResult(name, lhs, rhs,
                                     ok=(skipped or lhs == rhs),
                                     skipped=skipped, detail=detail))

    # -- record flow -------------------------------------------------------
    law("delivered_records_match_debatchers",
        m.records_delivered, sum(d.records_out for d in debs),
        detail="every delivery goes through Debatcher.complete")
    law("delivered_bytes_match_debatchers",
        m.bytes_delivered, sum(d.bytes_out for d in debs))
    law("batcher_ingress_matches_engine",
        sum(b.records_in for b in bats),
        m.records_in - st.records_combined,
        detail="records buffered = submitted - combined away map-side")

    failures = sum(c.stats.failures_injected for c in engine.coordinators)
    drained = (engine._pending_ingests == 0
               and not engine._work_pending())
    lossless = (m.uploads_aborted == 0 and m.fetches_aborted == 0
                and failures == 0)
    law("records_in_equals_delivered",
        m.records_delivered, m.records_in - st.records_combined,
        skipped=not (drained and lossless),
        detail="end-to-end: needs a drained run with no aborts/crashes "
               f"(aborts={m.uploads_aborted}/{m.fetches_aborted}, "
               f"failures={failures})")
    law("no_duplicates_without_replay",
        m.duplicates_delivered, 0,
        skipped=not (drained and lossless))
    law("replayed_records_match_coordinators",
        m.records_replayed,
        sum(c.stats.records_replayed for c in engine.coordinators))

    # -- GET accounting ----------------------------------------------------
    law("store_gets_led_by_caches",
        store.gets, sum(c.store_gets for c in caches),
        detail="all GET counting routes through begin_store_get")
    law("get_latency_samples_match_store_gets",
        len(m.get_latencies), store.gets,
        detail="one latency sample per issued GET (leads + hedges + merge)")
    law("put_latency_samples_match_store_puts",
        len(m.put_latencies), store.puts)
    law("cache_hits_reconcile",
        sum(c.hits for c in caches),
        sum(d.reads_cache for d in debs) + st.merge_cache_hits,
        skipped=cluster is not None,
        detail="cluster mode can drop a cache-sourced delivery at the "
               "exactly-once gate after the probe counted the hit")

    # -- notification flow -------------------------------------------------
    reads = sum(d.reads_cache + d.reads_store + d.reads_coalesced
                + d.reads_local for d in debs)
    if cluster is None:
        law("deliveries_match_admitted_notifications",
            reads,
            sum(d.notifications - d.duplicates_dropped for d in debs)
            - m.fetches_aborted,
            detail="admitted = notified - deduped; admitted fetches either "
                   "deliver or abort")
    else:
        law("deliveries_match_cluster_gate",
            reads, cluster.stats.delivered,
            detail="on_delivery admits exactly stats.delivered fetches")
        law("published_notes_match_cluster_log",
            len(engine.published), cluster.stats.published)

    # -- byte flow through the store ---------------------------------------
    law("put_bytes_match_finalized_blobs",
        store.put_bytes,
        sum(b.blob_bytes for b in bats) + st.merged_blob_bytes
        - m.uploads_aborted_bytes - m.uploads_lost_bytes,
        detail="every finalized byte is durable, aborted, or lost with a "
               "crashed lane; merged blobs add re-packed bytes")

    # -- strategy-side (two-round merge) -----------------------------------
    if st.notes_intercepted or st.merged_blobs:
        parked = sum(len(v) for v in
                     getattr(engine.strategy, "_pending", {}).values())
        law("merge_notes_conserved",
            st.notes_intercepted,
            st.merged_inputs + st.merge_fallback_notes + st.merge_singles
            + parked,
            detail="every intercepted note is merged, falls back, passes "
                   "through as a single, or is still parked")

    if strict and rep.violations:
        raise ConservationError(rep.summary())
    return rep
