"""Metrics registry: named counters / gauges / histograms keyed by
component and AZ, with virtual-clock-windowed time series.

Every metric buckets its observations into fixed ``window_s`` windows of
the *virtual* clock, so time-sliced questions ("p95 during the
rebalance", "PUT rate while the AZ was dark") are queries over the
recorded series instead of bespoke instrumentation:

    reg = MetricsRegistry(window_s=0.25)
    h = reg.histogram("e2e", component="latency")
    h.observe(0.120, now=1.37)
    h.percentile(95)                  # whole run
    h.percentile(95, t0=1.0, t1=2.0)  # only observations in [1.0, 2.0)

Histograms are backed by :class:`~repro.obs.sketch.QuantileSketch` — one
global sketch plus one per active window — so windowed quantiles come
from merging the per-window sketches, with the sketch's relative-error
guarantee intact (sketches merge losslessly).

Nothing here touches an RNG or the event loop: recording is purely a
side table, safe inside the bit-reproducible engine.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs.sketch import QuantileSketch

MetricKey = Tuple[str, str, Optional[int]]   # (name, component, az)


class Counter:
    """Monotonic counter with a per-window series of increments."""

    __slots__ = ("window_s", "total", "series")

    def __init__(self, window_s: float):
        self.window_s = window_s
        self.total = 0
        self.series: List[List[float]] = []   # [window_index, increment]

    def inc(self, n: int = 1, now: float = 0.0) -> None:
        self._inc_window(int(now // self.window_s), n)

    def _inc_window(self, idx: int, n: int) -> None:
        """Bulk path: increment with the window index already computed
        (``total_in`` never assumes unique or sorted series entries, so
        out-of-order bulk applies stay correct)."""
        self.total += n
        s = self.series
        if s and s[-1][0] == idx:
            s[-1][1] += n
        else:
            s.append([idx, n])

    def total_in(self, t0: float, t1: float) -> int:
        lo, hi = int(t0 // self.window_s), int(t1 // self.window_s)
        return int(sum(v for idx, v in self.series if lo <= idx < hi))

    def to_dict(self) -> dict:
        return {"total": self.total, "windows": len(self.series)}


class Gauge:
    """Point-in-time samples (virtual timestamp, value)."""

    __slots__ = ("samples",)

    def __init__(self, window_s: float = 0.0):
        self.samples: List[Tuple[float, float]] = []

    def set(self, value: float, now: float = 0.0) -> None:
        self.samples.append((now, value))

    @property
    def last(self) -> Optional[float]:
        return self.samples[-1][1] if self.samples else None

    def to_dict(self) -> dict:
        return {"last": self.last, "samples": len(self.samples)}


class Histogram:
    """Per-window quantile sketches with a buffered hot path.

    Observations land in a plain Python list for the current window (a
    ~100 ns append) and are flushed into that window's sketch in bulk
    when the window rolls over or the buffer fills — the engine's
    per-delivery hooks never pay per-observation sketch costs. The
    whole-run view is the (lossless) merge of the window sketches,
    built on query; queries happen a handful of times per run.
    """

    __slots__ = ("window_s", "alpha", "windows", "_buf", "_buf_idx")

    #: buffer cap — bounds memory and keeps flushes on the vectorized
    #: add_many path
    _BUF_MAX = 8192

    def __init__(self, window_s: float, alpha: float = 0.01):
        self.window_s = window_s
        self.alpha = alpha
        self.windows: List[Tuple[int, QuantileSketch]] = []
        self._buf: List[float] = []
        self._buf_idx = 0

    def _window_sketch(self, idx: int) -> QuantileSketch:
        w = self.windows
        if w and w[-1][0] == idx:
            return w[-1][1]
        sk = QuantileSketch(alpha=self.alpha)
        w.append((idx, sk))
        return sk

    def _flush(self) -> None:
        if self._buf:
            self._window_sketch(self._buf_idx).add_many(self._buf)
            self._buf = []

    def _bucket(self, now: float) -> List[float]:
        idx = int(now // self.window_s)
        if idx != self._buf_idx or len(self._buf) >= self._BUF_MAX:
            self._flush()
            self._buf_idx = idx
        return self._buf

    def observe(self, x: float, now: float = 0.0) -> None:
        self._bucket(now).append(x)

    def observe_weighted(self, x: float, n: int, now: float = 0.0) -> None:
        buf = self._bucket(now)
        if n <= 16:
            buf.extend([x] * n)
        else:
            # straight into the window sketch — adds commute with the
            # buffered values pending for the same window
            self._window_sketch(int(now // self.window_s)).add_weighted(x, n)

    def observe_many(self, xs, now: float = 0.0) -> None:
        buf = self._bucket(now)
        buf.extend(xs if type(xs) is list else np.asarray(xs).tolist())

    def _sliced(self, t0: Optional[float],
                t1: Optional[float]) -> QuantileSketch:
        self._flush()
        lo = -1 if t0 is None else int(t0 // self.window_s)
        hi = float("inf") if t1 is None else int(t1 // self.window_s)
        out = QuantileSketch(alpha=self.alpha)
        for idx, sk in self.windows:
            if lo <= idx < hi:
                out.merge(sk)
        return out

    @property
    def sketch(self) -> QuantileSketch:
        """Whole-run sketch (merged from the windows, lossless)."""
        return self._sliced(None, None)

    def percentile(self, q: float, t0: Optional[float] = None,
                   t1: Optional[float] = None) -> Optional[float]:
        return self._sliced(t0, t1).percentile(q)

    def percentiles(self, qs: Sequence[float], t0: Optional[float] = None,
                    t1: Optional[float] = None) -> list:
        return self._sliced(t0, t1).percentiles(qs)

    @property
    def count(self) -> int:
        self._flush()
        return sum(sk.count for _, sk in self.windows)

    @property
    def sum(self) -> float:
        self._flush()
        return sum(sk.sum for _, sk in self.windows)

    @property
    def mean(self) -> float:
        n = self.count
        return self.sum / n if n else 0.0

    def to_dict(self) -> dict:
        sk = self.sketch
        d = sk.to_dict()
        if sk.count:
            p50, p95, p99 = sk.percentiles([50, 95, 99])
            d.update(mean=sk.mean, p50=p50, p95=p95, p99=p99)
        d["windows"] = len(self.windows)
        return d


class MetricsRegistry:
    """Get-or-create registry of metrics keyed (name, component, az)."""

    def __init__(self, window_s: float = 0.25, alpha: float = 0.01):
        self.window_s = window_s
        self.alpha = alpha
        self.counters: Dict[MetricKey, Counter] = {}
        self.gauges: Dict[MetricKey, Gauge] = {}
        self.histograms: Dict[MetricKey, Histogram] = {}
        self.marks: List[Tuple[float, str]] = []   # (virtual time, label)

    def counter(self, name: str, component: str = "",
                az: Optional[int] = None) -> Counter:
        key = (name, component, az)
        c = self.counters.get(key)
        if c is None:
            c = self.counters[key] = Counter(self.window_s)
        return c

    def gauge(self, name: str, component: str = "",
              az: Optional[int] = None) -> Gauge:
        key = (name, component, az)
        g = self.gauges.get(key)
        if g is None:
            g = self.gauges[key] = Gauge()
        return g

    def histogram(self, name: str, component: str = "",
                  az: Optional[int] = None) -> Histogram:
        key = (name, component, az)
        h = self.histograms.get(key)
        if h is None:
            h = self.histograms[key] = Histogram(self.window_s, self.alpha)
        return h

    def mark(self, label: str, now: float) -> None:
        """Record a named instant (crash, rebalance trigger/complete…) —
        the anchors for windowed queries."""
        self.marks.append((now, label))

    def marks_named(self, prefix: str) -> List[Tuple[float, str]]:
        return [(t, label) for t, label in self.marks
                if label.startswith(prefix)]

    @staticmethod
    def _key_str(key: MetricKey) -> str:
        name, component, az = key
        out = f"{component}.{name}" if component else name
        return f"{out}[az={az}]" if az is not None else out

    def snapshot(self) -> dict:
        """JSON-ready dump of every metric (totals + summary quantiles)."""
        return {
            "counters": {self._key_str(k): c.to_dict()
                         for k, c in sorted(self.counters.items(),
                                            key=lambda kv: self._key_str(kv[0]))},
            "gauges": {self._key_str(k): g.to_dict()
                       for k, g in sorted(self.gauges.items(),
                                          key=lambda kv: self._key_str(kv[0]))},
            "histograms": {self._key_str(k): h.to_dict()
                           for k, h in sorted(self.histograms.items(),
                                              key=lambda kv: self._key_str(kv[0]))},
            "marks": [[t, label] for t, label in self.marks],
        }
