"""Public entry point: expert-parallel MoE FFN with selectable shuffle mode.

Modes (``ShuffleConfig.mode``):
  * ``dense``  — single-device capacity-based einsum dispatch (oracle; used
                 by smoke tests and as the correctness reference).
  * ``direct`` — flat all-to-all over the full EP domain (the "native Kafka
                 shuffling" baseline analogue).
  * ``blob``   — BlobShuffle: hierarchical two-stage exchange with pooled
                 per-pod blob capacity and optional int8 DCN compression.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.shuffle import dispatch as D

# Public kernel surface, resolved lazily (PEP 562): the kernel packages
# import repro.shuffle.* for their host-side front halves, so importing
# them eagerly here would cycle when a kernel module is imported first.
_KERNEL_EXPORTS = {
    "compress_pack_fused": "repro.kernels.blob_codec.ops",
    "unpack_decompress_fused": "repro.kernels.blob_codec.ops",
    "blob_pack_fused": "repro.kernels.blob_pack.ops",
    "unpack_from_keys": "repro.kernels.blob_unpack.ops",
}


def __getattr__(name):
    mod = _KERNEL_EXPORTS.get(name)
    if mod is None:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(mod), name)


@dataclasses.dataclass(frozen=True)
class ShuffleConfig:
    mode: str = "dense"                  # dense | direct | blob
    token_axes: tuple = ("pod", "data", "model")
    expert_axes: tuple = ("pod", "model")  # EP domain, major → minor
    pod_axis: str = "pod"
    capacity_factor: float = 1.25
    compress_dcn: bool = False
    norm_topk: bool = True
    # set by make_train_step when the step runs inside a shard_map that is
    # already manual over "pod" (blob grad sync): the EP domain is then
    # intra-pod and the inner shard_map uses the ambient (context) mesh.
    use_context_mesh: bool = False

    def resolve(self, mesh) -> "ShuffleConfig":
        """Drop axes that are absent from (or trivial in) the mesh."""
        names = set(_mesh_axis_names(mesh))
        tok = tuple(a for a in self.token_axes if a in names)
        exp = tuple(a for a in self.expert_axes if a in names)
        return dataclasses.replace(self, token_axes=tok, expert_axes=exp)

    def pod_local(self) -> "ShuffleConfig":
        """EP restricted to intra-pod axes (for pod-manual DP regions)."""
        return dataclasses.replace(
            self,
            token_axes=tuple(a for a in self.token_axes if a != self.pod_axis),
            expert_axes=tuple(a for a in self.expert_axes
                              if a != self.pod_axis),
            use_context_mesh=True)


def _mesh_axis_names(mesh):
    if mesh is not None:
        return mesh.axis_names
    ctx = jaxcompat.get_abstract_mesh()
    return ctx.axis_names if ctx is not None else ()


def mesh_axis_size(mesh, name) -> int:
    if mesh is not None:
        return mesh.shape[name]
    return dict(jaxcompat.get_abstract_mesh().shape)[name]


def _expert_ffn(we_gate, we_up, we_down, compute_dtype):
    """Batched SwiGLU over (E_loc, C, d) token buffers."""
    def fn(t):
        t = t.astype(compute_dtype)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", t,
                                   we_gate.astype(compute_dtype)))
        u = jnp.einsum("ecd,edf->ecf", t, we_up.astype(compute_dtype))
        return jnp.einsum("ecf,efd->ecd", g * u,
                          we_down.astype(compute_dtype))
    return fn


def _route(x, w_router, top_k, norm_topk, num_real: Optional[int] = None):
    """Router in fp32. Returns (sel_w (T,k) f32, sel_idx (T,k) i32, probs).

    ``num_real``: if the expert set was padded up to the EP-domain size
    (e.g. qwen2-moe's 60 experts on a 32-way domain -> 64), mask the pad
    columns so they are never selected.
    """
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)
    if num_real is not None and num_real < logits.shape[-1]:
        mask = jnp.arange(logits.shape[-1]) < num_real
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    sel_w, sel_idx = jax.lax.top_k(probs, top_k)
    if norm_topk:
        sel_w = sel_w / jnp.maximum(
            jnp.sum(sel_w, axis=-1, keepdims=True), 1e-9)
    return sel_w, sel_idx.astype(jnp.int32), probs


def dense_moe_ffn(x, w_router, we_gate, we_up, we_down, *, top_k: int,
                  capacity_factor: float, norm_topk: bool = True,
                  compute_dtype=jnp.bfloat16):
    """Single-device capacity-based dispatch (correctness oracle).

    x: (T, d). Returns (y (T, d), aux_loss scalar, expert_load (E,)).
    """
    T, d = x.shape
    E = w_router.shape[1]
    sel_w, sel_idx, probs = _route(x, w_router, top_k, norm_topk)
    U = T * top_k
    cap = D._cap(U / E, capacity_factor)
    from repro.shuffle.binning import bin_pack, scatter_to_bins, \
        gather_from_bins
    unit_expert = sel_idx.reshape(-1)
    unit_tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    pack = bin_pack(unit_expert, E, cap)
    ebuf = scatter_to_bins(x[unit_tok], pack, E, cap)      # (E, cap, d)
    eout = _expert_ffn(we_gate, we_up, we_down, compute_dtype)(ebuf)
    y_units = gather_from_bins(eout, pack)                  # (U, d)
    y = jnp.einsum("tk,tkd->td", sel_w,
                   y_units.reshape(T, top_k, d).astype(jnp.float32))
    load = pack.counts
    aux = _aux_loss(probs, load, U, E)
    return y.astype(x.dtype), aux, load


def _aux_loss(probs, load, total_units, E):
    """Switch-style load-balance loss: E * Σ_e f_e · p̄_e."""
    f = load.astype(jnp.float32) / jnp.maximum(total_units, 1)
    pbar = jnp.mean(probs, axis=0)
    return E * jnp.sum(f * pbar)


def _pad_experts(w_router, we_gate, we_up, we_down, ep: int):
    """Pad the expert dimension up to a multiple of the EP-domain size."""
    E = we_gate.shape[0]
    E_pad = -(-E // ep) * ep
    if E_pad == E:
        return w_router, we_gate, we_up, we_down, E
    padE = ((0, E_pad - E),)
    return (jnp.pad(w_router, ((0, 0), padE[0])),
            jnp.pad(we_gate, padE + ((0, 0), (0, 0))),
            jnp.pad(we_up, padE + ((0, 0), (0, 0))),
            jnp.pad(we_down, padE + ((0, 0), (0, 0))),
            E)


def ep_moe_ffn(x, w_router, we_gate, we_up, we_down, *, top_k: int,
               cfg: ShuffleConfig, mesh, compute_dtype=jnp.bfloat16,
               token_mask: Optional[jax.Array] = None):
    """Expert-parallel MoE FFN under shard_map.

    x: (T, d) global flat token array; T must divide the token-axes product
    (callers pad; ``token_mask`` zeroes the combine weights of pad tokens).
    Expert weights: (E, d, d_e) / (E, d_e, d), sharded over ``expert_axes``.

    Returns (y (T, d), aux_loss, diagnostics) with diagnostics psum'd over
    the whole mesh (fully replicated scalars / (E,) loads).
    """
    cfg = cfg.resolve(mesh if not cfg.use_context_mesh else None)
    if cfg.use_context_mesh:
        mesh = None
    if cfg.mode == "dense" or not cfg.expert_axes:
        y, aux, load = dense_moe_ffn(
            x, w_router, we_gate, we_up, we_down, top_k=top_k,
            capacity_factor=cfg.capacity_factor, norm_topk=cfg.norm_topk,
            compute_dtype=compute_dtype)
        zero = jnp.zeros((), jnp.float32)
        return y, aux, D.DispatchDiagnostics(
            jnp.zeros((), jnp.int32), load, zero)

    ep_size = 1
    for a in cfg.expert_axes:
        ep_size *= mesh_axis_size(mesh, a)
    w_router, we_gate, we_up, we_down, E_real = _pad_experts(
        w_router, we_gate, we_up, we_down, ep_size)
    E = w_router.shape[1]
    all_axes = tuple(_mesh_axis_names(mesh))
    # diagnostics are psum'd over the EP axes inside dispatch; fold the
    # remaining mesh axes here so out_specs=P() (fully replicated) is sound.
    spectators = tuple(a for a in all_axes if a not in cfg.expert_axes)
    has_pod = cfg.pod_axis in cfg.expert_axes and \
        mesh_axis_size(mesh, cfg.pod_axis) > 1
    mode = cfg.mode if (cfg.mode != "blob" or has_pod) else "direct"
    inner_axes = tuple(a for a in cfg.expert_axes if a != cfg.pod_axis)

    if token_mask is None:
        token_mask = jnp.ones((x.shape[0],), jnp.float32)

    def local_fn(x_loc, mask_loc, wr, wg, wu, wd):
        sel_w, sel_idx, probs = _route(x_loc, wr, top_k, cfg.norm_topk,
                                       num_real=E_real)
        sel_w = sel_w * mask_loc[:, None]
        expert_fn = _expert_ffn(wg, wu, wd, compute_dtype)
        common = dict(num_experts=E, capacity_factor=cfg.capacity_factor,
                      d_out=x_loc.shape[1])
        if mode == "blob":
            y, diag = D.blob_dispatch_combine(
                x_loc, sel_idx, sel_w, expert_fn, pod_axis=cfg.pod_axis,
                inner_axes=inner_axes, compress_dcn=cfg.compress_dcn,
                **common)
        else:
            y, diag = D.flat_dispatch_combine(
                x_loc, sel_idx, sel_w, expert_fn, ep_axes=cfg.expert_axes,
                **common)
        # Fold spectator axes into the global diagnostics + aux loss.
        n_tok = jax.lax.psum(jnp.sum(mask_loc), all_axes)
        load = diag.expert_load
        psum_probs = jax.lax.psum(
            jnp.sum(probs * mask_loc[:, None], axis=0), all_axes)
        if spectators:
            load = jax.lax.psum(load, spectators)
            dropped = jax.lax.psum(diag.dropped, spectators)
            dcn = jax.lax.psum(diag.dcn_bytes, spectators)
        else:
            dropped, dcn = diag.dropped, diag.dcn_bytes
        f = load.astype(jnp.float32) / jnp.maximum(n_tok * top_k, 1)
        pbar = psum_probs / jnp.maximum(n_tok, 1)
        aux = E_real * jnp.sum(f[:E_real] * pbar[:E_real])
        return y, aux, dropped, load[:E_real], dcn

    tok_spec = P(cfg.token_axes if cfg.token_axes else None)
    kwargs = {}
    if cfg.use_context_mesh:
        # nested inside a pod-manual region: use the ambient mesh and make
        # manual only the axes that are not already manual in the context.
        ctx = jaxcompat.get_abstract_mesh()
        kwargs["axis_names"] = (set(ctx.axis_names)
                                - jaxcompat.manual_axis_names(ctx))
    y, aux, dropped, load, dcn = jaxcompat.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(cfg.token_axes, None), tok_spec, P(None, None),
                  P(cfg.expert_axes, None, None),
                  P(cfg.expert_axes, None, None),
                  P(cfg.expert_axes, None, None)),
        out_specs=(P(cfg.token_axes, None), P(), P(), P(), P()),
        **kwargs,
    )(x, token_mask, w_router, we_gate, we_up, we_down)
    return y, aux, D.DispatchDiagnostics(dropped, load, dcn)
