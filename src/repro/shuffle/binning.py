"""Token binning ("Batcher") primitives shared by all shuffle modes.

``bin_pack`` is the tensor-level analogue of the paper's Batcher: units
(token, expert-slot) are grouped by destination into fixed-capacity,
contiguous bins — the "blobs". ``counts`` is the compact notification
metadata (the analogue of the batch-id + byte-range references that flow
through Kafka in the paper).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Packing(NamedTuple):
    slot: jax.Array     # (U,) int32 — flat slot in the (bins*capacity) buffer
    valid: jax.Array    # (U,) bool — False for capacity-overflow (dropped)
    counts: jax.Array   # (bins,) int32 — notification metadata (true demand)


def sorted_order(keys: jax.Array, num_bins: int
                 ) -> "tuple[jax.Array, jax.Array, jax.Array]":
    """Stable argsort-by-destination description: (order, starts, counts).

    ``order`` maps sorted position -> unit index; ``starts[b]`` is bin
    b's first position within ``order``; ``counts`` is the true demand.
    This is the shared front half of ``bin_pack`` and the fused pack
    kernels (``repro.kernels.blob_pack``)."""
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    counts = jnp.bincount(keys, length=num_bins).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    return order, starts, counts


def bin_pack(keys: jax.Array, num_bins: int, capacity: int) -> Packing:
    """Assign each unit a slot = key*capacity + rank-within-key.

    Ranks are assigned in stable sorted order, so records for a given
    destination appear contiguously — matching the paper's blob layout
    ("records for a given partition appear sequentially within the batch").
    """
    U = keys.shape[0]
    order, starts, counts = sorted_order(keys, num_bins)
    sorted_keys = keys[order]
    rank_sorted = jnp.arange(U, dtype=jnp.int32) - starts[sorted_keys]
    rank = jnp.zeros(U, jnp.int32).at[order].set(rank_sorted)
    valid = rank < capacity
    slot = keys.astype(jnp.int32) * capacity + jnp.minimum(rank, capacity - 1)
    return Packing(slot, valid, counts)


def scatter_to_bins(values: jax.Array, pack: Packing, num_bins: int,
                    capacity: int) -> jax.Array:
    """values: (U, ...) -> (num_bins, capacity, ...). Overflow units are
    routed to a dump row that is sliced off (no collisions among valid)."""
    total = num_bins * capacity
    slot = jnp.where(pack.valid, pack.slot, total)
    buf = jnp.zeros((total + 1,) + values.shape[1:], values.dtype)
    buf = buf.at[slot].set(values, mode="drop")
    return buf[:total].reshape((num_bins, capacity) + values.shape[1:])


def gather_from_bins(buf: jax.Array, pack: Packing) -> jax.Array:
    """Inverse of scatter: (num_bins, capacity, ...) -> (U, ...).
    Invalid (dropped) units read zeros."""
    flat = buf.reshape((-1,) + buf.shape[2:])
    vals = flat[pack.slot]
    mask = pack.valid.reshape((-1,) + (1,) * (vals.ndim - 1))
    return jnp.where(mask, vals, 0)


def dropped_units(pack: Packing, capacity: int) -> jax.Array:
    """Overflow count derived from the notification metadata."""
    return jnp.sum(jnp.maximum(pack.counts - capacity, 0))
