"""Quantized transfer for the expensive (inter-pod / DCN) leg.

The paper pays the cheap tier (object storage) with bytes and the expensive
tier (cross-AZ) with nothing; the TPU analogue compresses payloads before
they cross the ``pod`` axis. Two users:

  * blob MoE dispatch: int8 per-row quantization of the stage-2 blobs,
  * gradient sync: int8 all-reduce with **error feedback** (the residual is
    carried to the next step so compression noise does not bias training).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization over the last axis.

    Returns (q int8 same shape, scale float32 shape[:-1]).
    """
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def compress_decompress(x: jax.Array) -> jax.Array:
    """Round-trip (used to model the lossy channel in tests/benchmarks)."""
    q, s = int8_quantize(x)
    return int8_dequantize(q, s, x.dtype)


def with_error_feedback(grad: jax.Array, residual: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Quantize (grad + residual); return (dequantized payload, new residual).

    new_residual = (grad + residual) - payload — carried to the next step.
    """
    target = grad.astype(jnp.float32) + residual.astype(jnp.float32)
    payload = compress_decompress(target)
    new_residual = target - payload.astype(jnp.float32)
    return payload.astype(grad.dtype), new_residual.astype(residual.dtype)
