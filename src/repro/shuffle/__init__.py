"""TPU-native adaptation of BlobShuffle: hierarchical, blob-batched
repartitioning collectives (see DESIGN.md §2).

  * ``dispatch``  — per-device token dispatch/combine (flat vs blob modes)
  * ``api``       — shard_map wrappers (the public entry points)
  * ``grad_sync`` — blob-bucketed hierarchical cross-pod gradient reduction
  * ``compression`` — int8 quantization with error feedback for the DCN leg
"""

from repro.shuffle.api import ep_moe_ffn, ShuffleConfig  # noqa: F401
