"""Blob-bucketed hierarchical cross-pod gradient synchronization.

The BlobShuffle pattern applied to dense-model data parallelism: intra-pod
reductions ride the cheap ICI (handled by GSPMD as usual), while the
cross-pod ("cross-AZ") reduction is taken over manually and

  * **bucketed** into ~``blob_bytes`` flat blobs (the ``S_batch`` knob —
    amortizes per-collective latency/launch overhead exactly as batching
    amortizes per-request S3 cost, and enables overlap),
  * optionally **int8-compressed** on the DCN leg only (pay the expensive
    tier in fewer bytes), with optional **error feedback** so compression
    noise is carried, not accumulated.

Exact algorithm per blob (P = number of pods):
  reshape (P, n/P) → all_to_all over "pod" (each pod receives every pod's
  copy of its shard) → dequantize+sum locally → re-quantize → all_gather.
  DCN bytes: 2·(P−1)/P·n·itemsize  (itemsize 1 when compressed vs 4).

These functions run inside a shard_map that is *manual* over the "pod"
axis (see ``make_train_step``'s grad_sync modes).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.shuffle import compression

PyTree = Any


MAX_BLOBS = 32  # cap on emitted collectives (keeps HLO size bounded)


def _flatten_to_blobs(tree: PyTree, blob_bytes: int):
    """Concatenate all leaves (as f32) and split into ~blob_bytes blobs.

    The blob count is capped at MAX_BLOBS: like the paper's Batcher, the
    batch size is a *target* — very large gradients get proportionally
    larger blobs rather than an unbounded number of collectives.
    """
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.astype(jnp.float32).reshape(-1)
                            for l in leaves])
    n_per_blob = max(blob_bytes // 4, 1)
    n_blobs = min(max(-(-flat.size // n_per_blob), 1), MAX_BLOBS)
    n_per_blob = -(-flat.size // n_blobs)
    pad = n_blobs * n_per_blob - flat.size
    flat = jnp.pad(flat, (0, pad))
    blobs = flat.reshape(n_blobs, n_per_blob)
    meta = (treedef, [(l.shape, l.dtype) for l in leaves], pad)
    return blobs, meta


def _unflatten_from_blobs(blobs: jax.Array, meta) -> PyTree:
    treedef, shapes, pad = meta
    flat = blobs.reshape(-1)
    if pad:
        flat = flat[:-pad]
    out, off = [], 0
    for shape, dtype in shapes:
        n = 1
        for s in shape:
            n *= s
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, out)


def _blob_allreduce(blob: jax.Array, pod_axis: str, npods: int,
                    compress: bool) -> jax.Array:
    """All-reduce one (n,) blob across pods via a2a + local sum + gather."""
    if npods == 1:
        return blob
    n = blob.shape[0]
    pad = (-n) % npods
    x = jnp.pad(blob, (0, pad)).reshape(npods, -1)
    if compress:
        q, s = compression.int8_quantize(x)
        q = jax.lax.all_to_all(q, pod_axis, 0, 0, tiled=False)
        s = jax.lax.all_to_all(s, pod_axis, 0, 0, tiled=False)
        shard = jnp.sum(compression.int8_dequantize(q, s, jnp.float32),
                        axis=0)
        qr, sr = compression.int8_quantize(shard[None, :])
        qg = jax.lax.all_gather(qr[0], pod_axis)
        sg = jax.lax.all_gather(sr, pod_axis)
        full = compression.int8_dequantize(qg, sg[:, 0], jnp.float32)
    else:
        x = jax.lax.all_to_all(x, pod_axis, 0, 0, tiled=False)
        shard = jnp.sum(x, axis=0)
        full = jax.lax.all_gather(shard, pod_axis)
    out = full.reshape(-1)
    return out[:n] if pad else out


def blob_allreduce_grads(grads: PyTree, *, pod_axis: str = "pod",
                         blob_bytes: int = 16 * 1024 * 1024,
                         compress: bool = False,
                         residual: Optional[jax.Array] = None,
                         average: bool = True
                         ) -> Tuple[PyTree, Optional[jax.Array]]:
    """Hierarchically all-reduce a gradient pytree across pods.

    ``residual``: error-feedback state (flat blobs array) when compressing;
    pass None to disable EF. Returns (synced grads, new residual).
    """
    npods = jax.lax.psum(1, pod_axis)
    blobs, meta = _flatten_to_blobs(grads, blob_bytes)
    if compress and residual is not None:
        target = blobs + residual
    else:
        target = blobs

    # one collective per blob — independent ops XLA can schedule/overlap
    reduced = jnp.stack([
        _blob_allreduce(target[i], pod_axis, npods, compress)
        for i in range(target.shape[0])])

    new_residual = None
    if compress and residual is not None:
        # what this pod contributed vs what actually went out on the wire
        sent = jnp.stack([compression.compress_decompress(target[i])
                          for i in range(target.shape[0])])
        new_residual = target - sent
    if average:
        reduced = reduced / npods
    return _unflatten_from_blobs(reduced, meta), new_residual


def residual_init(grads_like: PyTree, blob_bytes: int = 16 * 1024 * 1024
                  ) -> jax.Array:
    blobs, _ = _flatten_to_blobs(
        jax.tree.map(jnp.zeros_like, grads_like), blob_bytes)
    return blobs
