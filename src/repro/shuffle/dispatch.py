"""Per-device expert dispatch/combine — flat baseline vs blob-hierarchical.

These functions run *inside* ``shard_map`` (see ``repro.shuffle.api``) and
implement two routings of the same logical token→expert repartitioning:

``flat``  — the "native Kafka Streams shuffling" analogue: one all-to-all over
            the full EP domain. Every (source, destination-device) pair gets
            its own worst-case-sized lane, so slack capacity (and on a
            multi-pod mesh, every fine-grained message) crosses the expensive
            inter-pod link individually.

``blob``  — the BlobShuffle analogue: two-stage hierarchical exchange.
            Stage 1 bins units by destination *model-rank* and exchanges them
            intra-pod (cheap ICI) so that each device aggregates one
            contiguous **blob** per destination pod. Stage 2 moves those
            pooled blobs across the ``pod`` axis (expensive DCN) exactly once
            — the "GET once per AZ" invariant — with capacity pooled over all
            intra-pod sources (statistical multiplexing → smaller slack), and
            optionally int8-compressed (the cheap-tier/expensive-tier split
            of the paper).

Both modes pre-exchange compact **notification** metadata (per-destination
counts) so overflow/load diagnostics are exact.
"""

from __future__ import annotations

import math
from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.shuffle.binning import (bin_pack,
                                   dropped_units,
                                   gather_from_bins,
                                   scatter_to_bins)
from repro.shuffle import compression


class DispatchDiagnostics(NamedTuple):
    dropped: jax.Array          # units dropped to capacity overflow (global)
    expert_load: jax.Array      # (E,) tokens routed per expert (global)
    dcn_bytes: jax.Array        # payload bytes that crossed the pod axis


def _cap(expected: float, factor: float, align: int = 8) -> int:
    c = int(math.ceil(expected * factor))
    return max(align, -(-c // align) * align)


def pooled_capacity_factor(base: float, pool: int) -> float:
    """Slack needed shrinks ~1/sqrt(pool) when pooling independent demand —
    the statistical-multiplexing win of blob aggregation (paper §4 batching)."""
    return 1.0 + (base - 1.0) / math.sqrt(max(pool, 1))


def _a2a(x: jax.Array, axis_names) -> jax.Array:
    """Tiled all-to-all over (possibly multiple) mesh axes; x: (ep, C, ...)."""
    return jax.lax.all_to_all(x, axis_names, split_axis=0, concat_axis=0,
                              tiled=False)


# ---------------------------------------------------------------------------
# Flat (baseline) dispatch
# ---------------------------------------------------------------------------

def flat_dispatch_combine(
    x: jax.Array,                 # (T_loc, d) local tokens
    sel_idx: jax.Array,           # (T_loc, k) selected global expert ids
    sel_w: jax.Array,             # (T_loc, k) combine weights
    expert_fn: Callable,          # (E_loc, C, d) -> (E_loc, C, d_out)
    *,
    num_experts: int,
    ep_axes: Sequence[str],       # axes forming the EP domain, e.g. ("pod","model")
    capacity_factor: float,
    d_out: int,
):
    """One-stage all-to-all over the whole EP domain."""
    T_loc, d = x.shape
    k = sel_idx.shape[1]
    ep = _axes_size(ep_axes)
    E_loc = num_experts // ep
    U = T_loc * k

    unit_expert = sel_idx.reshape(-1)
    unit_tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)

    # Per-(source, expert) lane capacity — fine-grained, worst-case slack.
    cap = _cap(U / num_experts, capacity_factor)
    pack = bin_pack(unit_expert, num_experts, cap)

    send = scatter_to_bins(x[unit_tok], pack, num_experts, cap)
    send = send.reshape(ep, E_loc * cap, d)
    recv = _a2a(send, tuple(ep_axes))                       # (ep, E_loc*cap, d)
    recv = recv.reshape(ep, E_loc, cap, d).transpose(1, 0, 2, 3) \
        .reshape(E_loc, ep * cap, d)

    out = expert_fn(recv)                                   # (E_loc, ep*cap, d_out)

    back = out.reshape(E_loc, ep, cap, d_out).transpose(1, 0, 2, 3) \
        .reshape(ep, E_loc * cap, d_out)
    back = _a2a(back, tuple(ep_axes))
    back = back.reshape(num_experts, cap, d_out)
    y_units = gather_from_bins(back, pack)                  # (U, d_out)

    y = jnp.einsum("tk,tkd->td", sel_w,
                   y_units.reshape(T_loc, k, d_out).astype(jnp.float32))

    # notifications → diagnostics
    counts_global = jax.lax.psum(pack.counts, tuple(ep_axes))
    dropped = jax.lax.psum(dropped_units(pack, cap), tuple(ep_axes))
    dcn = _flat_dcn_bytes(send, ep_axes)
    return y.astype(x.dtype), DispatchDiagnostics(dropped, counts_global, dcn)


def _flat_dcn_bytes(send: jax.Array, ep_axes: Sequence[str]) -> jax.Array:
    """Bytes of the flat a2a payload that cross the pod boundary."""
    if "pod" not in ep_axes:
        return jnp.zeros((), jnp.float32)
    npods = jax.lax.psum(1, "pod")
    frac_cross = (npods - 1) / npods
    per_dev = send.size * jnp.dtype(send.dtype).itemsize * frac_cross
    return jax.lax.psum(jnp.float32(per_dev), tuple(ep_axes))


# ---------------------------------------------------------------------------
# Blob (hierarchical) dispatch — the paper's technique
# ---------------------------------------------------------------------------

def blob_dispatch_combine(
    x: jax.Array,
    sel_idx: jax.Array,
    sel_w: jax.Array,
    expert_fn: Callable,
    *,
    num_experts: int,
    pod_axis: str,                # outer (expensive) axis
    inner_axes: Sequence[str],    # intra-pod EP axes, e.g. ("model",)
    capacity_factor: float,
    d_out: int,
    compress_dcn: bool = False,   # int8-compress the inter-pod leg
):
    """Two-stage hierarchical dispatch: intra-pod aggregation → pooled
    inter-pod blob transfer → local expert execution. See module docstring."""
    T_loc, d = x.shape
    k = sel_idx.shape[1]
    P = _axes_size([pod_axis])
    M = _axes_size(inner_axes)
    ep = P * M
    E_loc = num_experts // ep
    U = T_loc * k

    unit_expert = sel_idx.reshape(-1)
    unit_tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), k)

    # expert e lives at (pod p, model m, local l):
    #   p = e // (M*E_loc);  m = (e // E_loc) % M;  l = e % E_loc
    dest_m = (unit_expert // E_loc) % M

    # ---- Stage 1: intra-pod exchange over the model axis (cheap ICI).
    cap1 = _cap(U / M, capacity_factor)
    pack1 = bin_pack(dest_m, M, cap1)
    payload1 = scatter_to_bins(x[unit_tok], pack1, M, cap1)
    meta1 = scatter_to_bins(unit_expert + 1, pack1, M, cap1)  # 0 == empty
    recv1 = _a2a(payload1, tuple(inner_axes))     # (M, cap1, d)
    rmeta1 = _a2a(meta1, tuple(inner_axes))       # (M, cap1)

    # This device now aggregates, per destination pod, one contiguous blob
    # of everything its pod wants to send to its model-rank peers there.
    u1_expert = rmeta1.reshape(-1) - 1            # (-1 == empty slot)
    u1_valid = u1_expert >= 0
    u1_x = recv1.reshape(M * cap1, d)

    dest_p = jnp.where(u1_valid, u1_expert // (M * E_loc), P)  # P == drop bin
    # ---- Stage 2: pooled blob capacity — slack shrinks by ~1/sqrt(M)
    # because demand from M sources is multiplexed into one blob.
    # Expected arrivals at this device: M sources × U/M units = U; per pod U/P.
    cf2 = pooled_capacity_factor(capacity_factor, M)
    cap2 = _cap(U / P, cf2)
    pack2 = bin_pack(dest_p.astype(jnp.int32), P + 1, cap2)
    payload2 = scatter_to_bins(u1_x, pack2, P + 1, cap2)[:P]
    meta2 = scatter_to_bins(u1_expert + 1, pack2, P + 1, cap2)[:P]

    if compress_dcn:
        q, scale = compression.int8_quantize(payload2)
        q = _a2a(q, (pod_axis,))
        scale = _a2a(scale, (pod_axis,))
        recv2 = compression.int8_dequantize(q, scale, payload2.dtype)
        dcn_payload_bytes = payload2.size * 1 + scale.size * 4
    else:
        recv2 = _a2a(payload2, (pod_axis,))
        dcn_payload_bytes = payload2.size * jnp.dtype(payload2.dtype).itemsize
    rmeta2 = _a2a(meta2, (pod_axis,))

    # ---- Local expert execution ("Debatcher" + processing)
    u2_expert = rmeta2.reshape(-1) - 1
    u2_valid = u2_expert >= 0
    u2_x = recv2.reshape(P * cap2, d)
    local_e = jnp.where(u2_valid, u2_expert % E_loc, E_loc)
    # Expected per local expert: U·P·M system units / E experts = U/E_loc.
    cf3 = pooled_capacity_factor(capacity_factor, M * P)
    cap_e = _cap(U / E_loc, cf3)
    pack3 = bin_pack(local_e.astype(jnp.int32), E_loc + 1, cap_e)
    ebuf = scatter_to_bins(u2_x, pack3, E_loc + 1, cap_e)[:E_loc]

    eout = expert_fn(ebuf)                        # (E_loc, cap_e, d_out)

    # ---- Reverse path (slots are symmetric; results ride the same lanes)
    eout_full = jnp.concatenate(
        [eout, jnp.zeros((1, cap_e, d_out), eout.dtype)], axis=0)
    y2 = gather_from_bins(eout_full, pack3)       # (P*cap2, d_out)
    back2 = y2.reshape(P, cap2, d_out)
    back2 = _a2a(back2, (pod_axis,))
    y1_full = jnp.concatenate(
        [back2, jnp.zeros((1, cap2, d_out), back2.dtype)], axis=0)
    y1 = gather_from_bins(y1_full, pack2)         # (M*cap1, d_out)
    back1 = y1.reshape(M, cap1, d_out)
    back1 = _a2a(back1, tuple(inner_axes))
    y_units = gather_from_bins(back1, pack1)      # (U, d_out)

    y = jnp.einsum("tk,tkd->td", sel_w,
                   y_units.reshape(T_loc, k, d_out).astype(jnp.float32))

    all_axes = tuple(inner_axes) + (pod_axis,)
    counts_global = jax.lax.psum(
        jnp.bincount(unit_expert, length=num_experts).astype(jnp.int32),
        all_axes)
    dropped = jax.lax.psum(
        dropped_units(pack1, cap1)
        + jnp.sum(jnp.maximum(pack2.counts[:P] - cap2, 0))
        + jnp.sum(jnp.maximum(pack3.counts[:E_loc] - cap_e, 0)), all_axes)
    frac_cross = (P - 1) / P
    dcn = jax.lax.psum(jnp.float32(dcn_payload_bytes * frac_cross), all_axes)
    return y.astype(x.dtype), DispatchDiagnostics(dropped, counts_global, dcn)


def _axes_size(axis_names) -> int:
    size = 1
    for a in axis_names:
        size *= jax.lax.psum(1, a)
    return size
