"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

On real hardware this runs the full production configuration; on CPU use
``--smoke`` for the reduced config (same code path, small shapes). The
multi-pod distribution config itself is proven by ``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-mode", default="dense",
                    choices=["dense", "direct", "blob"])
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "blob", "blob_int8"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    import jax
    from repro.checkpoint import FileStore
    from repro.configs import get_config
    from repro.data import lm_batch_stream
    from repro.models import lm
    from repro.models.common import init_params
    from repro.runtime import FaultTolerantTrainer
    from repro.shuffle.api import ShuffleConfig
    from repro.training import (OptConfig, TrainConfig, adamw_init,
                                make_train_step)
    from repro.utils import tree_num_params

    cfg = get_config(args.arch, smoke=args.smoke)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(devices=n_dev)
    shuf = ShuffleConfig(mode=args.moe_mode) if cfg.moe else \
        ShuffleConfig(mode="dense")
    tcfg = TrainConfig(opt=OptConfig(learning_rate=args.lr,
                                     total_steps=args.steps),
                       microbatches=args.microbatches, shuffle=shuf,
                       grad_sync=args.grad_sync)
    step = jax.jit(make_train_step(cfg, tcfg, mesh=mesh))
    batch_fn = lm_batch_stream(cfg.vocab_size, args.batch, args.seq,
                               multimodal=cfg.multimodal,
                               d_model=cfg.d_model)
    print(f"arch={cfg.name} params={tree_num_params(params):,} "
          f"devices={n_dev}")

    ckpt_dir = args.ckpt_dir or f"/tmp/repro_ckpt_{args.arch}"
    trainer = FaultTolerantTrainer(FileStore(ckpt_dir), step, batch_fn,
                                   ckpt_every=args.ckpt_every)
    t0 = time.time()
    params, opt, losses = trainer.run(params, opt, steps=args.steps)
    print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; ckpt={ckpt_dir}")


if __name__ == "__main__":
    main()
