"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns an ArraySpec tree for the step inputs;
``abstract()`` / sharding rules are applied by the dry-run and launchers.
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig, ShapeConfig


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, ArraySpec]:
    B, S = shape.global_batch, shape.seq_len

    if shape.step == "decode":
        return {"tokens": ArraySpec((B, 1), jnp.int32, ("batch", None)),
                "pos": ArraySpec((), jnp.int32, ())}

    specs: Dict[str, ArraySpec] = {}
    mm = cfg.multimodal
    if mm is not None and mm.kind == "audio":
        specs["frames"] = ArraySpec((B, S, cfg.d_model), jnp.bfloat16,
                                    ("batch", "seq", None))
    elif mm is not None and mm.kind == "vision":
        P = mm.num_patches
        specs["tokens"] = ArraySpec((B, S - P), jnp.int32, ("batch", "seq"))
        specs["patches"] = ArraySpec((B, P, cfg.d_model), jnp.bfloat16,
                                     ("batch", "seq", None))
    else:
        specs["tokens"] = ArraySpec((B, S), jnp.int32, ("batch", "seq"))

    if shape.step == "train":
        specs["labels"] = ArraySpec((B, S), jnp.int32, ("batch", "seq"))
    return specs
