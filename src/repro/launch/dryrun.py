import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch × shape) cell on the
# production meshes and extract roofline terms from the compiled artifact.
# The two lines above MUST run before any jax import (device count locks on
# first init); tests/benches never import this module.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro import jaxcompat  # noqa: E402
from repro.configs import all_cells, all_skips, get_config, get_shape  # noqa: E402
from repro.distributed.sharding import (DEFAULT_RULES, named_shardings,  # noqa: E402
                                        partition_spec)
from repro.launch import hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import input_specs  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models.common import (ArraySpec, ModelConfig, ShapeConfig,  # noqa: E402
                                 abstract_params, is_spec)
from repro.serving.engine import ServeConfig, make_decode_step, \
    make_prefill_step  # noqa: E402
from repro.shuffle.api import ShuffleConfig  # noqa: E402
from repro.training.train_step import TrainConfig, make_train_step  # noqa: E402
from repro.training.optimizer import OptConfig  # noqa: E402

# --- hardware model (TPU v5e-class, per assignment) -------------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per chip (intra-pod link)
DCN_BW = 6.25e9            # bytes/s per chip (inter-pod; assumed ICI/8)
HBM_PER_CHIP = 16 * 1024**3

# microbatch counts for train cells (activation-memory control)
MICROBATCH = {
    "qwen2-72b": 8, "llava-next-34b": 8,
    "zamba2-2.7b": 8, "mamba2-130m": 8,
    # §Perf 2.1: one big microbatch amortizes FSDP/SP gathers (3.4× step)
    "deepseek-v2-lite-16b": 1, "qwen2-moe-a2.7b": 1,
    "starcoder2-3b": 2, "granite-3-2b": 2, "gemma-2b": 2,
    "hubert-xlarge": 2,
}


def _float_to(dtype):
    def f(s: ArraySpec) -> ArraySpec:
        if jnp.issubdtype(jnp.dtype(s.dtype), jnp.floating):
            return dataclasses.replace(s, dtype=dtype)
        return s
    return f


def serving_param_defs(cfg: ModelConfig):
    """Serving keeps weights in compute dtype (bf16)."""
    return jax.tree.map(_float_to(cfg.compute_dtype), lm.param_defs(cfg),
                        is_leaf=is_spec)


def cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Per-cell sharding rules (see DESIGN.md / sharding.py).

    Decode: pure tensor parallelism — NO FSDP ("embed"->data) on serving
    weights. FSDP'd weights make QKV projections partial-sum over "data",
    and GSPMD pushes that psum through the cache dynamic-update-slice,
    all-reducing the entire stacked KV cache every step (observed 14.6 GB
    all-reduce on deepseek decode_32k — see EXPERIMENTS.md §Perf).
    MLA latent caches (no head dim) and GQA caches whose kv-head count
    does not divide the model axis are sequence-sharded instead.
    """
    rules = DEFAULT_RULES
    if shape.is_decode:
        model_size = mesh.shape.get("model", 1)
        if cfg.mla is not None:
            # MLA: pure TP + seq-sharded latent cache. FSDP'd serving
            # weights make the latent projection a partial sum which GSPMD
            # pushes through the cache update, all-reducing the whole
            # stacked cache (§Perf D1) — measured 64 GiB -> 10 GiB.
            rules = rules.override(embed=(), kv_embed=(),
                                   kv_heads=(), kv_seq=("model",))
        elif cfg.num_kv_heads % model_size != 0:
            # GQA with kv heads not divisible by TP: sequence-shard caches
            rules = rules.override(kv_heads=(), kv_seq=("model",))
    return rules


def _strip_ambient_manual(pspec):
    """Drop mesh axes that are Manual in the ambient mesh (inside a
    pod-manual shard_map the constraint must not mention "pod")."""
    ctx = jaxcompat.get_abstract_mesh()
    if ctx is None:
        return pspec
    manual = jaxcompat.manual_axis_names(ctx)
    if not manual:
        return pspec

    def strip(part):
        if part is None:
            return None
        if isinstance(part, tuple):
            kept = tuple(a for a in part if a not in manual)
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return None if part in manual else part
    from jax.sharding import PartitionSpec as P
    return P(*(strip(p) for p in pspec))


def make_hints(cfg: ModelConfig, mesh, rules):
    """Sharding-constraint hooks: sequence-parallel residuals + either
    head-sharded or context-parallel (q-block-sharded) flash attention."""
    from repro.models.flash import ShardHints
    act_rules = rules.override(seq=("model",))

    def residual(x):
        spec = ArraySpec(x.shape, x.dtype, ("batch", "seq", None))
        ps = _strip_ambient_manual(partition_spec(spec, act_rules, mesh))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))

    model_size = mesh.shape.get("model", 1)
    heads_ok = cfg.num_heads % model_size == 0

    def qblocks(x):  # (B, nq, qc, H, D)
        axes = (("batch", None, None, "heads", None) if heads_ok
                else ("batch", "seq", None, None, None))
        spec = ArraySpec(x.shape, x.dtype, axes)
        ps = _strip_ambient_manual(partition_spec(spec, act_rules, mesh))
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, ps))

    return ShardHints(residual=residual, qblocks=qblocks)


def pick_q_chunk(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """q-chunk so that the q-block count divides the model axis when the
    arch needs context-parallel attention (heads % model != 0)."""
    model_size = mesh.shape.get("model", 1)
    if cfg.num_heads % model_size == 0:
        return cfg.flash_q_chunk
    qc = cfg.flash_q_chunk
    while qc > 128 and (shape.seq_len // qc) % model_size != 0:
        qc //= 2
    return qc


def shuffle_for(cfg: ModelConfig, mesh, moe_mode: str) -> ShuffleConfig:
    return ShuffleConfig(
        mode=moe_mode if cfg.moe is not None else "dense",
        token_axes=("pod", "data", "model"),
        expert_axes=("pod", "model"),
    )


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (train) / 2·N·D (inference tokens)."""
    n_active = cfg.active_param_count()
    embed = cfg.vocab_size * cfg.d_model
    n = max(n_active - embed, 1)
    if shape.step == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.step == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
               moe_mode: str, grad_sync: str, microbatches: int,
               remat: str = "full", mla_absorb: bool = False,
               compress_dcn: bool = False):
    rules = cell_rules(cfg, shape, mesh)
    shuf = shuffle_for(cfg, mesh, moe_mode)
    batch_defs = input_specs(cfg, shape)
    batch_abs = {k: s.abstract() for k, s in batch_defs.items()}
    batch_sh = {k: NamedSharding(mesh, partition_spec(s, rules, mesh))
                for k, s in batch_defs.items()}

    if shape.step in ("train", "prefill"):
        cfg = dataclasses.replace(
            cfg, flash_q_chunk=pick_q_chunk(cfg, shape, mesh))
    hints = make_hints(cfg, mesh, rules)

    if shape.step == "train":
        defs = lm.param_defs(cfg)
        params_abs = abstract_params(defs)
        params_sh = named_shardings(defs, rules, mesh)
        opt_defs = {"m": jax.tree.map(_float_to(jnp.float32), defs,
                                      is_leaf=is_spec),
                    "v": jax.tree.map(_float_to(jnp.float32), defs,
                                      is_leaf=is_spec),
                    "count": ArraySpec((), jnp.int32, ())}
        opt_abs = abstract_params(opt_defs)
        opt_sh = named_shardings(opt_defs, rules, mesh)
        if compress_dcn:
            shuf = dataclasses.replace(shuf, compress_dcn=True)
        tcfg = TrainConfig(opt=OptConfig(), microbatches=microbatches,
                           remat=remat, shuffle=shuf, grad_sync=grad_sync)
        step = make_train_step(cfg, tcfg, mesh=mesh, hints=hints)
        fn = jax.jit(step,
                     in_shardings=(params_sh, opt_sh, batch_sh),
                     out_shardings=(params_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs)

    if shape.step == "prefill":
        defs = serving_param_defs(cfg)
        params_abs = abstract_params(defs)
        params_sh = named_shardings(defs, rules, mesh)
        scfg = ServeConfig(shuffle=shuf)
        prefill = make_prefill_step(cfg, scfg, mesh=mesh, hints=hints)
        fn = jax.jit(prefill, in_shardings=(params_sh, batch_sh))
        return fn, (params_abs, batch_abs)

    # decode
    defs = serving_param_defs(cfg)
    params_abs = abstract_params(defs)
    params_sh = named_shardings(defs, rules, mesh)
    cdefs = lm.cache_defs(cfg, shape.global_batch, shape.seq_len)
    cache_abs = abstract_params(cdefs)
    cache_sh = named_shardings(cdefs, rules, mesh)
    scfg = ServeConfig(shuffle=shuf)
    decode = make_decode_step(cfg, scfg, mesh=mesh)
    fn = jax.jit(decode, in_shardings=(params_sh, cache_sh, batch_sh),
                 out_shardings=(cache_sh, None, None),
                 donate_argnums=(1,))
    return fn, (params_abs, cache_abs, batch_abs)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             moe_mode: str = "blob", grad_sync: str = "auto",
             microbatches: int = 0, remat: str = "full",
             capacity_factor: float = 0.0, ssd_chunk: int = 0,
             ssd_bf16: bool = False, mla_absorb: bool = False,
             compress_dcn: bool = False) -> dict:
    cfg = get_config(arch)
    overrides = {}
    if ssd_chunk and cfg.ssm is not None:
        overrides["ssm"] = dataclasses.replace(cfg.ssm, chunk=ssd_chunk)
    if ssd_bf16 and cfg.ssm is not None:
        base = overrides.get("ssm", cfg.ssm)
        overrides["ssm"] = dataclasses.replace(base, intra_bf16=True)
    if capacity_factor and cfg.moe is not None:
        overrides["moe"] = dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor)
    if mla_absorb and cfg.mla is not None:
        overrides["mla"] = dataclasses.replace(cfg.mla)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = get_shape(shape_name)
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    n_dev = 1
    for a in mesh.axis_names:
        n_dev *= mesh.shape[a]
    dpp = n_dev // mesh.shape.get("pod", 1)
    mb = microbatches or (MICROBATCH.get(arch, 1)
                          if shape.step == "train" else 1)

    if mla_absorb:
        import repro.models.lm as _lm
        import repro.models.mla as _mla
        _orig = _mla.mla_decode

        def _mla_decode_abs(c, p, x, cache, pos):
            return _orig(c, p, x, cache, pos, absorb=True)
        _lm_attn = _lm._attn_decode

        def _patched(c, p, x, cache, pos):
            if c.mla is not None:
                return _mla_decode_abs(c, p, x, cache, pos)
            return _lm_attn(c, p, x, cache, pos)
        _lm._attn_decode = _patched

    t0 = time.time()
    fn, args = build_cell(cfg, shape, mesh, moe_mode=moe_mode,
                          grad_sync=grad_sync, microbatches=mb,
                          remat=remat, compress_dcn=compress_dcn)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = jaxcompat.cost_analysis(compiled)
    stats = hlo_analysis.analyze(compiled.as_text(), num_devices=n_dev,
                                 devices_per_pod=dpp)

    compute_s = stats.flops / PEAK_FLOPS
    memory_s = stats.bytes_accessed / HBM_BW
    ici_bytes = stats.collective_bytes - stats.dcn_collective_bytes
    collective_s = ici_bytes / ICI_BW + stats.dcn_collective_bytes / DCN_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(cfg, shape) / n_dev
    bound_s = mf / PEAK_FLOPS
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "step": shape.step, "devices": n_dev,
        "moe_mode": moe_mode if cfg.moe else None,
        "grad_sync": grad_sync if shape.step == "train" else None,
        "microbatches": mb, "remat": remat,
        "capacity_factor": capacity_factor or None,
        "ssd_chunk": ssd_chunk or None, "ssd_bf16": ssd_bf16,
        "mla_absorb": mla_absorb,
        "compress_dcn": compress_dcn,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_est_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
            "hbm_per_chip": HBM_PER_CHIP,
        },
        "xla_cost_analysis": {
            "flops_no_trips": ca.get("flops"),
            "bytes_no_trips": ca.get("bytes accessed"),
        },
        "hlo": {
            "flops_per_dev": stats.flops,
            "bytes_per_dev": stats.bytes_accessed,
            "collective_bytes_per_dev": stats.collective_bytes,
            "dcn_collective_bytes_per_dev": stats.dcn_collective_bytes,
            "collective_by_op": stats.collective_by_op,
            "collective_count": stats.collective_count,
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "step_time_s": step_s,
            "model_flops_per_dev": mf,
            "useful_flops_ratio": (mf / stats.flops) if stats.flops else 0.0,
            "roofline_fraction": (bound_s / step_s) if step_s else 0.0,
        },
    }
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--moe-mode", default="blob",
                    choices=["blob", "direct", "dense"])
    ap.add_argument("--grad-sync", default="auto",
                    choices=["auto", "blob", "blob_int8"])
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--cf", type=float, default=0.0,
                    help="MoE capacity factor override")
    ap.add_argument("--ssd-chunk", type=int, default=0)
    ap.add_argument("--ssd-bf16", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--compress-dcn", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.list:
        for arch, shp in all_cells():
            print(f"{arch:24s} {shp}")
        for arch, shp, why in all_skips():
            print(f"{arch:24s} {shp:12s} SKIP: {why}")
        return

    if args.all:
        # one subprocess per cell: isolation + incremental (skip existing)
        failures = []
        for arch, shp in all_cells():
            out = _cell_path(args.out, args.mesh, arch, shp, args.tag)
            if os.path.exists(out) and not args.force:
                print(f"skip (exists): {out}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shp, "--mesh", args.mesh,
                   "--moe-mode", args.moe_mode, "--grad-sync",
                   args.grad_sync, "--out", args.out]
            if args.tag:
                cmd += ["--tag", args.tag]
            print(">>", " ".join(cmd), flush=True)
            rc = subprocess.run(cmd).returncode
            if rc != 0:
                failures.append((arch, shp))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    res = run_cell(args.arch, args.shape, args.mesh, moe_mode=args.moe_mode,
                   grad_sync=args.grad_sync, microbatches=args.microbatches,
                   remat=args.remat, capacity_factor=args.cf,
                   ssd_chunk=args.ssd_chunk, ssd_bf16=args.ssd_bf16,
                   mla_absorb=args.mla_absorb,
                   compress_dcn=args.compress_dcn)
    out = _cell_path(args.out, args.mesh, args.arch, args.shape, args.tag)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    r = res["roofline"]
    print(f"{args.arch} {args.shape} [{args.mesh}] compile="
          f"{res['compile_s']}s dominant={r['dominant']} "
          f"step={r['step_time_s']:.4f}s frac={r['roofline_fraction']:.3f} "
          f"peak_mem={res['memory']['peak_est_bytes']/2**30:.2f}GiB")


def _cell_path(out, mesh, arch, shape, tag=""):
    suffix = f"__{tag}" if tag else ""
    return os.path.join(out, mesh, f"{arch}__{shape}{suffix}.json")


if __name__ == "__main__":
    main()
