"""Serving launcher: ``python -m repro.launch.serve --arch <id> ...``

Batched prefill + autoregressive decode with the KV/state cache; reduced
config on CPU (``--smoke``, default); production shapes are exercised by
the dry-run (decode_32k / long_500k cells).
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.common import init_params
    from repro.serving import ServeConfig, make_decode_step

    cfg = get_config(args.arch, smoke=args.smoke)
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only (no decode step)")
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    B = args.batch
    max_seq = args.prompt_len + args.tokens
    cache = jax.tree.map(
        jnp.zeros_like,
        init_params(lm.cache_defs(cfg, B, max_seq), jax.random.key(1)))
    serve_step = jax.jit(make_decode_step(cfg, ServeConfig()))
    prompts = jax.random.randint(jax.random.key(2), (B, args.prompt_len),
                                 0, cfg.vocab_size)
    nxt = prompts[:, 0]
    t0 = time.time()
    for t in range(max_seq - 1):
        tok = prompts[:, t:t + 1] if t < args.prompt_len else nxt[:, None]
        cache, nxt, _ = serve_step(params, cache,
                                   {"tokens": tok, "pos": jnp.int32(t)})
    jax.block_until_ready(nxt)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={B} {max_seq - 1} steps in {dt:.2f}s "
          f"({(max_seq - 1) * B / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
