"""Trip-count-aware analysis of compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts ``while`` bodies ONCE, but our
models scan over layers (and microbatches), so the dominant work lives
inside loops. This module re-derives roofline inputs from the HLO text:

  * ``flops``            — 2·(result)·(contraction) per ``dot``, × loop trips
  * ``bytes``            — operand+result bytes of every top-level
                           instruction at fusion granularity, × loop trips
  * ``collectives``      — per (op kind): bytes moved (max of operand/result
                           sizes), × loop trips, classified ICI vs DCN by
                           whether the replica groups span pods.

Everything is **per device** (the HLO module is one SPMD partition).
Validated in tests against known trip counts and analytic model FLOPs.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * DTYPE_BYTES.get(dtype, 4)


_OPCODE_RE = re.compile(r"([\w\-]+)\(")


def _split_defn(defn: str):
    """Return (result_shapes, opcode, operand_names) for one instruction.

    HLO instruction text: ``<result-type> <opcode>(<operands>), attrs...``
    where result-type may be a tuple. The opcode is the first
    ``word(``-token, which cannot occur inside a type.
    """
    m = _OPCODE_RE.search(defn)
    if not m:
        return _SHAPE_RE.findall(defn), "", []
    opcode = m.group(1)
    head = defn[: m.start(1)]
    shapes = _SHAPE_RE.findall(head)
    # operands: balanced-paren region right after "opcode("
    start = m.end(0)
    depth = 0
    end = len(defn)
    for i in range(start, len(defn)):
        ch = defn[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                end = i
                break
            depth -= 1
    opnames = _OPND_RE.findall(defn[start:end])
    return shapes, opcode, opnames


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    defn: str
    result_bytes: int
    operand_names: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s+.*\{\s*$")


def parse_module(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    """Split HLO text into computations keyed by name. Returns (comps, entry)."""
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//"):
            continue
        mh = _HEADER_RE.match(s)
        if mh:
            cur = Computation(mh.group(2), [])
            comps[cur.name] = cur
            if mh.group(1):
                entry = cur.name
            continue
        if s == "}" or cur is None:
            continue
        md = _DEF_RE.match(s)
        if not md:
            continue
        name, defn = md.groups()
        shapes, opcode, opnames = _split_defn(defn)
        rbytes = sum(_shape_bytes(d, s_) for d, s_ in shapes)
        cur.instructions.append(
            Instruction(name, opcode, defn, rbytes, opnames))
    return comps, entry


def _build_shape_table(comps) -> Dict[str, int]:
    table: Dict[str, int] = {}
    for c in comps.values():
        for ins in c.instructions:
            table[ins.name] = ins.result_bytes
    return table


def _trip_count(comps, cond_name: str) -> int:
    """Trip count from the loop condition: the constant operand of the
    ``compare`` instruction (induction variable vs limit)."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts: Dict[str, int] = {}
    for ins in cond.instructions:
        m = re.search(r"constant\((\d+)\)", ins.defn)
        if m and ins.opcode == "constant":
            consts[ins.name] = int(m.group(1))
    trips = []
    for ins in cond.instructions:
        if ins.opcode != "compare":
            continue
        for op in ins.operand_names:
            if op in consts:
                trips.append(consts[op])
    if trips:
        return max(trips)
    # fallback: any constant in the condition
    return max(consts.values()) if consts else 1


def _dot_flops(ins: Instruction, shapes_dims: Dict[str, Tuple[str, str]]
               ) -> float:
    """2 · prod(result dims) · prod(lhs contracting dims)."""
    res, _, _ = _split_defn(ins.defn)
    out_elems = 1
    for _, dims in res:
        for d in (dims.split(",") if dims else []):
            out_elems *= int(d)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.defn)
    if not m or not ins.operand_names:
        return 2.0 * out_elems  # fallback
    lhs = shapes_dims.get(ins.operand_names[0])
    if lhs is None:
        return 2.0 * out_elems
    dims = [int(x) for x in lhs[1].split(",")] if lhs[1] else []
    k = 1
    for ci in (int(x) for x in m.group(1).split(",") if x):
        if ci < len(dims):
            k *= dims[ci]
    return 2.0 * out_elems * k


# --- replica group parsing (iota and explicit forms) -----------------------

def parse_replica_groups(defn: str, num_devices: int
                         ) -> Optional[List[List[int]]]:
    m = re.search(r"replica_groups=\{\{([^=]*?)\}\}", defn)
    if m:
        return [[int(x) for x in g.split(",") if x.strip()]
                for g in m.group(1).split("},{")]
    m = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?",
        defn)
    if m:
        G, N = int(m.group(1)), int(m.group(2))
        rdims = [int(x) for x in m.group(3).split(",")]
        ids = list(range(math.prod(rdims)))
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            # reshape to rdims, transpose by perm, flatten
            import numpy as np
            ids = list(np.arange(math.prod(rdims)).reshape(rdims)
                       .transpose(perm).reshape(-1))
        return [[int(ids[g * N + i]) for i in range(N)] for g in range(G)]
    return None


def _crosses_pod(groups: Optional[List[List[int]]],
                 devices_per_pod: int) -> bool:
    if not groups:
        return False
    for g in groups:
        pods = {d // devices_per_pod for d in g}
        if len(pods) > 1:
            return True
    return False


# --- main accounting --------------------------------------------------------

@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0          # total payload moved, per device
    dcn_collective_bytes: float = 0.0      # subset whose groups span pods
    collective_by_op: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: int = 0

    def merge_scaled(self, other: "HloStats", k: float):
        self.flops += other.flops * k
        self.bytes_accessed += other.bytes_accessed * k
        self.collective_bytes += other.collective_bytes * k
        self.dcn_collective_bytes += other.dcn_collective_bytes * k
        self.collective_count += int(other.collective_count * k)
        for op, b in other.collective_by_op.items():
            self.collective_by_op[op] = \
                self.collective_by_op.get(op, 0.0) + b * k


def analyze(hlo: str, *, num_devices: int = 1, devices_per_pod: int = 0
            ) -> HloStats:
    comps, entry = parse_module(hlo)
    shape_bytes = _build_shape_table(comps)
    # also keep (dtype, dims) for dot flop computation
    shapes_dims: Dict[str, Tuple[str, str]] = {}
    for c in comps.values():
        for ins in c.instructions:
            res, _, _ = _split_defn(ins.defn)
            if res:
                shapes_dims[ins.name] = res[0]
    dpp = devices_per_pod or num_devices

    if entry is None:
        # fallback: computation not referenced as body/cond/calls/to_apply
        referenced = set()
        for c in comps.values():
            for ins in c.instructions:
                for key in ("body=", "condition=", "calls=", "to_apply="):
                    for m in re.finditer(key + r"%?([\w\.\-]+)", ins.defn):
                        referenced.add(m.group(1))
        roots = [n for n in comps if n not in referenced]
        entry = roots[-1] if roots else list(comps)[-1]

    memo: Dict[str, HloStats] = {}

    def walk(comp_name: str) -> HloStats:
        if comp_name in memo:
            return memo[comp_name]
        stats = HloStats()
        comp = comps.get(comp_name)
        if comp is None:
            memo[comp_name] = stats
            return stats
        memo[comp_name] = stats  # guard against cycles
        for ins in comp.instructions:
            if ins.opcode == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", ins.defn)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.defn)
                trips = _trip_count(comps, mc.group(1)) if mc else 1
                if mb:
                    stats.merge_scaled(walk(mb.group(1)), trips)
                continue
            if ins.opcode in ("call", "conditional", "async-start"):
                for m in re.finditer(
                        r"(?:to_apply|calls|branch_computations=\{|true_computation|false_computation)=?%?([\w\.\-]+)",
                        ins.defn):
                    stats.merge_scaled(walk(m.group(1)), 1.0)
                # fall through to count the call's own bytes too
            opnd_bytes = sum(shape_bytes.get(n, 0)
                             for n in ins.operand_names)
            io_bytes = ins.result_bytes + opnd_bytes
            if ins.opcode not in ("parameter", "constant",
                                  "get-tuple-element", "tuple", "bitcast"):
                stats.bytes_accessed += io_bytes
            if ins.opcode == "dot":
                stats.flops += _dot_flops(ins, shapes_dims)
            elif ins.opcode == "convolution":
                stats.flops += 2.0 * ins.result_bytes  # rough fallback
            if ins.opcode in COLLECTIVE_OPS or any(
                    ins.opcode.startswith(c + "-start")
                    for c in COLLECTIVE_OPS):
                base_op = ins.opcode.replace("-start", "")
                moved = max(ins.result_bytes, opnd_bytes)
                stats.collective_bytes += moved
                stats.collective_count += 1
                stats.collective_by_op[base_op] = \
                    stats.collective_by_op.get(base_op, 0.0) + moved
                groups = parse_replica_groups(ins.defn, num_devices)
                if devices_per_pod and _crosses_pod(groups, dpp):
                    stats.dcn_collective_bytes += moved
        return stats

    total = HloStats()
    total.merge_scaled(walk(entry), 1.0)
    return total
