"""Launch-layer public surface: mesh builders for examples and tests."""

from repro.launch.mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
