"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The production target is TPU v5e-class:
one pod = 256 chips as a (data=16, model=16) mesh; multi-pod adds a
leading "pod" axis (2 pods = 512 chips for the dry-run; the axis is what
scales to 1000+ nodes — nothing in the framework assumes pod == 2).
"""

from __future__ import annotations

from repro import jaxcompat


def _mesh(shape, axes):
    return jaxcompat.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_test_mesh(*, devices: int = 8):
    """Small mesh over host devices for unit/integration tests.

    8 devices -> (pod=2, data=2, model=2): every axis is non-trivial so the
    hierarchical shuffle paths are fully exercised.
    """
    if devices == 8:
        return _mesh((2, 2, 2), ("pod", "data", "model"))
    if devices == 4:
        return _mesh((2, 2), ("data", "model"))
    return _mesh((devices,), ("data",))
