"""deepseek-v2-lite-16b [moe]: 27L d=2048 16H d_ff(expert)=1408 vocab=102400.

MLA (kv_lora=512, qk_nope=128, qk_rope=64, v=128); MoE with 2 shared +
64 routed experts, top-6, first layer dense (d_ff 10944) [arXiv:2405.04434].

NOTE: the assignment note says "160 routed" which is DeepSeek-V2-236B's
count; the header says "MoE 64e top-6" which matches the real v2-lite. We
follow the header (64 routed) — see DESIGN.md §4.

This is the paper technique's primary arch: the EP token dispatch IS the
repartitioning that BlobShuffle optimizes (shuffle.mode = direct | blob).
"""

from repro.models.common import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    kind="decoder",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408, num_shared=2,
                  first_dense_layers=1, dense_d_ff=10944),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    source="arXiv:2405.04434",
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    kind="decoder",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=96, num_shared=2,
                  first_dense_layers=1, dense_d_ff=128),
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
)
