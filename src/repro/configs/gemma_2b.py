"""gemma-2b [dense]: 18L d=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU, head_dim=256, tied + sqrt(d)-scaled embeddings [arXiv:2403.08295]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    kind="decoder",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
    source="arXiv:2403.08295",
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    mlp="geglu",
    tie_embeddings=True,
    embed_scale=True,
)
