"""hubert-xlarge [audio]: 48L d=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only, same arch as wav2vec2 [arXiv:2106.07447]. The audio frontend
(conv feature extractor) is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings of size d_model.
"""

from repro.models.common import ModelConfig, MultimodalConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    kind="encoder",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    mlp="gelu",
    qkv_bias=True,
    causal=False,
    multimodal=MultimodalConfig(kind="audio"),
    source="arXiv:2106.07447",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke",
    kind="encoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    mlp="gelu",
    qkv_bias=True,
    causal=False,
    multimodal=MultimodalConfig(kind="audio"),
)
