"""starcoder2-3b [dense]: 30L d=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.

GQA + RoPE [arXiv:2402.19173; hf]. GELU MLP with bias; full attention here
(the real model's sliding window is orthogonal to the shuffle technique —
see DESIGN.md)."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    kind="decoder",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    mlp="gelu",
    qkv_bias=True,
    rope_theta=100000.0,
    source="arXiv:2402.19173",
)

SMOKE = ModelConfig(
    name="starcoder2-3b-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    mlp="gelu",
    qkv_bias=True,
)
