"""llava-next-34b [vlm]: 60L d=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

Anyres tiling [hf:llava-hf/llava-v1.6-*]. The assignment specifies the
transformer BACKBONE only; the vision frontend is a STUB — ``input_specs``
provides precomputed patch embeddings (anyres: 2880 patches/example),
already projected to d_model, which are prepended to the token embeddings.
"""

from repro.models.common import ModelConfig, MultimodalConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    kind="decoder",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
    multimodal=MultimodalConfig(kind="vision", num_patches=2880),
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (34b variant)",
)

SMOKE = ModelConfig(
    name="llava-next-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    multimodal=MultimodalConfig(kind="vision", num_patches=16),
)
