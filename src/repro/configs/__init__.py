"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``.

Ten assigned architectures (see DESIGN.md §4), each with its exact
published config and a reduced SMOKE variant of the same family.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.common import (ALL_SHAPES, ModelConfig, ShapeConfig,
                                 applicable_shapes, skipped_shapes)

ARCH_MODULES: Dict[str, str] = {
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "gemma-2b": "repro.configs.gemma_2b",
    "qwen2-72b": "repro.configs.qwen2_72b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
}

ARCH_IDS: List[str] = list(ARCH_MODULES)


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(ARCH_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def all_cells():
    """Every applicable (arch, shape) dry-run cell."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            yield arch, shape.name


def all_skips():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for name, why in skipped_shapes(cfg):
            yield arch, name, why
