"""qwen2-72b [dense]: 80L d=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

GQA with QKV bias [arXiv:2407.10671; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    kind="decoder",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    source="arXiv:2407.10671",
)

SMOKE = ModelConfig(
    name="qwen2-72b-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    qkv_bias=True,
)
