"""granite-3-2b [dense]: 40L d=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

GQA [hf:ibm-granite/granite-3.0-2b-base]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    kind="decoder",
    num_layers=40,
    d_model=2048,
    num_heads=32,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

SMOKE = ModelConfig(
    name="granite-3-2b-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    tie_embeddings=True,
)
