"""qwen2-moe-a2.7b [moe]: 24L d=2048 16H (kv=16) d_ff=1408 vocab=151936.

4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B]. Second
primary arch for the BlobShuffle EP dispatch."""

from repro.models.common import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    kind="decoder",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_expert=1408, num_shared=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke",
    kind="decoder",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=128,
    qkv_bias=True,
    moe=MoEConfig(num_experts=6, top_k=2, d_expert=96, num_shared=2),
)
