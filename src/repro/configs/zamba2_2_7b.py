"""zamba2-2.7b [hybrid]: 54L d=2560 32H (kv=32) d_ff=10240 vocab=32000.

Mamba2 backbone (ssm_state=64) + shared attention block invoked every 6
layers, fed concat(hidden, initial-embedding) [arXiv:2411.15242]."""

from repro.models.common import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    kind="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, headdim=64, ngroups=1,
                  chunk=256),
    hybrid=HybridConfig(shared_block_every=6, concat_embed=True),
    source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    kind="hybrid",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=32, ngroups=1,
                  chunk=32),
    hybrid=HybridConfig(shared_block_every=2, concat_embed=True),
)
