"""mamba2-130m [ssm]: 24L d=768, attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality) [arXiv:2405.21060]. d_inner = 2*768 = 1536,
headdim 64 -> 24 SSD heads.
"""

from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    kind="ssm",
    num_layers=24,
    d_model=768,
    num_heads=24,
    num_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, headdim=64, ngroups=1,
                  chunk=256),
    source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    kind="ssm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, headdim=32, ngroups=1,
                  chunk=32),
)
