"""Version bridge for the jax sharding API.

The model stack is written against the current jax surface
(``jax.shard_map``, ``jax.sharding.AxisType``,
``jax.sharding.get_abstract_mesh``); older installs (0.4.x) ship the
same machinery under ``jax.experimental.shard_map`` with renamed
knobs (``check_rep``/``auto`` instead of ``check_vma``/``axis_names``)
and no ambient-mesh context at all. Every call site goes through this
module so the difference lives in exactly one place:

* ``shard_map`` — translates ``check_vma`` -> ``check_rep`` and
  ``axis_names={manual}`` -> ``auto=frozenset(mesh) - manual`` on old
  jax; passes through verbatim on new jax.
* ``get_abstract_mesh`` — the ambient (context) mesh, or ``None`` when
  the install has no such concept. Callers already treat ``None`` as
  "no context": e.g. ``shuffle.api`` resolves an empty EP domain and
  falls back to the dense MoE path inside pod-manual regions, which is
  exactly the right degradation when nested partial-manual regions
  are unavailable.
* ``manual_axis_names`` — the Manual axes of a context mesh (empty set
  when ``AxisType`` does not exist).
* ``make_mesh`` — ``jax.make_mesh`` with explicit Auto axis types when
  the install supports them.
* ``cost_analysis`` — normalizes ``Compiled.cost_analysis()`` to one
  flat dict (0.4.x returns a one-element list of dicts).
"""

from __future__ import annotations

import jax

#: True when this install has the current ``jax.shard_map`` API.
NEW_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
              axis_names=None):
    if NEW_SHARD_MAP:
        kw = {}
        if check_vma is not None:
            kw["check_vma"] = check_vma
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    # check_rep is a static verifier with known false positives around
    # partial-auto regions on old jax; the new default is also lax, so
    # only enable it when the caller asked for the check explicitly.
    kw = {"check_rep": bool(check_vma)}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - set(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def get_abstract_mesh():
    """Ambient mesh of the enclosing shard_map region, else ``None``."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    mesh = fn()
    # new jax returns an empty AbstractMesh outside any region
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return mesh


def manual_axis_names(mesh) -> set:
    """Names of the mesh axes that are Manual (shard_map'd) in ``mesh``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if mesh is None or axis_type is None:
        return set()
    return {n for n, t in zip(mesh.axis_names, mesh.axis_types)
            if t == axis_type.Manual}


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def make_mesh(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))
