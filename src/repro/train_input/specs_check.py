"""Dryrun lane: validate the shuffle-fed batch against the sharded specs.

Three layers, cheapest first (mirroring ``launch/dryrun.py``'s
lower-and-inspect harness, scoped to the input pipeline):

* ``input_spec_report`` — from ``launch.specs.input_specs`` +
  ``distributed.sharding`` rules alone: each input's global shape,
  dtype, PartitionSpec, and per-device shard shape (with the
  divisibility proof that the spec actually tiles the mesh);
* ``validate_device_batch`` — a batch the pipeline actually produced:
  every array must match the spec's shape/dtype and carry a sharding
  equivalent to the rules' NamedSharding, shard-shape checked against
  the report;
* ``lower_train_step`` — trace/lower the real ``make_train_step`` with
  the sharded batch abstracts (no compile, no devices touched beyond
  metadata): proves the specs are consumable by the actual step.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.distributed.sharding import (DEFAULT_RULES, batch_specs,
                                        partition_spec)
from repro.launch.specs import input_specs
from repro.models.common import ShapeConfig


def _shard_shape(global_shape, pspec, mesh):
    """Per-device shard shape under ``pspec`` (raises on non-divisible —
    ``partition_spec`` should never emit such a spec)."""
    out = []
    for dim, part in zip(global_shape, tuple(pspec) + (None,) * (
            len(global_shape) - len(tuple(pspec)))):
        axes = (part,) if isinstance(part, str) else (part or ())
        n = 1
        for ax in axes:
            n *= mesh.shape[ax]
        if dim % n:
            raise ValueError(f"dim {dim} not divisible by mesh product {n} "
                             f"for spec {pspec}")
        out.append(dim // n)
    return tuple(out)


def input_spec_report(model_cfg, shape: ShapeConfig, mesh,
                      rules=None) -> Dict[str, dict]:
    rules = rules or DEFAULT_RULES
    report = {}
    for name, spec in input_specs(model_cfg, shape).items():
        ps = partition_spec(spec, rules, mesh)
        report[name] = {
            "global_shape": list(spec.shape),
            "dtype": str(spec.dtype.__name__ if hasattr(spec.dtype, "__name__")
                         else spec.dtype),
            "partition_spec": str(ps),
            "per_device_shape": list(_shard_shape(spec.shape, ps, mesh)),
        }
    return report


def validate_device_batch(batch, model_cfg, shape: ShapeConfig, mesh,
                          rules=None) -> Dict[str, dict]:
    """Assert a produced device batch matches the sharded input specs;
    returns the report on success, raises AssertionError on any drift."""
    import jax.numpy as jnp

    rules = rules or DEFAULT_RULES
    specs = input_specs(model_cfg, shape)
    shardings = batch_specs(specs, rules, mesh)
    report = input_spec_report(model_cfg, shape, mesh, rules)
    assert set(batch) == set(specs), \
        f"batch keys {sorted(batch)} != spec keys {sorted(specs)}"
    for name, arr in batch.items():
        spec = specs[name]
        assert tuple(arr.shape) == tuple(spec.shape), \
            f"{name}: shape {arr.shape} != spec {spec.shape}"
        assert arr.dtype == jnp.dtype(spec.dtype), \
            f"{name}: dtype {arr.dtype} != spec {spec.dtype}"
        want = shardings[name]
        assert arr.sharding.is_equivalent_to(want, arr.ndim), \
            f"{name}: sharding {arr.sharding} != {want}"
        got_shard = tuple(arr.addressable_shards[0].data.shape)
        assert got_shard == tuple(report[name]["per_device_shape"]), \
            f"{name}: shard shape {got_shard} != " \
            f"{report[name]['per_device_shape']}"
    return report


def lower_train_step(model_cfg, tcfg, mesh, shape: ShapeConfig,
                     rules=None) -> Optional[str]:
    """Lower (trace, don't compile) the real train step against the
    sharded input abstracts; returns the lowered StableHLO head or
    raises if the specs don't feed the step."""
    import jax
    import jax.numpy as jnp

    from repro.models import lm
    from repro.models.common import abstract_params
    from repro.training.optimizer import adamw_init
    from repro.training.train_step import make_train_step

    rules = rules or DEFAULT_RULES
    params_abs = abstract_params(lm.param_defs(model_cfg))
    opt_abs = jax.eval_shape(adamw_init, params_abs)
    specs = input_specs(model_cfg, shape)
    shardings = batch_specs(specs, rules, mesh)
    batch_abs = {k: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype),
                                         sharding=shardings[k])
                 for k, s in specs.items()}
    step = make_train_step(model_cfg, tcfg, mesh=mesh)
    lowered = jax.jit(step).lower(params_abs, opt_abs, batch_abs)
    return lowered.as_text()[:400]
