"""Shuffle-fed training loop with blob checkpointing and crash/resume.

``train_shuffle_fed`` is the driver that makes the two halves of the
repo one system: an ``AsyncShuffleEngine`` (built fresh and
deterministically by ``engine_factory``) feeds sharded device batches
through ``ShuffleFedInput`` into a real jitted ``make_train_step``;
every ``ckpt_every`` steps the model/optimizer state is checkpointed
through ``BlobCheckpointer`` with the pipeline's committed per-partition
offsets riding in the manifest's ``extra`` — model state and input
progress commit atomically.

Crash/resume contract (the resume-after-AZ-outage scenario in
``benchmarks/train_input.py``):

* ``crash_at_step=s`` raises ``SimulatedCrash`` after step ``s``'s batch
  was fetched but before the step runs — a crash mid-step, with
  uncommitted work in flight;
* a ``resume=True`` run restores the latest manifest, rebuilds the
  engine from the same factory (the virtual-clock replay is
  bit-deterministic), fast-forwards the pipeline past the committed
  prefix, and cross-checks the replayed per-partition offsets against
  the manifest — so the resumed run re-trains exactly the uncommitted
  steps and nothing else;
* records are step-keyed (``train_input.tokens``) and parameters are
  stored as raw bytes, so the resumed loss trajectory is bit-identical
  to an uninterrupted run's.

For a deterministic crash window use a synchronous checkpointer
(``async_upload=False``): with async uploads, a manifest scheduled just
before the crash may or may not become visible — exactly the real-world
ambiguity, but not a reproducible gate.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax

from repro.checkpoint import latest_step
from repro.models import init_params, lm
from repro.train_input.pipeline import ShuffleFedInput
from repro.train_input.tokens import TokenStreamConfig
from repro.training import adamw_init, make_train_step


class SimulatedCrash(RuntimeError):
    """Injected process death mid-step (benchmarks/tests)."""


@dataclasses.dataclass
class ShuffleTrainResult:
    start_step: int              # first step this run trained
    steps: List[int]             # steps actually trained, in order
    losses: List[float]          # float32-exact loss per trained step
    crashed: bool
    offsets_checked: bool        # resume verified offsets vs manifest
    input_stats: Dict[str, float]
    pipeline: ShuffleFedInput
    engine: object


def train_shuffle_fed(model_cfg, tcfg, mesh, stream: TokenStreamConfig, *,
                      steps: int, engine_factory, ckpt=None,
                      ckpt_every: int = 4, resume: bool = False,
                      crash_at_step: Optional[int] = None,
                      step_fn=None, init_seed: int = 0,
                      pipeline_kwargs: Optional[dict] = None
                      ) -> ShuffleTrainResult:
    """Run (or resume) a shuffle-fed training session. See module doc."""
    engine = engine_factory()
    pipeline = ShuffleFedInput(engine, stream, steps=steps, mesh=mesh,
                               model_cfg=model_cfg,
                               **(pipeline_kwargs or {}))
    pipeline.submit()

    params = init_params(lm.param_defs(model_cfg), jax.random.key(init_seed))
    opt = adamw_init(params)
    if step_fn is None:
        step_fn = jax.jit(make_train_step(model_cfg, tcfg, mesh=mesh))

    start, offsets_checked = 0, False
    if resume:
        if ckpt is None:
            raise ValueError("resume=True requires a checkpointer")
        last = latest_step(ckpt.store)
        if last is None:
            raise RuntimeError("resume requested but no committed manifest")
        m = ckpt.manifest(last)
        state = ckpt.restore(last, {"params": params, "opt": opt})
        params, opt = state["params"], state["opt"]
        start = int(m["extra"]["next_step"])
        pipeline.fast_forward(start, m["extra"]["offsets"])
        offsets_checked = True
    elif ckpt is not None:
        # step-0 manifest: a crash before the first periodic checkpoint
        # still restores to a well-defined state
        ckpt.save(0, {"params": params, "opt": opt},
                  extra={"next_step": 0, "offsets": {}})
        ckpt.wait()

    losses: List[float] = []
    trained: List[int] = []
    step_time_s = 0.0
    crashed = False
    try:
        for s in range(start, steps):
            got, batch, _hit = pipeline.next_batch()
            assert got == s, f"pipeline served {got}, trainer at {s}"
            if crash_at_step is not None and s == crash_at_step:
                raise SimulatedCrash(f"injected crash mid-step {s}")
            t0 = time.perf_counter()
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])       # blocks on the step
            step_time_s += time.perf_counter() - t0
            losses.append(loss)
            trained.append(s)
            if ckpt is not None and (s + 1) % ckpt_every == 0:
                pipeline.commit(s + 1)
                ckpt.save(s + 1, {"params": params, "opt": opt},
                          extra={"next_step": s + 1,
                                 "offsets": pipeline.offsets()})
    except SimulatedCrash:
        crashed = True     # process "dies": no final commit, no drain

    if not crashed:
        if ckpt is not None:
            pipeline.commit(steps)
            ckpt.save(steps, {"params": params, "opt": opt},
                      extra={"next_step": steps,
                             "offsets": pipeline.offsets()})
            ckpt.wait()
        pipeline.finish()

    m = engine.metrics
    stats = {
        "records_delivered": m.records_delivered,
        "bytes_delivered": m.bytes_delivered,
        "records_replayed": m.records_replayed,
        "engine_duplicates": m.duplicates_delivered,
        "duplicate_rows_filtered": pipeline.duplicate_rows,
        "skipped_rows": pipeline.skipped_rows,
        "requests": pipeline.requests,
        "prefetch_hits": pipeline.prefetch_hits,
        "overlap_fraction": (pipeline.prefetch_hits / pipeline.requests
                             if pipeline.requests else 0.0),
        "host_wait_s": pipeline.host_wait_s,
        "host_prefetch_s": pipeline.host_prefetch_s,
        "step_time_s": step_time_s,
    }
    return ShuffleTrainResult(start, trained, losses, crashed,
                              offsets_checked, stats, pipeline, engine)
