"""Token-stream ⇄ Record codec for the shuffle-fed training input.

One training step's global batch is ``batch`` records, one per batch
row. Each record carries ``seq_len + 1`` int32 tokens (the LM input is
``value[:-1]``, the labels ``value[1:]``); its 8-byte key encodes
``(step, row)`` little-endian, which both routes it through the
engine's key partitioner and lets the consumer reassemble batches out
of any delivery order.

Generation is **step-keyed and deterministic** (a fresh
``np.random.Generator`` seeded from ``(seed, step)``): a restarted run
re-submits the identical records, which is what makes resume-after-crash
loss trajectories bit-identical to uninterrupted runs.

>>> cfg = TokenStreamConfig(vocab_size=64, batch=2, seq_len=4, seed=0)
>>> rb = step_records(cfg, step=3)
>>> len(rb)
2
>>> step, row, toks = decode_record(rb.record(1))
>>> (step, row, toks.shape, toks.dtype == np.int32)
(3, 1, (5,), True)
>>> rb2 = step_records(cfg, step=3)          # deterministic re-generation
>>> rb2.record(1).value == rb.record(1).value
True
>>> rows = {r: decode_record(rb.record(r))[2] for r in range(2)}
>>> b = assemble_batch(cfg, rows)
>>> sorted(b), b["tokens"].shape, b["labels"].shape
(['labels', 'tokens'], (2, 4), (2, 4))
>>> bool((b["tokens"][1, 1:] == b["labels"][1, :-1]).all())
True
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Dict, Tuple

import numpy as np

from repro.core.recordbatch import RecordBatch
from repro.core.records import Record

_KEY = struct.Struct("<II")      # (step, row) little-endian


@dataclasses.dataclass(frozen=True)
class TokenStreamConfig:
    """Shape + determinism of the synthetic LM token stream."""
    vocab_size: int
    batch: int                   # global batch rows per training step
    seq_len: int                 # model sequence length S
    seed: int = 0

    @property
    def record_value_bytes(self) -> int:
        return (self.seq_len + 1) * 4


def step_tokens(cfg: TokenStreamConfig, step: int) -> np.ndarray:
    """The (batch, seq_len+1) int32 token matrix for ``step`` — the
    ground truth both the producer (``step_records``) and any verifier
    derive from."""
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, int(step)]))
    return rng.integers(0, cfg.vocab_size,
                        (cfg.batch, cfg.seq_len + 1), dtype=np.int32)


def step_records(cfg: TokenStreamConfig, step: int) -> RecordBatch:
    """Encode step ``step`` as a columnar ``RecordBatch`` of ``batch``
    records, ready for ``AsyncShuffleEngine.submit_batch``."""
    toks = step_tokens(cfg, step)
    recs = [Record(key=_KEY.pack(step, row),
                   value=toks[row].tobytes(),
                   timestamp_us=step)
            for row in range(cfg.batch)]
    return RecordBatch.from_records(recs)


def decode_record(rec: Record) -> Tuple[int, int, np.ndarray]:
    """A delivered ``Record`` back to ``(step, row, tokens[S+1])``."""
    step, row = _KEY.unpack(rec.key)
    toks = np.frombuffer(rec.value, dtype=np.int32)
    return step, row, toks


def assemble_batch(cfg: TokenStreamConfig,
                   rows: Dict[int, np.ndarray]) -> Dict[str, np.ndarray]:
    """Rows (``row -> tokens[S+1]``) to the model's train-step batch
    (``tokens``/``labels``, both (batch, seq_len) int32), shifted by one
    position like ``repro.data.lm_batch_stream``."""
    if len(rows) != cfg.batch:
        missing = sorted(set(range(cfg.batch)) - set(rows))
        raise ValueError(f"incomplete batch: missing rows {missing}")
    mat = np.stack([rows[r] for r in range(cfg.batch)])
    return {"tokens": np.ascontiguousarray(mat[:, :-1]),
            "labels": np.ascontiguousarray(mat[:, 1:])}


def reference_batch(cfg: TokenStreamConfig, step: int
                    ) -> Dict[str, np.ndarray]:
    """What the shuffle-fed pipeline MUST produce for ``step`` — derived
    without the engine, used by tests and the resume correctness gate."""
    toks = step_tokens(cfg, step)
    return {"tokens": np.ascontiguousarray(toks[:, :-1]),
            "labels": np.ascontiguousarray(toks[:, 1:])}
