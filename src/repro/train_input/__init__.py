"""BlobShuffle as the training input pipeline (ROADMAP item 5).

``tokens`` encodes step-keyed LM batches as Records, ``pipeline`` drives
the async engine as a double-buffered batch source with committed
offsets, ``specs_check`` validates the sharded input specs, ``loop``
runs the checkpointed train loop with crash/resume. See
``docs/architecture.md`` for the end-to-end data flow.
"""

from repro.train_input.pipeline import ShuffleFedInput
from repro.train_input.tokens import (TokenStreamConfig, assemble_batch,
                                      decode_record, reference_batch,
                                      step_records, step_tokens)

__all__ = [
    "ShuffleFedInput", "TokenStreamConfig", "assemble_batch",
    "decode_record", "reference_batch", "step_records", "step_tokens",
    "SimulatedCrash", "ShuffleTrainResult", "train_shuffle_fed",
    "input_spec_report", "validate_device_batch", "lower_train_step",
]


def __getattr__(name):
    # loop/specs_check pull in jax + the model stack; load them lazily so
    # engine-only consumers of the pipeline stay light
    if name in ("SimulatedCrash", "ShuffleTrainResult",
                "train_shuffle_fed"):
        from repro.train_input import loop
        return getattr(loop, name)
    if name in ("input_spec_report", "validate_device_batch",
                "lower_train_step"):
        from repro.train_input import specs_check
        return getattr(specs_check, name)
    raise AttributeError(name)
