"""``ShuffleFedInput`` — the AsyncShuffleEngine as a training data source.

The adapter closes the loop between the repo's two halves: training
records are submitted to the shuffle engine as columnar
``RecordBatch``es (one per step, spaced on the virtual clock), and the
engine's delivered output (``engine.out[partition]``) is drained through
monotonic per-partition cursors, decoded, and reassembled into the
model's ``tokens``/``labels`` batches — sharded onto the mesh via
``launch.specs.input_specs`` + ``distributed.sharding`` when a mesh is
given.

Three properties the training loop leans on:

* **double-buffering on the virtual clock** — after serving step ``s``
  the pipeline immediately advances the engine until step
  ``s + prefetch_steps`` is fully staged (or the event heap drains), so
  by the time the trainer asks for ``s + 1`` the rows are already
  resident; ``prefetch_hits / requests`` is the step-time overlap
  fraction reported by the benchmark;
* **exactly-once consumption** — every delivered record is identified by
  its ``(step, row)`` key; replays/duplicates the engine's exactly-once
  commit path lets through during failure scenarios are filtered here
  and counted (``duplicate_rows``), so a batch can never contain a row
  twice and a step can never be assembled twice;
* **committed offsets** — ``commit(upto)`` folds the per-partition
  delivery counts of consumed steps into an offsets table that the
  trainer persists inside the checkpoint manifest (atomically with the
  model state). On restart, ``fast_forward`` replays the engine from
  zero, drops exactly the committed prefix, and cross-checks the
  recomputed offsets against the manifest — a restart can neither skip
  nor re-train a batch without tripping this gate.
"""

from __future__ import annotations

import time
from collections import Counter, defaultdict
from typing import Dict, Optional, Set, Tuple

import numpy as np

from repro.train_input.tokens import (TokenStreamConfig, assemble_batch,
                                      decode_record, step_records)


class ShuffleFedInput:
    """Drives an ``AsyncShuffleEngine`` as a step-indexed batch source."""

    def __init__(self, engine, stream: TokenStreamConfig, *,
                 steps: int, prefetch_steps: int = 2,
                 step_interval_s: float = 0.05,
                 time_slice_s: float = 0.05,
                 start_t: float = 0.0,
                 mesh=None, model_cfg=None, rules=None):
        self.engine = engine
        self.stream = stream
        self.steps = steps
        self.prefetch_steps = max(1, prefetch_steps)
        self.step_interval_s = step_interval_s
        self.time_slice_s = time_slice_s
        self.start_t = start_t
        # -- consumption state --------------------------------------------
        self._next = 0              # next step to serve to the trainer
        self._consumed_upto = 0     # steps < this are committed
        self._cursor: Dict[int, int] = defaultdict(int)
        self._staged: Dict[int, Dict[int, np.ndarray]] = {}
        self._seen: Set[Tuple[int, int]] = set()
        self._step_parts: Dict[int, Counter] = {}
        self._offsets: Dict[int, int] = {}
        self._horizon = start_t     # monotonic loop-advance watermark
        # -- counters -------------------------------------------------------
        self.requests = 0
        self.prefetch_hits = 0      # batches already staged when requested
        self.duplicate_rows = 0     # engine replays filtered by (step,row)
        self.late_rows = 0          # rows for already-committed steps
        self.skipped_rows = 0       # committed prefix dropped on resume
        self.host_wait_s = 0.0      # blocking collect time the step sees
        self.host_prefetch_s = 0.0  # overlapped advance time
        self._put = (self._make_device_put(mesh, model_cfg, rules)
                     if mesh is not None else None)

    # -- producer side ------------------------------------------------------
    def submit(self) -> None:
        """Schedule every step's RecordBatch on the virtual clock (an open
        stream arriving one micro-batch per ``step_interval_s``) and arm
        the engine's periodic commit cadence."""
        for s in range(self.steps):
            self.engine.submit_batch(self.start_t + s * self.step_interval_s,
                                     step_records(self.stream, s))
        self.engine.start()

    # -- consumer side ------------------------------------------------------
    def _drain(self) -> None:
        """Fold newly delivered records (past each partition cursor) into
        the staging tables; filter duplicates by ``(step, row)``."""
        for p, lst in self.engine.out.items():
            c = self._cursor[p]
            if c >= len(lst):
                continue
            for rec in lst[c:]:
                step, row, toks = decode_record(rec)
                key = (step, row)
                if key in self._seen:
                    self.duplicate_rows += 1
                    continue
                self._seen.add(key)
                self._step_parts.setdefault(step, Counter())[p] += 1
                if step < self._consumed_upto:
                    self.late_rows += 1
                else:
                    self._staged.setdefault(step, {})[row] = toks
            self._cursor[p] = len(lst)

    def _complete(self, step: int) -> bool:
        return len(self._staged.get(step, ())) == self.stream.batch

    def _advance(self, step: int, strict: bool) -> None:
        """Run the event loop in ``time_slice_s`` increments until
        ``step`` is fully staged. ``strict`` raises if the heap drains
        first (a lost batch); prefetch passes ``strict=False`` and just
        stops at the heap's end."""
        loop = self.engine.loop
        while not self._complete(step):
            if loop.pending() == 0:
                if strict:
                    have = len(self._staged.get(step, ()))
                    raise RuntimeError(
                        f"engine drained before step {step} was delivered "
                        f"({have}/{self.stream.batch} rows staged)")
                return
            self._horizon = max(self._horizon, loop.now) + self.time_slice_s
            loop.run(until=self._horizon)
            self._drain()

    def prefetch(self) -> None:
        """Advance the clock until ``prefetch_steps`` future steps are
        staged — the input runs ahead of training on the virtual clock."""
        t0 = time.perf_counter()
        target = min(self._next + self.prefetch_steps - 1, self.steps - 1)
        for s in range(self._next, target + 1):
            self._advance(s, strict=False)
        self.host_prefetch_s += time.perf_counter() - t0

    def next_batch(self):
        """The next step's batch: ``(step, batch, prefetched)``.

        ``batch`` is ``tokens``/``labels`` numpy (or sharded device
        arrays when the pipeline was built with a mesh); ``prefetched``
        is True when the rows were already staged — the double-buffer
        absorbed the input latency."""
        s = self._next
        if s >= self.steps:
            raise StopIteration(f"stream exhausted at step {self.steps}")
        self.requests += 1
        hit = self._complete(s)
        if hit:
            self.prefetch_hits += 1
        else:
            t0 = time.perf_counter()
            self._advance(s, strict=True)
            self.host_wait_s += time.perf_counter() - t0
        rows = self._staged.pop(s)
        batch = assemble_batch(self.stream, rows)
        self._next = s + 1
        self.prefetch()
        if self._put is not None:
            batch = self._put(batch)
        return s, batch, hit

    # -- commit / resume ----------------------------------------------------
    def commit(self, upto_step: int) -> None:
        """Mark steps ``< upto_step`` consumed: their per-partition
        delivery counts fold into the committed offsets table. Only
        already-served steps can commit."""
        if upto_step > self._next:
            raise ValueError(f"cannot commit step {upto_step}: "
                             f"only {self._next} steps served")
        for s in range(self._consumed_upto, upto_step):
            for p, n in self._step_parts.pop(s, {}).items():
                self._offsets[p] = self._offsets.get(p, 0) + n
        self._consumed_upto = max(self._consumed_upto, upto_step)

    def offsets(self) -> Dict[int, int]:
        """Committed per-partition consumed-record counts (checkpoint
        manifest payload)."""
        return {int(p): int(n) for p, n in sorted(self._offsets.items())}

    def fast_forward(self, resume_step: int,
                     expected_offsets: Optional[Dict] = None) -> None:
        """Resume path: replay the (deterministic) engine from zero,
        consume-and-drop the committed prefix ``[0, resume_step)``, and
        verify the recomputed per-partition offsets against the
        checkpoint manifest's. After this, ``next_batch`` serves
        ``resume_step`` — nothing skipped, nothing re-trained."""
        if self._next != 0:
            raise RuntimeError("fast_forward must run before consumption")
        for s in range(resume_step):
            self._advance(s, strict=True)
            self.skipped_rows += len(self._staged.pop(s))
        self._next = resume_step
        self.commit(resume_step)
        if expected_offsets is not None:
            exp = {int(p): int(n) for p, n in expected_offsets.items()}
            got = self.offsets()
            if got != exp:
                raise RuntimeError(
                    "resume offsets diverged from the committed manifest: "
                    f"manifest={exp} replayed={got}")
        self.prefetch()

    def finish(self):
        """Drain the engine (remaining uploads/commits/retention) and
        return its ``ShuffleMetrics`` — call once training is done."""
        return self.engine.run()

    # -- device batches -----------------------------------------------------
    def _make_device_put(self, mesh, model_cfg, rules):
        import jax

        from repro.distributed.sharding import DEFAULT_RULES, batch_specs
        from repro.launch.specs import input_specs
        from repro.models.common import ShapeConfig

        if model_cfg is None:
            raise ValueError("mesh given without model_cfg")
        self.shape = ShapeConfig("shuffle_fed", self.stream.seq_len,
                                 self.stream.batch, "train")
        self.input_specs = input_specs(model_cfg, self.shape)
        self.shardings = batch_specs(self.input_specs,
                                     rules or DEFAULT_RULES, mesh)

        def put(batch):
            return {k: jax.device_put(v, self.shardings[k])
                    for k, v in batch.items()}
        return put
