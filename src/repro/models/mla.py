"""Multi-head Latent Attention (DeepSeek-V2) with compressed KV cache.

Two decode paths:
  * ``absorb=False`` (naive): expand k_nope/v from the cached latent each step.
  * ``absorb=True``: absorb W_uk into the query and W_uv into the output —
    attention runs directly in the 512-dim latent space. This is the
    beyond-baseline optimized path (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig
from repro.models.layers import rms_norm
from repro.models.rope import apply_rope
from repro.models.attention import dense_attention, attention_op
from repro.models.flash import ShardHints, NO_HINTS

NEG_INF = -1e30


def mla_defs(cfg: ModelConfig, *, stacked: int = 0) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    pd = cfg.param_dtype
    return {
        "wq": ArraySpec(L + (d, H, qk), pd, la + ("embed", "heads", None)),
        "w_dkv": ArraySpec(L + (d, m.kv_lora_rank + m.qk_rope_head_dim), pd,
                           la + ("embed", None)),
        "kv_norm": ArraySpec(L + (m.kv_lora_rank,), jnp.float32,
                             la + (None,), init="zeros"),
        "w_uk": ArraySpec(L + (m.kv_lora_rank, H, m.qk_nope_head_dim), pd,
                          la + (None, "heads", None)),
        "w_uv": ArraySpec(L + (m.kv_lora_rank, H, m.v_head_dim), pd,
                          la + (None, "heads", None)),
        "wo": ArraySpec(L + (H, m.v_head_dim, d), pd,
                        la + ("heads", None, "embed")),
    }


def _project(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    """Common projections. Returns (q_nope, q_rope, c_kv, k_rope)."""
    m = cfg.mla
    cd = cfg.compute_dtype
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cd))
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    dkv = x @ p["w_dkv"].astype(cd)
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:], positions,
                        cfg.rope_theta)  # (B, S, 1, rope_dim)
    return q_nope, q_rope, c_kv, k_rope


def mla_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
              positions: jax.Array, hints: ShardHints = NO_HINTS
              ) -> jax.Array:
    """Full-sequence MLA (train / prefill) via expanded keys/values."""
    m = cfg.mla
    cd = cfg.compute_dtype
    q_nope, q_rope, c_kv, k_rope = _project(cfg, p, x, positions)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uk"].astype(cd))
    v = jnp.einsum("bsr,rhe->bshe", c_kv, p["w_uv"].astype(cd))
    H = cfg.num_heads
    k_rope_b = jnp.broadcast_to(
        k_rope, k_rope.shape[:2] + (H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # v head dim may differ from qk dim; attention_op handles D from q/k only
    out = attention_op(cfg, q, k, _pad_v(v, q.shape[-1]), causal=cfg.causal,
                       hints=hints)
    out = out[..., :m.v_head_dim]
    return jnp.einsum("bshe,hed->bsd", out.astype(cd), p["wo"].astype(cd))


def _pad_v(v: jax.Array, d: int) -> jax.Array:
    """Pad value head-dim up to the qk head-dim (sliced off after)."""
    if v.shape[-1] == d:
        return v
    return jnp.pad(v, ((0, 0),) * (v.ndim - 1) + ((0, d - v.shape[-1]),))


def mla_cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
                   *, stacked: int = 0) -> dict:
    m = cfg.mla
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    return {
        "c_kv": ArraySpec(L + (batch, max_seq, m.kv_lora_rank),
                          cfg.compute_dtype, la + ("batch", "kv_seq", None),
                          init="zeros"),
        "k_rope": ArraySpec(L + (batch, max_seq, m.qk_rope_head_dim),
                            cfg.compute_dtype, la + ("batch", "kv_seq", None),
                            init="zeros"),
    }


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
               pos: jax.Array, *, absorb: bool = False):
    """One-token MLA decode against the compressed latent cache."""
    m = cfg.mla
    cd = cfg.compute_dtype
    H = cfg.num_heads
    q_nope, q_rope, c_new, k_rope_new = _project(cfg, p, x, positions=pos[None])
    c_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1)
    kr_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, :, 0].astype(cache["k_rope"].dtype),
        pos, axis=1)
    new_cache = {"c_kv": c_cache, "k_rope": kr_cache}
    S = c_cache.shape[1]
    kpos = jnp.arange(S)
    valid = kpos < (pos + 1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)

    if absorb:
        # scores = (q_nope W_uk) · c_kv + q_rope · k_rope — all cache-sized
        # contractions accumulate in f32 without casting the cache
        q_abs = jnp.einsum("bqhe,rhe->bqhr", q_nope.astype(cd),
                           p["w_uk"].astype(cd))
        s_nope = jnp.einsum("bqhr,bsr->bhqs", q_abs, c_cache,
                            preferred_element_type=jnp.float32)
        s_rope = jnp.einsum("bqhe,bse->bhqs", q_rope.astype(cd), kr_cache,
                            preferred_element_type=jnp.float32)
        s = (s_nope + s_rope) * scale
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(cd)
        ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c_cache,
                         preferred_element_type=jnp.float32)
        out = jnp.einsum("bqhr,rhe->bqhe", ctx.astype(cd),
                         p["w_uv"].astype(cd),
                         preferred_element_type=jnp.float32)
    else:
        k_nope = jnp.einsum("bsr,rhe->bshe", c_cache.astype(cd),
                            p["w_uk"].astype(cd))
        v = jnp.einsum("bsr,rhe->bshe", c_cache.astype(cd),
                       p["w_uv"].astype(cd))
        k_rope_b = jnp.broadcast_to(
            kr_cache[:, :, None, :].astype(cd),
            (x.shape[0], S, H, m.qk_rope_head_dim))
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
        out = dense_attention(q, k, _pad_v(v, q.shape[-1]), causal=False,
                              kv_len=pos + 1)[..., :m.v_head_dim]
    y = jnp.einsum("bshe,hed->bsd", out.astype(cd), p["wo"].astype(cd))
    return y, new_cache
