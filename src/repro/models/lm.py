"""Model assembly: decoder / encoder / SSM / hybrid LMs with scan-over-layers.

Parameters are stacked along a leading ``layers`` axis so the HLO stays O(1)
in depth (essential for 80-layer dry-runs and 1000-node compile times).

Public surface:
  * ``param_defs(cfg)``                         — ArraySpec tree
  * ``forward(cfg, params, batch, ...)``        — logits + aux (train/prefill)
  * ``cache_defs(cfg, batch, max_seq)``         — decode cache ArraySpec tree
  * ``decode_step(cfg, params, cache, batch)``  — one-token serve step
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.common import ArraySpec, ModelConfig
from repro.models.flash import NO_HINTS, ShardHints
from repro.shuffle.api import ShuffleConfig

DENSE = ShuffleConfig(mode="dense")


# ---------------------------------------------------------------------------
# Block definitions
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig, *, stacked: int = 0) -> dict:
    if cfg.mla is not None:
        return MLA.mla_defs(cfg, stacked=stacked)
    return A.attention_defs(cfg, stacked=stacked)


def _moe_layers(cfg: ModelConfig) -> int:
    if cfg.moe is None:
        return 0
    return cfg.num_layers - cfg.moe.first_dense_layers


def block_defs(cfg: ModelConfig, *, stacked: int, ffn: str) -> dict:
    """One transformer block (attention + FFN). ffn: mlp | moe | dense_moe."""
    out = {"ln1": L.norm_defs(cfg.d_model, stacked=stacked),
           "attn": _attn_defs(cfg, stacked=stacked),
           "ln2": L.norm_defs(cfg.d_model, stacked=stacked)}
    if ffn == "moe":
        out["ffn"] = MOE.moe_defs(cfg, stacked=stacked)
    elif ffn == "dense_moe":  # leading dense layers of a MoE model
        out["ffn"] = L.mlp_defs(cfg, cfg.moe.dense_d_ff, stacked=stacked)
    else:
        out["ffn"] = L.mlp_defs(cfg, cfg.d_ff, stacked=stacked)
    return out


def ssm_block_defs(cfg: ModelConfig, *, stacked: int) -> dict:
    return {"ln": L.norm_defs(cfg.d_model, stacked=stacked),
            "mamba": SSM.mamba2_defs(cfg, stacked=stacked)}


def param_defs(cfg: ModelConfig) -> dict:
    defs: Dict[str, Any] = {"embed": L.embed_defs(cfg)}
    if cfg.kind in ("decoder", "encoder"):
        if cfg.moe is not None and cfg.moe.first_dense_layers:
            defs["dense_blocks"] = block_defs(
                cfg, stacked=cfg.moe.first_dense_layers, ffn="dense_moe")
            defs["blocks"] = block_defs(
                cfg, stacked=_moe_layers(cfg), ffn="moe")
        else:
            defs["blocks"] = block_defs(
                cfg, stacked=cfg.num_layers,
                ffn="moe" if cfg.moe is not None else "mlp")
    elif cfg.kind == "ssm":
        defs["blocks"] = ssm_block_defs(cfg, stacked=cfg.num_layers)
    elif cfg.kind == "hybrid":
        h = cfg.hybrid
        n_inv = cfg.num_layers // h.shared_block_every
        defs["blocks"] = ssm_block_defs(cfg, stacked=cfg.num_layers)
        defs["shared_block"] = block_defs(cfg, stacked=0, ffn="mlp")
        concat_dim = 2 * cfg.d_model if h.concat_embed else cfg.d_model
        defs["shared_in"] = ArraySpec(
            (n_inv, concat_dim, cfg.d_model), cfg.param_dtype,
            ("stack", "embed", None))
    else:
        raise ValueError(cfg.kind)
    defs["final_norm"] = L.norm_defs(cfg.d_model)
    return defs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params, batch) -> jax.Array:
    """Token / multimodal / stub-frontend embedding. Returns (B, S, d)."""
    if cfg.multimodal is not None and cfg.multimodal.kind == "audio":
        # hubert: precomputed frame embeddings from the stub frontend
        x = batch["frames"].astype(cfg.compute_dtype)
        S = x.shape[1]
        pos = _sinusoidal(S, cfg.d_model, x.dtype)
        return x + pos[None]
    tok = L.embed_apply(cfg, params["embed"], batch["tokens"])
    if cfg.multimodal is not None and cfg.multimodal.kind == "vision":
        patches = batch["patches"].astype(cfg.compute_dtype)
        return jnp.concatenate([patches, tok], axis=1)
    return tok


def _sinusoidal(S: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(S, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    out = jnp.zeros((S, d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang[:, : (d // 2)]))
    return out.astype(dtype)


def _attn_apply(cfg, p, x, positions, hints=NO_HINTS):
    if cfg.mla is not None:
        return MLA.mla_apply(cfg, p, x, positions=positions, hints=hints)
    return A.attention_apply(cfg, p, x, positions=positions, hints=hints)


def _block_apply(cfg, p, x, positions, *, moe: bool, mesh, shuffle,
                 hints=NO_HINTS):
    """Pre-LN transformer block. Returns (x, aux)."""
    h = _attn_apply(cfg, p["attn"],
                    L.rms_norm(x, p["ln1"], cfg.norm_eps), positions,
                    hints=hints)
    x = x + h
    z = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        y, aux, _ = MOE.moe_apply(cfg, p["ffn"], z, shuffle=shuffle,
                                  mesh=mesh)
    else:
        y, aux = L.mlp_apply(cfg, p["ffn"], z), jnp.zeros((), jnp.float32)
    return x + y, aux


def _ssm_block_apply(cfg, p, x):
    return x + SSM.mamba2_apply(cfg, p["mamba"],
                                L.rms_norm(x, p["ln"], cfg.norm_eps))


def _squeeze0(tree):
    return jax.tree.map(lambda a: a[0], tree)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)  # "full": save only layer boundaries


def forward(cfg: ModelConfig, params, batch, *, mesh=None,
            shuffle: ShuffleConfig = DENSE, remat: str = "none",
            hints: ShardHints = NO_HINTS) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits (B, S, V), aux_loss).

    ``hints.residual`` shards the residual stream at every block boundary
    (sequence parallelism — shards the remat-saved activations over the
    "model" axis); ``hints.qblocks`` shards flash-attention q blocks
    (context parallelism for archs whose heads don't divide the TP axis).
    """
    c = hints.res
    x = c(_embed_inputs(cfg, params, batch))
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.kind in ("decoder", "encoder"):
        if "dense_blocks" in params:
            def dense_body(x, p):
                x, aux = _block_apply(cfg, p, x, positions, moe=False,
                                      mesh=mesh, shuffle=shuffle,
                                      hints=hints)
                return c(x), aux
            if cfg.moe.first_dense_layers == 1:
                # size-1 scans trigger degenerate GSPMD reshards — inline
                x, aux = _remat(dense_body, remat)(
                    x, _squeeze0(params["dense_blocks"]))
                aux_total += aux
            else:
                x, auxs = jax.lax.scan(_remat(dense_body, remat), x,
                                       params["dense_blocks"])
                aux_total += jnp.sum(auxs)

        moe = cfg.moe is not None

        def body(x, p):
            x, aux = _block_apply(cfg, p, x, positions, moe=moe,
                                  mesh=mesh, shuffle=shuffle, hints=hints)
            return c(x), aux
        x, auxs = jax.lax.scan(_remat(body, remat), x, params["blocks"])
        aux_total += jnp.sum(auxs)

    elif cfg.kind == "ssm":
        def body(x, p):
            return c(_ssm_block_apply(cfg, p, x)), None
        x, _ = jax.lax.scan(_remat(body, remat), x, params["blocks"])

    elif cfg.kind == "hybrid":
        h = cfg.hybrid
        k = h.shared_block_every
        n_inv = cfg.num_layers // k
        x0 = x  # initial embedding, re-fed to every shared-block call
        blocks = jax.tree.map(
            lambda a: a.reshape((n_inv, k) + a.shape[1:]), params["blocks"])

        def group_body(x, xs):
            p_group, w_in = xs

            def inner(x, p):
                return _ssm_block_apply(cfg, p, x), None
            x, _ = jax.lax.scan(inner, x, p_group)
            inp = jnp.concatenate([x, x0], axis=-1) if h.concat_embed else x
            z = inp.astype(cfg.compute_dtype) @ w_in.astype(cfg.compute_dtype)
            y, _ = _block_apply(cfg, params["shared_block"], z, positions,
                                moe=False, mesh=mesh, shuffle=shuffle,
                                hints=hints)
            return c(x + y - z), None  # residual contribution of shared block

        x, _ = jax.lax.scan(_remat(group_body, remat), x,
                            (blocks, params["shared_in"]))

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(cfg, params["embed"], x)
    return logits, aux_total


# ---------------------------------------------------------------------------
# Decode (one token with a cache)
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Decode-cache ArraySpec tree (stacked per layer like the params)."""
    if cfg.kind == "decoder":
        n_dense = cfg.moe.first_dense_layers if cfg.moe else 0
        mk = (MLA.mla_cache_defs if cfg.mla is not None
              else A.attention_cache_defs)
        out = {"blocks": mk(cfg, batch, max_seq,
                            stacked=cfg.num_layers - n_dense)}
        if n_dense:
            out["dense_blocks"] = mk(cfg, batch, max_seq, stacked=n_dense)
        return out
    if cfg.kind == "ssm":
        return {"blocks": SSM.mamba2_cache_defs(
            cfg, batch, stacked=cfg.num_layers)}
    if cfg.kind == "hybrid":
        n_inv = cfg.num_layers // cfg.hybrid.shared_block_every
        return {"blocks": SSM.mamba2_cache_defs(
                    cfg, batch, stacked=cfg.num_layers),
                "shared": A.attention_cache_defs(
                    cfg, batch, max_seq, stacked=n_inv)}
    raise ValueError(f"{cfg.kind} has no decode step")


def _attn_decode(cfg, p, x, cache, pos):
    if cfg.mla is not None:
        return MLA.mla_decode(cfg, p, x, cache, pos)
    return A.attention_decode(cfg, p, x, cache, pos)


def _block_decode(cfg, p, x, cache, pos, *, moe, mesh, shuffle):
    h, new_cache = _attn_decode(cfg, p["attn"],
                                L.rms_norm(x, p["ln1"], cfg.norm_eps),
                                cache, pos)
    x = x + h
    z = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if moe:
        y, _, _ = MOE.moe_apply(cfg, p["ffn"], z, shuffle=shuffle, mesh=mesh)
    else:
        y = L.mlp_apply(cfg, p["ffn"], z)
    return x + y, new_cache


def decode_step(cfg: ModelConfig, params, cache, batch, *, mesh=None,
                shuffle: ShuffleConfig = DENSE):
    """One-token decode. batch: {"tokens": (B, 1), "pos": scalar int32}.

    Returns (logits (B, 1, V), new_cache).
    """
    pos = batch["pos"]
    x = L.embed_apply(cfg, params["embed"], batch["tokens"])

    if cfg.kind == "decoder":
        if "dense_blocks" in params:
            def dense_body(x, xs):
                p, c = xs
                x, nc = _block_decode(cfg, p, x, c, pos, moe=False,
                                      mesh=mesh, shuffle=shuffle)
                return x, nc
            if cfg.moe.first_dense_layers == 1:
                x, nc1 = dense_body(x, (_squeeze0(params["dense_blocks"]),
                                        _squeeze0(cache["dense_blocks"])))
                ncache_d = jax.tree.map(lambda a: a[None], nc1)
            else:
                x, ncache_d = jax.lax.scan(
                    dense_body, x, (params["dense_blocks"],
                                    cache["dense_blocks"]))
        moe = cfg.moe is not None

        def body(x, xs):
            p, c = xs
            x, nc = _block_decode(cfg, p, x, c, pos, moe=moe, mesh=mesh,
                                  shuffle=shuffle)
            return x, nc
        x, ncache = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
        new_cache = {"blocks": ncache}
        if "dense_blocks" in params:
            new_cache["dense_blocks"] = ncache_d

    elif cfg.kind == "ssm":
        def body(x, xs):
            p, c = xs
            h, nc = SSM.mamba2_decode(
                cfg, p["mamba"], L.rms_norm(x, p["ln"], cfg.norm_eps), c, pos)
            return x + h, nc
        x, ncache = jax.lax.scan(body, x, (params["blocks"],
                                           cache["blocks"]))
        new_cache = {"blocks": ncache}

    elif cfg.kind == "hybrid":
        h = cfg.hybrid
        k = h.shared_block_every
        n_inv = cfg.num_layers // k
        x0 = x
        blocks = jax.tree.map(
            lambda a: a.reshape((n_inv, k) + a.shape[1:]), params["blocks"])
        caches = jax.tree.map(
            lambda a: a.reshape((n_inv, k) + a.shape[1:]), cache["blocks"])

        def group_body(x, xs):
            p_group, c_group, w_in, attn_c = xs

            def inner(x, pc):
                p, c = pc
                y, nc = SSM.mamba2_decode(
                    cfg, p["mamba"], L.rms_norm(x, p["ln"], cfg.norm_eps),
                    c, pos)
                return x + y, nc
            x, nc_group = jax.lax.scan(inner, x, (p_group, c_group))
            inp = jnp.concatenate([x, x0], axis=-1) if h.concat_embed else x
            z = inp.astype(cfg.compute_dtype) @ w_in.astype(cfg.compute_dtype)
            sb = params["shared_block"]
            y, n_attn_c = _block_decode(cfg, sb, z, attn_c, pos, moe=False,
                                        mesh=mesh, shuffle=shuffle)
            return x + y - z, (nc_group, n_attn_c)

        x, (nc, n_shared) = jax.lax.scan(
            group_body, x, (blocks, caches, params["shared_in"],
                            cache["shared"]))
        new_cache = {
            "blocks": jax.tree.map(
                lambda a: a.reshape((cfg.num_layers,) + a.shape[2:]), nc),
            "shared": n_shared,
        }

    else:
        raise ValueError(f"{cfg.kind} has no decode step")

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed_apply(cfg, params["embed"], x)
    return logits, new_cache
