"""Rotary position embeddings (llama-style rotate-half convention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim//2,), float32."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """cos/sin tables for integer positions (…,) -> (…, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    angles = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Uses the rotate-half convention: pairs are (x[: d/2], x[d/2 :]).
    """
    head_dim = x.shape[-1]
    cos, sin = rope_cos_sin(positions, head_dim, theta)  # (..., seq, d/2)
    cos = cos[..., None, :]  # add heads axis
    sin = sin[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
