"""Flash attention (jnp) with a custom VJP — the memory-correct oracle.

Design (matches the Pallas kernel in repro.kernels.flash_attention):
  * q is reshaped to (B, nq, qc, H, D) blocks and processed *vectorized*
    (no loop over q blocks) so the nq dim can be sharded over the "model"
    mesh axis — context parallelism for archs whose head count does not
    divide the TP axis (starcoder2: 24H, llava: 56H, gemma MQA: 8H).
  * the kv loop is a lax.scan with running (acc, m, l) — O(S·kv_chunk)
    memory, never O(S²).
  * custom_vjp: backward recomputes block scores (flash-2 style) instead
    of saving probabilities — without this, scan-transpose stacks the full
    probability tensor per layer (observed 46 GB/layer on starcoder2-3b).

``hints.qblocks`` lets callers install a sharding constraint on the
blocked-q layout at every flash call site.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ShardHints:
    """Sharding-constraint hooks threaded through the model."""
    residual: Optional[Callable] = None   # (B, S, d)
    qblocks: Optional[Callable] = None    # (B, nq, qc, H, D)

    def res(self, x):
        return self.residual(x) if self.residual is not None else x

    def qb(self, x):
        return self.qblocks(x) if self.qblocks is not None else x


NO_HINTS = ShardHints()


def _expand(k, G):
    return jnp.repeat(k, G, axis=2) if G > 1 else k


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, q_chunk, kv_chunk, q_offset, hints):
    out, _ = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset,
                             hints)
    return out


def _blocked(q, q_chunk):
    B, Sq, H, D = q.shape
    pad = (-Sq) % q_chunk
    qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = qp.shape[1] // q_chunk
    return qp.reshape(B, nq, q_chunk, H, D), nq, pad


def _kv_blocked(k, kv_chunk):
    B, Skv, KVH, D = k.shape
    pad = (-Skv) % kv_chunk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkv = kp.shape[1] // kv_chunk
    # scan-major layout: (nkv, B, kv_chunk, KVH, D)
    return jnp.moveaxis(kp.reshape(B, nkv, kv_chunk, KVH, D), 1, 0), nkv, pad


def _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset, hints):
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)

    qb, nq, qpad = _blocked(q, q_chunk)
    qb = hints.qb(qb)
    kb, nkv, kvpad = _kv_blocked(k, kv_chunk)
    vb, _, _ = _kv_blocked(v, kv_chunk)
    qb32 = qb.astype(jnp.float32)
    qpos = (jnp.arange(nq * q_chunk) + q_offset).reshape(nq, q_chunk)

    def step(carry, inp):
        acc, m, l = carry
        ki, kblk, vblk = inp
        kblk = _expand(kblk, G).astype(jnp.float32)
        vblk = _expand(vblk, G).astype(jnp.float32)
        s = jnp.einsum("bnqhd,bkhd->bnhqk", qb32,
                       jnp.moveaxis(kblk, 0, 0)) * scale
        kpos = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = kpos[None, None, :] < Skv
        if causal:
            mask = mask & (qpos[:, :, None] >= kpos[None, None, :])
        s = jnp.where(mask[None, :, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnhqk,bkhd->bnhqd", p, vblk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, nq, H, q_chunk, D), jnp.float32)
    m0 = jnp.full((B, nq, H, q_chunk), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, H, q_chunk), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0),
                                  (jnp.arange(nkv), kb, vb))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))          # (B, nq, H, qc)
    out_b = acc / jnp.maximum(l[..., None], 1e-30)    # (B, nq, H, qc, D)
    out = jnp.moveaxis(out_b, 2, 3).reshape(B, nq * q_chunk, H, D)
    out = out[:, :Sq].astype(q.dtype)
    return out, lse


def _flash_fwd(q, k, v, causal, q_chunk, kv_chunk, q_offset, hints):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_chunk, kv_chunk, q_offset,
                               hints)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_chunk, kv_chunk, q_offset, hints, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    q_chunk_ = min(q_chunk, Sq)
    kv_chunk_ = min(kv_chunk, Skv)

    qb, nq, _ = _blocked(q, q_chunk_)
    qb = hints.qb(qb)
    dob, _, _ = _blocked(dout.astype(jnp.float32), q_chunk_)
    dob = hints.qb(dob)
    ob, _, _ = _blocked(out.astype(jnp.float32), q_chunk_)
    ob = hints.qb(ob)
    kb, nkv, _ = _kv_blocked(k, kv_chunk_)
    vb, _, _ = _kv_blocked(v, kv_chunk_)
    qb32 = qb.astype(jnp.float32)
    # D_i = rowsum(dout * out)
    delta = jnp.einsum("bnqhd,bnqhd->bnhq", dob, ob)   # (B,nq,H,qc)
    dob_h = jnp.moveaxis(dob, 3, 2)                    # (B,nq,H,qc,D)
    qpos = (jnp.arange(nq * q_chunk_) + q_offset).reshape(nq, q_chunk_)

    def step(dq_acc, inp):
        ki, kblk, vblk = inp
        ke = _expand(kblk, G).astype(jnp.float32)      # (kc,... ) scan slice
        ve = _expand(vblk, G).astype(jnp.float32)
        s = jnp.einsum("bnqhd,bkhd->bnhqk", qb32, ke) * scale
        kpos = ki * kv_chunk_ + jnp.arange(kv_chunk_)
        mask = kpos[None, None, :] < Skv
        if causal:
            mask = mask & (qpos[:, :, None] >= kpos[None, None, :])
        s = jnp.where(mask[None, :, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                # (B,nq,H,qc,kc)
        dv = jnp.einsum("bnhqk,bnhqd->bkhd", p, dob_h)
        dp = jnp.einsum("bnhqd,bkhd->bnhqk", dob_h, ve)
        ds = p * (dp - delta[..., None]) * scale
        dq_blk = jnp.einsum("bnhqk,bkhd->bnqhd", ds, ke)
        dk = jnp.einsum("bnhqk,bnqhd->bkhd", ds, qb32)
        # fold GQA groups back to KVH heads
        if G > 1:
            dk = dk.reshape(dk.shape[0], dk.shape[1], KVH, G, D).sum(3)
            dv = dv.reshape(dv.shape[0], dv.shape[1], KVH, G, D).sum(3)
        return dq_acc + dq_blk, (dk, dv)

    dq0 = jnp.zeros((B, nq, q_chunk_, H, D), jnp.float32)
    dq_b, (dk_b, dv_b) = jax.lax.scan(step, dq0,
                                      (jnp.arange(nkv), kb, vb))
    dq = dq_b.reshape(B, nq * q_chunk_, H, D)[:, :Sq].astype(q.dtype)
    dk = jnp.moveaxis(dk_b, 0, 1).reshape(B, nkv * kv_chunk_, KVH, D)
    dk = dk[:, :Skv].astype(k.dtype)
    dv = jnp.moveaxis(dv_b, 0, 1).reshape(B, nkv * kv_chunk_, KVH, D)
    dv = dv[:, :Skv].astype(v.dtype)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, q_chunk: int = 512,
                    kv_chunk: int = 1024, q_offset: int = 0,
                    hints: ShardHints = NO_HINTS) -> jax.Array:
    """Public flash attention. q: (B,Sq,H,D); k,v: (B,Skv,KVH,D)."""
    return _flash(q, k, v, causal, q_chunk, kv_chunk, q_offset, hints)
