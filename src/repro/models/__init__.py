from repro.models.common import (ArraySpec, ModelConfig, MoEConfig,
                                 SSMConfig, MLAConfig, HybridConfig,
                                 MultimodalConfig, ShapeConfig,
                                 abstract_params, init_params)
