"""Attention: dense reference, chunked flash (jnp), GQA/MQA, decode path.

The chunked ("flash-style") implementation is the mathematical oracle for the
Pallas flash kernel in ``repro.kernels.flash_attention`` and the path that the
multi-pod dry-run lowers (Pallas does not lower on the CPU backend). It is
O(q_chunk·kv_chunk) in memory, which makes the 32k-prefill cells compilable.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig
from repro.models.flash import ShardHints, NO_HINTS, flash_attention
from repro.models.rope import apply_rope

NEG_INF = -1e30


def _gqa_expand(k: jax.Array, groups: int) -> jax.Array:
    """(B, S, KVH, D) -> (B, S, KVH*groups, D) by repeat."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset=0,
                    kv_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference attention.

    q: (B, Sq, H, D);  k, v: (B, Skv, KVH, D) with H % KVH == 0.
    ``q_offset``: position of q[0] relative to k[0] (decode: cur position).
    ``kv_len``: optional valid kv length (masks positions >= kv_len).
    """
    B, Sq, H, D = q.shape
    KVH = k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    # grouped-query einsum — never materializes the GQA-expanded cache
    # (jnp.repeat on the stacked decode cache costs G× cache bytes and is
    # hoisted out of the layer scan — see EXPERIMENTS.md §Perf); f32
    # accumulation without f32 copies of cache-sized operands.
    qg = q.reshape(B, Sq, KVH, G, D)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if kv_len is not None:
        mask = mask & (kpos[None, :] < kv_len)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def flash_attention_jnp(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, q_chunk: int = 512,
                        kv_chunk: int = 1024, q_offset: int = 0) -> jax.Array:
    """Blocked attention with running softmax stats (flash algorithm).

    Same signature/semantics as ``dense_attention`` (without kv_len).
    Memory: O(q_chunk × kv_chunk) per program instead of O(Sq × Skv).
    """
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to multiples
    sq_pad = (-Sq) % q_chunk
    skv_pad = (-Skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_pad), (0, 0), (0, 0)))
    nq, nkv = qp.shape[1] // q_chunk, kp.shape[1] // kv_chunk

    kp = kp.reshape(B, nkv, kv_chunk, KVH, D)
    vp = vp.reshape(B, nkv, kv_chunk, KVH, D)

    def one_q_block(qi_and_block):
        qi, qb = qi_and_block  # qb: (B, q_chunk, H, D)
        qb32 = qb.astype(jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_step(carry, inputs):
            acc, m, l = carry
            ki, kb, vb = inputs  # (B, kv_chunk, KVH, D)
            kb = _gqa_expand(kb, G).astype(jnp.float32)
            vb = _gqa_expand(vb, G).astype(jnp.float32)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb32, kb) * scale
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)
            mask = kpos[None, :] < Skv  # mask kv padding
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.arange(nkv), jnp.moveaxis(kp, 1, 0), jnp.moveaxis(vp, 1, 0)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, q_chunk, H, D)

    qblocks = jnp.moveaxis(
        qp.reshape(B, nq, q_chunk, H, D), 1, 0)  # (nq, B, q_chunk, H, D)
    outs = jax.lax.map(one_q_block, (jnp.arange(nq), qblocks))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def attention_op(cfg: ModelConfig, q, k, v, *, causal, q_offset=0,
                 kv_len=None, hints: ShardHints = NO_HINTS) -> jax.Array:
    """Dispatch dense vs flash (custom-VJP) based on sequence length."""
    Sq, Skv = q.shape[1], k.shape[1]
    if kv_len is not None or max(Sq, Skv) <= cfg.flash_min_seq:
        return dense_attention(q, k, v, causal=causal, q_offset=q_offset,
                               kv_len=kv_len)
    return flash_attention(q, k, v, causal=causal,
                           q_chunk=cfg.flash_q_chunk,
                           kv_chunk=cfg.flash_kv_chunk, q_offset=q_offset,
                           hints=hints)


# ---------------------------------------------------------------------------
# Standard (GQA / MQA / MHA) attention layer
# ---------------------------------------------------------------------------

def attention_defs(cfg: ModelConfig, *, stacked: int = 0) -> dict:
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.resolved_head_dim
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    pd = cfg.param_dtype
    out = {
        "wq": ArraySpec(L + (d, H, hd), pd, la + ("embed", "heads", None)),
        # kv projections carry their own d-axis name: at decode they must
        # be REPLICATED over "model" so k_new/v_new are not partial sums —
        # GSPMD otherwise defers the psum through the cache update,
        # all-reducing the whole stacked cache (EXPERIMENTS.md §Perf D1/D4)
        "wk": ArraySpec(L + (d, KVH, hd), pd,
                        la + ("kv_embed", "kv_heads", None)),
        "wv": ArraySpec(L + (d, KVH, hd), pd,
                        la + ("kv_embed", "kv_heads", None)),
        "wo": ArraySpec(L + (H, hd, d), pd, la + ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = ArraySpec(L + (H, hd), pd, la + ("heads", None), init="zeros")
        out["bk"] = ArraySpec(L + (KVH, hd), pd, la + ("kv_heads", None), init="zeros")
        out["bv"] = ArraySpec(L + (KVH, hd), pd, la + ("kv_heads", None), init="zeros")
    return out


def attention_qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions):
    """Project to q, k, v and apply RoPE. x: (B, S, d)."""
    cd = cfg.compute_dtype
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
                    positions: jax.Array, causal: Optional[bool] = None,
                    hints: ShardHints = NO_HINTS) -> jax.Array:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    causal = cfg.causal if causal is None else causal
    q, k, v = attention_qkv(cfg, p, x, positions)
    out = attention_op(cfg, q, k, v, causal=causal, hints=hints)
    return jnp.einsum("bshe,hed->bsd", out.astype(cfg.compute_dtype),
                      p["wo"].astype(cfg.compute_dtype))


def attention_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                     pos: jax.Array):
    """One-token decode. x: (B, 1, d); cache: {"k","v"}: (B, S, KVH, hd).

    ``pos``: scalar int32 — current position (number of tokens already in
    the cache). Returns (out (B, 1, d), new_cache).
    """
    q, k_new, v_new = attention_qkv(cfg, p, x, positions=pos[None])
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1)
    out = dense_attention(q, k_cache, v_cache, causal=False,
                          kv_len=pos + 1)
    y = jnp.einsum("bshe,hed->bsd", out.astype(cfg.compute_dtype),
                   p["wo"].astype(cfg.compute_dtype))
    return y, {"k": k_cache, "v": v_cache}


def attention_cache_defs(cfg: ModelConfig, batch: int, max_seq: int,
                         *, stacked: int = 0) -> dict:
    KVH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    spec = ArraySpec(L + (batch, max_seq, KVH, hd), cfg.compute_dtype,
                     la + ("batch", "kv_seq", "kv_heads", None), init="zeros")
    return {"k": spec, "v": spec}
