"""Mamba2 (state-space duality / SSD) blocks.

The chunked SSD algorithm is expressed as matmuls (MXU-friendly) with a
`lax.scan` over chunk states — the TPU-native adaptation of the CUDA scan.
``ssd_chunked`` is the jnp oracle for the Pallas kernel in
``repro.kernels.ssd_scan``. ``ssd_reference`` is a step-by-step recurrence
used only in tests.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig
from repro.models.layers import rms_norm


# ---------------------------------------------------------------------------
# Core SSD math
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, A, B, C, *, initial_state=None):
    """Naive sequential recurrence (oracle).

    x: (b, S, H, P); dt: (b, S, H); A: (H,); B, C: (b, S, G, N).
    Returns (y (b, S, H, P), final_state (b, H, P, N)).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    dA = jnp.exp(dt32 * A[None, None, :])  # (b, S, H)

    def step(state, inputs):
        xt, dAt, dtt, Bt, Ct = inputs
        state = state * dAt[..., None, None] + \
            (dtt[..., None, None] * xt[..., None]) * Bt[:, :, None, :]
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    state0 = (jnp.zeros((b, H, P, N), jnp.float32)
              if initial_state is None else initial_state.astype(jnp.float32))
    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dA, 1, 0),
          jnp.moveaxis(dt32, 1, 0), jnp.moveaxis(Bh, 1, 0),
          jnp.moveaxis(Ch, 1, 0))
    state, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def ssd_chunked(x, dt, A, B, C, *, chunk: int = 256, initial_state=None,
                intra_bf16: bool = False):
    """Chunked SSD (matmul form). Same contract as ``ssd_reference``.

    ``intra_bf16``: hold the O(S·chunk·H) quadratic intra-chunk tensors
    (decay, scores) in bf16 with f32 accumulation — halves the dominant
    HBM traffic of the jnp path (the Pallas ssd_scan kernel fuses these
    entirely on real TPUs; see EXPERIMENTS.md §Perf).
    """
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    # reshape to (b, nc, Q, ...)
    xq = x.reshape(b, nc, chunk, H, P).astype(jnp.float32)
    dtq = dt.reshape(b, nc, chunk, H).astype(jnp.float32)
    Bq = jnp.repeat(B.reshape(b, nc, chunk, G, N), rep, axis=3).astype(jnp.float32)
    Cq = jnp.repeat(C.reshape(b, nc, chunk, G, N), rep, axis=3).astype(jnp.float32)

    a = dtq * A[None, None, None, :]              # (b, nc, Q, H)
    cum_a = jnp.cumsum(a, axis=2)                  # inclusive
    a_total = cum_a[:, :, -1]                      # (b, nc, H)

    # --- intra-chunk (quadratic in Q, matmul-friendly) ---
    # decay[i, j] = exp(cum_a[i] - cum_a[j]) for i >= j
    diff = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]  # (b,nc,Q,Q,H)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    it = jnp.bfloat16 if intra_bf16 else jnp.float32
    decay = jnp.where(causal[None, None, :, :, None],
                      jnp.exp(diff), 0.0).astype(it)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cq.astype(it), Bq.astype(it),
                        preferred_element_type=it) * decay \
        * dtq[:, :, None, :, :].astype(it)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xq.astype(it),
                         preferred_element_type=jnp.float32)

    # --- end-of-chunk states ---
    w = jnp.exp(a_total[:, :, None, :] - cum_a) * dtq  # (b, nc, Q, H)
    chunk_states = jnp.einsum("bcjh,bcjhp,bcjhn->bchpn", w, xq, Bq)

    # --- inter-chunk recurrence: associative (parallel-prefix) scan.
    # state_k = e^{a_k}·state_{k-1} + S_k is a linear recurrence; the
    # associative form runs in log2(nc) batched steps instead of nc
    # sequential slices — fewer/larger fused ops (≈3× less HBM traffic on
    # the jnp path, §Perf 3.3) and real parallelism on TPU.
    state0 = (jnp.zeros((b, H, P, N), jnp.float32)
              if initial_state is None else initial_state.astype(jnp.float32))
    decays = jnp.exp(a_total)                       # (b, nc, H)
    states_in = chunk_states.at[:, 0].add(
        state0 * decays[:, 0, :, None, None])       # fold initial state in

    def combine(x, y):
        a1, s1 = x
        a2, s2 = y
        return a1 * a2, s1 * a2[..., None, None] + s2

    _, states_after = jax.lax.associative_scan(
        combine, (decays, states_in), axis=1)       # inclusive prefix
    final_state = states_after[:, -1]
    prev_states = jnp.concatenate(
        [state0[:, None], states_after[:, :-1]], axis=1)  # (b,nc,H,P,N)

    # --- contribution of the incoming state to each position ---
    y_inter = jnp.einsum("bcih,bcihn,bchpn->bcihp",
                         jnp.exp(cum_a), Cq, prev_states)

    y = (y_intra + y_inter).reshape(b, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt, A, B, C):
    """Single-token SSD update.

    state: (b, H, P, N); x: (b, H, P); dt: (b, H); B, C: (b, G, N).
    Returns (y (b, H, P), new_state).
    """
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=1).astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32 * A[None, :])
    state = state * dA[..., None, None] + \
        (dt32[..., None, None] * x.astype(jnp.float32)[..., None]) \
        * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj → conv → SSD → gated norm → out_proj)
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.d_inner(cfg.d_model)
    H = s.nheads(cfg.d_model)
    conv_ch = d_inner + 2 * s.ngroups * s.d_state
    d_in_proj = 2 * d_inner + 2 * s.ngroups * s.d_state + H
    return s, d_inner, H, conv_ch, d_in_proj


def mamba2_defs(cfg: ModelConfig, *, stacked: int = 0) -> dict:
    s, d_inner, H, conv_ch, d_in_proj = _dims(cfg)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    pd = cfg.param_dtype
    return {
        "in_proj": ArraySpec(L + (cfg.d_model, d_in_proj), pd,
                             la + ("embed", "mlp")),
        "conv_w": ArraySpec(L + (s.d_conv, conv_ch), pd,
                            la + (None, "mlp"), init="small"),
        "conv_b": ArraySpec(L + (conv_ch,), pd, la + ("mlp",), init="zeros"),
        "A_log": ArraySpec(L + (H,), jnp.float32, la + ("heads",),
                           init="zeros"),
        "dt_bias": ArraySpec(L + (H,), jnp.float32, la + ("heads",),
                             init="zeros"),
        "D": ArraySpec(L + (H,), jnp.float32, la + ("heads",), init="ones"),
        "norm": ArraySpec(L + (d_inner,), jnp.float32, la + ("mlp",),
                          init="zeros"),
        "out_proj": ArraySpec(L + (d_inner, cfg.d_model), pd,
                              la + ("mlp", "embed")),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    s, d_inner, H, conv_ch, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt, (s, d_inner, H, gn)


def _causal_conv(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xBC: (B, S, C); w: (K, C); b: (C,)."""
    K = w.shape[0]
    x = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    # sum over K shifted copies — avoids conv primitives, trivially shardable.
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for k in range(K):
        out = out + x[:, k:k + xBC.shape[1]].astype(jnp.float32) * \
            w[k].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(xBC.dtype)


def mamba2_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence Mamba2 block. x: (B, S, d_model)."""
    cd = cfg.compute_dtype
    zxbcdt = x.astype(cd) @ p["in_proj"].astype(cd)
    z, xBC, dt, (s, d_inner, H, gn) = _split_in_proj(cfg, zxbcdt)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs = xBC[..., :d_inner]
    Bm = xBC[..., d_inner:d_inner + gn]
    Cm = xBC[..., d_inner + gn:]
    b, S = x.shape[0], x.shape[1]
    xs = xs.reshape(b, S, H, s.headdim)
    Bm = Bm.reshape(b, S, s.ngroups, s.d_state)
    Cm = Cm.reshape(b, S, s.ngroups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(xs, dt, A, Bm, Cm, chunk=s.chunk,
                       intra_bf16=s.intra_bf16)
    y = y + p["D"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, S, d_inner).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(cd)


def mamba2_cache_defs(cfg: ModelConfig, batch: int, *, stacked: int = 0
                      ) -> dict:
    s, d_inner, H, conv_ch, _ = _dims(cfg)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    return {
        "conv": ArraySpec(L + (batch, s.d_conv - 1, conv_ch),
                          cfg.compute_dtype, la + ("batch", None, "mlp"),
                          init="zeros"),
        "state": ArraySpec(L + (batch, H, s.headdim, s.d_state),
                           jnp.float32, la + ("batch", "heads", None, None),
                           init="zeros"),
    }


def mamba2_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                  pos: jax.Array):
    """Single-token Mamba2 step. x: (B, 1, d_model)."""
    cd = cfg.compute_dtype
    zxbcdt = x[:, 0].astype(cd) @ p["in_proj"].astype(cd)
    z, xBC, dt, (s, d_inner, H, gn) = _split_in_proj(cfg, zxbcdt)
    # conv over (cached history, current)
    hist = cache["conv"]                                # (B, K-1, C)
    window = jnp.concatenate([hist, xBC[:, None]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + \
        p["conv_b"].astype(jnp.float32)
    xBC = jax.nn.silu(conv_out).astype(cd)
    new_conv = window[:, 1:]
    xs = xBC[..., :d_inner].reshape(-1, H, s.headdim)
    Bm = xBC[..., d_inner:d_inner + gn].reshape(-1, s.ngroups, s.d_state)
    Cm = xBC[..., d_inner + gn:].reshape(-1, s.ngroups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, new_state = ssd_decode_step(cache["state"], xs, dt, A, Bm, Cm)
    y = y + p["D"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(-1, d_inner).astype(cd)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"].astype(cd))[:, None]
    return out, {"conv": new_conv, "state": new_state}
