"""Basic layers: norms, MLPs, embeddings — pure functions over param dicts."""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics but the large elementwise product in the
    input dtype — keeps activations (and their cotangents) bf16, which is
    what lets GSPMD move bf16 instead of f32 across the mesh (§Perf)."""
    dtype = x.dtype
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dtype)
    w = (1.0 + weight.astype(jnp.float32)).astype(dtype)
    return x * inv * w


def norm_defs(d: int, *, stacked: int = 0) -> ArraySpec:
    shape = (stacked, d) if stacked else (d,)
    axes = ("layers", "embed") if stacked else ("embed",)
    return ArraySpec(shape, jnp.float32, axes, init="zeros")


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig, d_ff: int, *, stacked: int = 0) -> dict:
    d = cfg.d_model
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    pd = cfg.param_dtype
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": ArraySpec(L + (d, d_ff), pd, la + ("embed", "mlp")),
            "w_up": ArraySpec(L + (d, d_ff), pd, la + ("embed", "mlp")),
            "w_down": ArraySpec(L + (d_ff, d), pd, la + ("mlp", "embed")),
        }
    return {  # plain gelu MLP (hubert-style encoder FFN)
        "w_up": ArraySpec(L + (d, d_ff), pd, la + ("embed", "mlp")),
        "b_up": ArraySpec(L + (d_ff,), pd, la + ("mlp",), init="zeros"),
        "w_down": ArraySpec(L + (d_ff, d), pd, la + ("mlp", "embed")),
        "b_down": ArraySpec(L + (d,), pd, la + ("embed",), init="zeros"),
    }


def mlp_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = cfg.compute_dtype
    x = x.astype(cd)
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        g = act(x @ p["w_gate"].astype(cd))
        u = x @ p["w_up"].astype(cd)
        return (g * u) @ p["w_down"].astype(cd)
    h = jax.nn.gelu(x @ p["w_up"].astype(cd) + p["b_up"].astype(cd))
    return h @ p["w_down"].astype(cd) + p["b_down"].astype(cd)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_defs(cfg: ModelConfig) -> dict:
    out = {"tok": ArraySpec((cfg.vocab_size, cfg.d_model), cfg.param_dtype,
                            ("vocab", "embed"), init="small")}
    if not cfg.tie_embeddings:
        out["unembed"] = ArraySpec((cfg.d_model, cfg.vocab_size),
                                   cfg.param_dtype, ("embed", "vocab"))
    return out


def embed_apply(cfg: ModelConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def unembed_apply(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = cfg.compute_dtype
    if cfg.tie_embeddings:
        return x.astype(cd) @ p["tok"].astype(cd).T
    return x.astype(cd) @ p["unembed"].astype(cd)
