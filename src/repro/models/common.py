"""Model configuration and parameter-definition infrastructure.

Every model in the zoo is described by a ``ModelConfig`` and exposes its
parameters via a tree of ``ArraySpec`` — the single source of truth for
shape, dtype, *and* logical sharding axes. From that one tree we derive:

  * abstract parameters (``jax.ShapeDtypeStruct``) for the dry-run,
  * real initialized parameters for smoke tests / examples,
  * ``NamedSharding`` trees via the rules in ``repro.distributed.sharding``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------------------
# ArraySpec: shape + dtype + logical axes + init scheme
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Declarative spec of one parameter / state array.

    ``axes`` has one *logical* axis name (or None) per dimension. Logical
    names are mapped to mesh axes by sharding rules (see distributed/).
    """

    shape: tuple
    dtype: Any = jnp.float32
    axes: tuple = ()
    init: str = "normal"     # normal | zeros | ones | embed | small
    init_scale: float = 1.0  # multiplier on top of the fan-in scaling

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank")

    @property
    def size(self) -> int:
        return math.prod(self.shape)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key: jax.Array) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        # fan-in scaled normal. For stacked-layer params the leading "layers"
        # (or "experts") axis is excluded from fan-in.
        fan_dims = [
            d for d, a in zip(self.shape, self.axes or (None,) * len(self.shape))
            if a not in ("layers", "experts", "stack")
        ]
        fan_in = fan_dims[0] if fan_dims else 1
        if self.init == "embed":
            scale = 1.0
        elif self.init == "small":
            scale = 0.02
        else:
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        scale *= self.init_scale
        x = jax.random.normal(key, self.shape, jnp.float32) * scale
        return x.astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ArraySpec)


def abstract_params(defs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.abstract(), defs, is_leaf=is_spec)


def init_params(defs: PyTree, key: jax.Array) -> PyTree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def spec_axes(defs: PyTree) -> PyTree:
    """Parallel tree of logical-axis tuples."""
    return jax.tree.map(lambda s: s.axes, defs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int            # routed experts
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    num_shared: int = 0         # always-on shared experts (same d_expert)
    first_dense_layers: int = 0  # leading layers that use a dense FFN instead
    dense_d_ff: int = 0          # hidden size of those dense layers
    router_noise: float = 0.0
    aux_loss_coef: float = 0.001
    # token capacity factor for dense-dispatch mode (einsum); sort-based
    # dispatch (shuffle modes) is capacity-free thanks to the notification
    # metadata pre-exchange.
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64
    ngroups: int = 1
    chunk: int = 256
    intra_bf16: bool = False  # quadratic intra-chunk tensors in bf16

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def nheads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.headdim


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = no query compression (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: Mamba2 backbone with a shared attention block."""
    shared_block_every: int = 6   # one shared-block call per this many layers
    # the shared block consumes concat(h, h_embed) -> proj to d_model
    concat_embed: bool = True


@dataclasses.dataclass(frozen=True)
class MultimodalConfig:
    """Stub modality frontend: input_specs provide precomputed embeddings."""
    kind: str = "vision"          # vision | audio
    num_patches: int = 2880       # patches (vision) per example
    frontend_dim: int = 0         # 0 => already projected to d_model


# ---------------------------------------------------------------------------
# ModelConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                      # decoder | encoder | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 => d_model // num_heads
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: scale embeddings by sqrt(d)
    causal: bool = True
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    hybrid: Optional[HybridConfig] = None
    multimodal: Optional[MultimodalConfig] = None
    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    # attention implementation thresholds
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 1024
    flash_min_seq: int = 2048      # below this use dense reference attention
    # comments / provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attn_free(self) -> bool:
        return self.kind == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports the long_500k decode shape (SSM / hybrid)."""
        return self.kind in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return self.kind != "encoder"

    def param_count(self) -> int:
        """Approximate parameter count (exact count comes from the defs)."""
        from repro.models import lm  # local import to avoid cycle
        return sum(s.size for s in jax.tree.leaves(
            lm.param_defs(self), is_leaf=is_spec) if is_spec(s))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed only)."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        moe_layers = self.num_layers - m.first_dense_layers
        per_expert = 3 * self.d_model * m.d_expert
        inactive = moe_layers * (m.num_experts - m.top_k) * per_expert
        return total - inactive


# ---------------------------------------------------------------------------
# Shape presets (the four assigned input-shape cells)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.step == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig) -> list:
    """Shape cells that apply to this architecture (see DESIGN.md §4)."""
    out = []
    for s in ALL_SHAPES:
        if s.is_decode and not cfg.has_decode:
            continue  # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue  # needs sub-quadratic attention
        out.append(s)
    return out


def skipped_shapes(cfg: ModelConfig) -> list:
    names = {s.name for s in applicable_shapes(cfg)}
    out = []
    for s in ALL_SHAPES:
        if s.name in names:
            continue
        if s.is_decode and not cfg.has_decode:
            out.append((s.name, "encoder-only arch has no decode step"))
        else:
            out.append((s.name, "pure full-attention arch; long_500k needs "
                                "sub-quadratic attention"))
    return out
