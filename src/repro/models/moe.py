"""MoE FFN layer: shared experts (always-on, local — the "local cache"
analogue: never shuffled) + routed experts dispatched via repro.shuffle."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArraySpec, ModelConfig
from repro.shuffle.api import ShuffleConfig, dense_moe_ffn, ep_moe_ffn


def moe_defs(cfg: ModelConfig, *, stacked: int = 0) -> dict:
    m = cfg.moe
    d, de = cfg.d_model, m.d_expert
    E = m.num_experts
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    pd = cfg.param_dtype
    out = {
        "router": ArraySpec(L + (d, E), jnp.float32, la + ("embed", None),
                            init="small"),
        "we_gate": ArraySpec(L + (E, d, de), pd,
                             la + ("experts", "embed", "expert_mlp")),
        "we_up": ArraySpec(L + (E, d, de), pd,
                           la + ("experts", "embed", "expert_mlp")),
        "we_down": ArraySpec(L + (E, de, d), pd,
                             la + ("experts", "expert_mlp", "embed")),
    }
    if m.num_shared:
        ds = m.num_shared * de  # shared experts fused into one wide SwiGLU
        # hidden dim replicated (logical axis None): model-sharding it
        # conflicts with the sequence-sharded residual stream and makes
        # GSPMD fully re-replicate f32 activations in the backward (§Perf)
        out["shared"] = {
            "w_gate": ArraySpec(L + (d, ds), pd, la + ("embed", None)),
            "w_up": ArraySpec(L + (d, ds), pd, la + ("embed", None)),
            "w_down": ArraySpec(L + (ds, d), pd, la + (None, "embed")),
        }
    return out


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array, *,
              shuffle: ShuffleConfig, mesh=None
              ) -> Tuple[jax.Array, jax.Array, dict]:
    """x: (B, S, d). Returns (y, aux_loss, diagnostics dict)."""
    m = cfg.moe
    cd = cfg.compute_dtype
    B, S, d = x.shape
    xt = x.reshape(B * S, d)

    if shuffle.mode == "dense" or mesh is None:
        y, aux, load = dense_moe_ffn(
            xt, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            top_k=m.top_k, capacity_factor=m.capacity_factor,
            norm_topk=shuffle.norm_topk, compute_dtype=cd)
        diag = {"expert_load": load,
                "dropped": jnp.zeros((), jnp.int32),
                "dcn_bytes": jnp.zeros((), jnp.float32)}
    else:
        # pad token count to the token-axes product
        from repro.shuffle.api import mesh_axis_size
        shuf = shuffle.resolve(mesh if not shuffle.use_context_mesh
                               else None)
        devs = 1
        for a in shuf.token_axes:
            devs *= mesh_axis_size(
                mesh if not shuffle.use_context_mesh else None, a)
        T = B * S
        pad = (-T) % devs
        if pad:
            xt = jnp.pad(xt, ((0, pad), (0, 0)))
        mask = (jnp.arange(T + pad) < T).astype(jnp.float32)
        y, aux, dg = ep_moe_ffn(
            xt, p["router"], p["we_gate"], p["we_up"], p["we_down"],
            top_k=m.top_k, cfg=shuf, mesh=mesh, compute_dtype=cd,
            token_mask=mask)
        y = y[:T]
        diag = {"expert_load": dg.expert_load, "dropped": dg.dropped,
                "dcn_bytes": dg.dcn_bytes}

    y = y.reshape(B, S, d)
    if m.num_shared:
        sp = p["shared"]
        xs = x.astype(cd)
        g = jax.nn.silu(xs @ sp["w_gate"].astype(cd))
        u = xs @ sp["w_up"].astype(cd)
        y = y + (g * u) @ sp["w_down"].astype(cd)
    return y.astype(x.dtype), aux * m.aux_loss_coef, diag
