"""BlobShuffle pipeline facade — the add-on API of Listing 1, runnable as
a single-process, multi-instance topology (used by examples and tests).

    shuffle = BlobShufflePipeline(config)
    out = shuffle.run(records)   # records routed to per-partition outputs

Internally: per-instance Batchers → simulated S3 + per-AZ distributed
caches (+ optional local caches) → per-AZ Debatchers, with periodic
commits through the CommitCoordinator.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.blob import Notification
from repro.core.cache import DistributedCache, LocalCache
from repro.core.commit import CommitCoordinator
from repro.core.debatcher import Debatcher
from repro.core.records import Record, default_partitioner
from repro.core.store import SimulatedS3


class BlobShufflePipeline:
    def __init__(self, cfg: BlobShuffleConfig, *, n_instances: int = 3,
                 store: Optional[SimulatedS3] = None, seed: int = 0,
                 exactly_once: bool = True):
        self.cfg = cfg
        self.n_instances = n_instances
        self.store = store or SimulatedS3(seed=seed,
                                          retention_s=cfg.retention_s)
        self.caches = [
            DistributedCache(az, max(n_instances // cfg.num_az, 1),
                             cfg.distributed_cache_bytes, self.store,
                             cfg.cache_on_write)
            for az in range(cfg.num_az)]
        self.notifications: List[Notification] = []
        self.batchers: List[Batcher] = []
        self.coordinators: List[CommitCoordinator] = []
        self.debatchers: List[Debatcher] = []
        for az in range(cfg.num_az):
            local = (LocalCache(cfg.local_cache_bytes, self.caches[az])
                     if cfg.local_cache_bytes else None)
            self.debatchers.append(
                Debatcher(az, self.caches[az], local,
                          exactly_once=exactly_once))
        for i in range(n_instances):
            az = i % cfg.num_az
            b = Batcher(cfg, self.partition_to_az,
                        lambda key: default_partitioner(
                            key, cfg.num_partitions),
                        self.caches[az])
            self.batchers.append(b)
            self.coordinators.append(
                CommitCoordinator(b, self.debatchers,
                                  self.notifications.append))

    def partition_to_az(self, partition: int) -> int:
        return partition % self.cfg.num_az

    def run(self, records: List[Record], *, now: float = 0.0,
            commit_every: Optional[int] = None,
            fail_instance_before_commit: Optional[int] = None
            ) -> Dict[int, List[Record]]:
        """Push records round-robin through instances; commit; debatch.

        ``fail_instance_before_commit``: inject a crash on that instance
        right before the first commit (its uncommitted records replay —
        at-least-once upstream, exactly-once downstream via dedup).
        """
        t = now
        pending_replay: List[Record] = []
        for i, rec in enumerate(records):
            inst = i % self.n_instances
            self.coordinators[inst].process(rec, t)
            t += 1e-6
            if commit_every and (i + 1) % commit_every == 0:
                if fail_instance_before_commit is not None:
                    replay = self.coordinators[
                        fail_instance_before_commit].fail_and_restart(t)
                    pending_replay.extend(replay)
                    fail_instance_before_commit = None
                for c in self.coordinators:
                    t += c.commit(t)
        for i, rec in enumerate(pending_replay):
            self.coordinators[i % self.n_instances].process(rec, t)
            t += 1e-6
        for c in self.coordinators:
            t += c.commit(t)
        # read path: deliver notifications to the target AZ's debatcher
        out: Dict[int, List[Record]] = defaultdict(list)
        for note in self.notifications:
            recs, _, _ = self.debatchers[note.target_az].process(note, t)
            out[note.partition].extend(recs)
        return dict(out)
