"""BlobShuffle pipeline facade — the add-on API of Listing 1, runnable as
a single-process, multi-instance topology (used by examples and tests).

    shuffle = BlobShufflePipeline(config)
    out = shuffle.run(records)   # records routed to per-partition outputs

Since the async-engine refactor this is a thin driver over
``repro.core.engine.AsyncShuffleEngine``: records are scheduled on the
virtual clock, commits (and injected failures) become events, and the
event loop runs to quiescence — so the same execution model that powers
the latency/cost sweeps also backs the functional API. Exactly-once
semantics are unchanged: replayed records re-enter the topology and the
Debatchers' (blob, partition) dedup plus commit-batched notification
visibility keep the output duplicate-free.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.batcher import BlobShuffleConfig
from repro.core.engine import AsyncShuffleEngine, EngineConfig
from repro.core.records import Record
from repro.core.stores import BlobStore


class BlobShufflePipeline:
    def __init__(self, cfg: BlobShuffleConfig, *, n_instances: int = 3,
                 store: Optional[BlobStore] = None, seed: int = 0,
                 exactly_once: bool = True,
                 engine_cfg: Optional[EngineConfig] = None):
        self.cfg = cfg
        self.n_instances = n_instances
        self.engine = AsyncShuffleEngine(cfg, engine_cfg,
                                         n_instances=n_instances,
                                         store=store, seed=seed,
                                         exactly_once=exactly_once)
        # component views kept for introspection/back-compat
        self.store = self.engine.store
        self.caches = self.engine.caches
        self.batchers = self.engine.batchers
        self.debatchers = self.engine.debatchers
        self.coordinators = self.engine.coordinators
        self.notifications = self.engine.published

    def partition_to_az(self, partition: int) -> int:
        return self.engine.partition_to_az(partition)

    def run(self, records: List[Record], *, now: float = 0.0,
            commit_every: Optional[int] = None,
            fail_instance_before_commit: Optional[int] = None
            ) -> Dict[int, List[Record]]:
        """Push records round-robin through instances; commit; debatch.

        ``fail_instance_before_commit``: inject a crash on that instance
        right before the first commit (its uncommitted records replay —
        at-least-once upstream, exactly-once downstream via dedup).
        """
        eng = self.engine
        dt = 1e-6
        t = now
        for i, rec in enumerate(records):
            eng.submit(t, rec, inst=i % self.n_instances)
            if commit_every and (i + 1) % commit_every == 0:
                if fail_instance_before_commit is not None:
                    eng.fail_at(t + dt / 4, fail_instance_before_commit)
                    fail_instance_before_commit = None
                eng.commit_at(t + dt / 2)
            t += dt
        eng.run()
        return {p: list(rs) for p, rs in eng.out.items()}
