"""Columnar record batches: the structure-of-arrays unit of flow.

A ``RecordBatch`` holds N records as contiguous byte arenas plus offset /
timestamp arrays (numpy-backed), so the hot path — partitioning, binning,
serialization — runs as vectorized array ops instead of per-``Record``
Python loops. ``Record`` remains the thin per-row view for compatibility.

Wire format is unchanged and bit-exact with ``repro.core.records``: the
vectorized serializer emits exactly ``b"".join(serialize(r) for r in
rows)`` (property-tested), so legacy and columnar paths produce
bit-identical blob payloads.

Headers are rare on the hot path; they are kept as an optional per-record
Python tuple side-table. Rows without headers take the fully vectorized
path; rows with headers get their (variable, self-describing) header
block appended by a small fix-up loop at the correct wire position.
"""

from __future__ import annotations

import struct
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.records import Record, _HDR

_HDR_NP = np.dtype([("klen", "<u4"), ("vlen", "<u4"),
                    ("ts", "<u8"), ("nh", "<u2")])
assert _HDR_NP.itemsize == _HDR.size

_FNV_OFFSET = np.uint64(0xCBF29CE484222325)
_FNV_PRIME = np.uint64(0x100000001B3)

_EMPTY_U8 = np.zeros(0, np.uint8)
_ZERO_OFF = np.zeros(1, np.int64)


def _offsets_from_lengths(lengths: np.ndarray) -> np.ndarray:
    out = np.zeros(len(lengths) + 1, np.int64)
    np.cumsum(lengths, out=out[1:])
    return out


def _ragged_gather(src: np.ndarray, starts: np.ndarray,
                   lengths: np.ndarray) -> np.ndarray:
    """Gather variable-length segments ``src[starts[i]:starts[i]+len[i]]``
    into one contiguous array, in order, with a single fancy index."""
    total = int(lengths.sum())
    if total == 0:
        return _EMPTY_U8
    seg_off = _offsets_from_lengths(lengths)
    idx = np.repeat(starts - seg_off[:-1], lengths) + np.arange(total)
    return src[idx]


class RecordBatch:
    """N records in structure-of-arrays layout.

    Arrays (all numpy):
      key_offsets    (N+1,) int64 — key i = key_arena[ko[i]:ko[i+1]]
      value_offsets  (N+1,) int64
      key_arena      (Kbytes,) uint8 — contiguous key bytes
      value_arena    (Vbytes,) uint8
      timestamps     (N,) uint64 — microseconds
      partitions     (N,) int32 or None — filled by the partitioner
      headers        tuple of per-record header tuples, or None (= none)
    """

    __slots__ = ("key_offsets", "value_offsets", "key_arena", "value_arena",
                 "timestamps", "partitions", "headers", "groups")

    def __init__(self, key_offsets: np.ndarray, key_arena: np.ndarray,
                 value_offsets: np.ndarray, value_arena: np.ndarray,
                 timestamps: np.ndarray,
                 headers: Optional[Tuple[Tuple[Tuple[bytes, bytes], ...],
                                         ...]] = None,
                 partitions: Optional[np.ndarray] = None):
        self.key_offsets = np.asarray(key_offsets, np.int64)
        self.value_offsets = np.asarray(value_offsets, np.int64)
        self.key_arena = np.asarray(key_arena, np.uint8)
        self.value_arena = np.asarray(value_arena, np.uint8)
        self.timestamps = np.asarray(timestamps, np.uint64)
        self.headers = headers
        self.partitions = partitions
        # opaque destination-grouping cache (owned by Batcher._group, so
        # the engine's arrival bookkeeping and the Batcher's binning share
        # one argsort); invalidated implicitly: row-subset views get None
        self.groups = None

    # -- construction -----------------------------------------------------
    @classmethod
    def empty(cls) -> "RecordBatch":
        return cls(_ZERO_OFF, _EMPTY_U8, _ZERO_OFF, _EMPTY_U8,
                   np.zeros(0, np.uint64))

    @classmethod
    def from_records(cls, records: Sequence[Record]) -> "RecordBatch":
        if not records:
            return cls.empty()
        keys = [r.key for r in records]
        values = [r.value for r in records]
        ko = _offsets_from_lengths(
            np.fromiter((len(k) for k in keys), np.int64, len(keys)))
        vo = _offsets_from_lengths(
            np.fromiter((len(v) for v in values), np.int64, len(values)))
        ka = np.frombuffer(b"".join(keys), np.uint8)
        va = np.frombuffer(b"".join(values), np.uint8)
        ts = np.fromiter((r.timestamp_us for r in records), np.uint64,
                         len(records))
        headers = (tuple(r.headers for r in records)
                   if any(r.headers for r in records) else None)
        return cls(ko, ka, vo, va, ts, headers)

    @classmethod
    def from_fixed(cls, keys_u64: np.ndarray, value_bytes: int,
                   timestamps_us: np.ndarray) -> "RecordBatch":
        """Vectorized builder for the common workload shape: 8-byte
        little-endian integer keys + constant-size zero values."""
        n = len(keys_u64)
        ka = np.ascontiguousarray(
            np.asarray(keys_u64).astype("<u8")).view(np.uint8)
        ko = np.arange(n + 1, dtype=np.int64) * 8
        vo = np.arange(n + 1, dtype=np.int64) * value_bytes
        va = np.zeros(n * value_bytes, np.uint8)
        return cls(ko, ka, vo, va, np.asarray(timestamps_us, np.uint64))

    @classmethod
    def from_buffer(cls, buf) -> "RecordBatch":
        """Parse a wire-format byte stream (the content of one blob byte
        range) into a columnar batch. The variable-length framing forces a
        sequential header scan, but key/value bytes are then gathered into
        the arenas with two vectorized passes — no per-record ``Record``
        objects or intermediate ``bytes`` copies are created."""
        mv = memoryview(buf)
        nbytes = len(mv)
        data = np.frombuffer(mv, np.uint8) if nbytes else _EMPTY_U8
        fast = cls._from_buffer_uniform(data)
        if fast is not None:
            return fast
        kst: List[int] = []
        kln: List[int] = []
        vln: List[int] = []
        ts: List[int] = []
        hdrs: List[Tuple[Tuple[bytes, bytes], ...]] = []
        any_hdrs = False
        unpack = _HDR.unpack_from
        hsz = _HDR.size
        p = 0
        while p < nbytes:
            klen, vlen, t, nh = unpack(mv, p)
            q = p + hsz
            kst.append(q)
            kln.append(klen)
            vln.append(vlen)
            ts.append(t)
            q += klen + vlen
            if nh:
                any_hdrs = True
                hs = []
                for _ in range(nh):
                    hk, hv = struct.unpack_from("<II", mv, q)
                    q += 8
                    hs.append((bytes(mv[q:q + hk]),
                               bytes(mv[q + hk:q + hk + hv])))
                    q += hk + hv
                hdrs.append(tuple(hs))
            else:
                hdrs.append(())
            p = q
        n = len(ts)
        if n == 0:
            return cls.empty()
        kst_a = np.asarray(kst, np.int64)
        kln_a = np.asarray(kln, np.int64)
        vln_a = np.asarray(vln, np.int64)
        ka = _ragged_gather(data, kst_a, kln_a)
        va = _ragged_gather(data, kst_a + kln_a, vln_a)
        return cls(_offsets_from_lengths(kln_a), ka,
                   _offsets_from_lengths(vln_a), va,
                   np.asarray(ts, np.uint64),
                   tuple(hdrs) if any_hdrs else None)

    @classmethod
    def _from_buffer_uniform(cls, data: np.ndarray) -> Optional["RecordBatch"]:
        """Opportunistic vectorized parse: hypothesize from the first
        header that every record has the same (klen, vlen, no headers)
        frame, then *verify* the hypothesis over all headers with one
        vectorized pass before trusting it. Returns None (→ generic scan)
        whenever the stream isn't uniform."""
        nbytes = data.size
        if nbytes < _HDR.size:
            return None
        kw, vw, _, nh = _HDR.unpack_from(data, 0)
        if nh != 0:
            return None
        row = _HDR.size + kw + vw
        if row == 0 or nbytes % row != 0:
            return None
        n = nbytes // row
        rows = data.reshape(n, row)
        hdr = np.ascontiguousarray(rows[:, :_HDR.size]).view(_HDR_NP)[:, 0]
        if not ((hdr["klen"] == kw).all() and (hdr["vlen"] == vw).all()
                and (hdr["nh"] == 0).all()):
            return None
        ka = (np.ascontiguousarray(rows[:, _HDR.size:_HDR.size + kw]).ravel()
              if kw else _EMPTY_U8)
        va = (np.ascontiguousarray(rows[:, _HDR.size + kw:]).ravel()
              if vw else _EMPTY_U8)
        return cls(np.arange(n + 1, dtype=np.int64) * kw, ka,
                   np.arange(n + 1, dtype=np.int64) * vw, va,
                   hdr["ts"].astype(np.uint64))

    # -- row access (compat views) ----------------------------------------
    def __len__(self) -> int:
        return len(self.timestamps)

    def key(self, i: int) -> bytes:
        return self.key_arena[
            self.key_offsets[i]:self.key_offsets[i + 1]].tobytes()

    def value(self, i: int) -> bytes:
        return self.value_arena[
            self.value_offsets[i]:self.value_offsets[i + 1]].tobytes()

    def record(self, i: int) -> Record:
        """Thin per-row ``Record`` view (copies the row's bytes)."""
        hs = self.headers[i] if self.headers is not None else ()
        return Record(self.key(i), self.value(i),
                      int(self.timestamps[i]), hs)

    def iter_records(self) -> Iterator[Record]:
        for i in range(len(self)):
            yield self.record(i)

    def to_records(self) -> List[Record]:
        return list(self.iter_records())

    # -- row selection -----------------------------------------------------
    def slice_rows(self, start: int, stop: int) -> "RecordBatch":
        """Zero-copy row slice: arenas and offsets are numpy views (the
        offset arrays are rebased, the byte arenas are shared)."""
        ko = self.key_offsets[start:stop + 1] - self.key_offsets[start]
        vo = self.value_offsets[start:stop + 1] - self.value_offsets[start]
        ka = self.key_arena[self.key_offsets[start]:self.key_offsets[stop]]
        va = self.value_arena[
            self.value_offsets[start]:self.value_offsets[stop]]
        hs = (self.headers[start:stop]
              if self.headers is not None else None)
        parts = (self.partitions[start:stop]
                 if self.partitions is not None else None)
        return RecordBatch(ko, ka, vo, va, self.timestamps[start:stop],
                           hs, parts)

    def select(self, idx: np.ndarray) -> "RecordBatch":
        """Gather arbitrary rows (vectorized ragged gather)."""
        idx = np.asarray(idx, np.int64)
        klen = self.key_offsets[idx + 1] - self.key_offsets[idx]
        vlen = self.value_offsets[idx + 1] - self.value_offsets[idx]
        ka = _ragged_gather(self.key_arena, self.key_offsets[idx], klen)
        va = _ragged_gather(self.value_arena, self.value_offsets[idx], vlen)
        hs = (tuple(self.headers[int(i)] for i in idx)
              if self.headers is not None else None)
        parts = (self.partitions[idx]
                 if self.partitions is not None else None)
        return RecordBatch(_offsets_from_lengths(klen), ka,
                           _offsets_from_lengths(vlen), va,
                           self.timestamps[idx], hs, parts)

    # -- serialization -----------------------------------------------------
    def _header_sizes(self, idx: np.ndarray) -> np.ndarray:
        hsz = np.zeros(len(idx), np.int64)
        if self.headers is not None:
            for j, i in enumerate(idx):
                hs = self.headers[int(i)]
                if hs:
                    hsz[j] = sum(8 + len(k) + len(v) for k, v in hs)
        return hsz

    def serialized_sizes(self) -> np.ndarray:
        """(N,) int64 — wire size of each row (vectorized Record.size)."""
        idx = np.arange(len(self), dtype=np.int64)
        return (_HDR.size
                + np.diff(self.key_offsets)
                + np.diff(self.value_offsets)
                + self._header_sizes(idx))

    @property
    def nbytes(self) -> int:
        return int(self.serialized_sizes().sum())

    def _uniform_widths(self) -> Optional[Tuple[int, int]]:
        """(key_width, value_width) when every row has the same key and
        value length and no headers — the fixed-size hot-path shape —
        else None."""
        if self.headers is not None or len(self) == 0:
            return None
        if (self.key_offsets[0] != 0 or self.value_offsets[0] != 0
                or self.key_arena.size != self.key_offsets[-1]
                or self.value_arena.size != self.value_offsets[-1]):
            return None    # arenas not densely packed from 0: generic path
        klen = np.diff(self.key_offsets)
        vlen = np.diff(self.value_offsets)
        if (klen == klen[0]).all() and (vlen == vlen[0]).all():
            return int(klen[0]), int(vlen[0])
        return None

    def serialize_rows(self, idx: Optional[np.ndarray] = None) -> bytearray:
        """Wire-serialize rows ``idx`` (default: all, in order) into one
        buffer — bit-exact with ``b"".join(serialize(row))``."""
        if idx is None:
            idx = np.arange(len(self), dtype=np.int64)
        else:
            idx = np.asarray(idx, np.int64)
        m = len(idx)
        if m == 0:
            return bytearray()
        uniform = self._uniform_widths()
        if uniform is not None:
            return self._serialize_rows_uniform(idx, *uniform)
        klen = self.key_offsets[idx + 1] - self.key_offsets[idx]
        vlen = self.value_offsets[idx + 1] - self.value_offsets[idx]
        hsz = self._header_sizes(idx)
        row_off = _offsets_from_lengths(_HDR.size + klen + vlen + hsz)
        out = bytearray(int(row_off[-1]))
        o = np.frombuffer(out, np.uint8)
        # fixed 18-byte headers: one packed struct-array scatter
        hdr = np.zeros(m, _HDR_NP)
        hdr["klen"] = klen
        hdr["vlen"] = vlen
        hdr["ts"] = self.timestamps[idx]
        if self.headers is not None:
            hdr["nh"] = [len(self.headers[int(i)]) for i in idx]
        dst = (row_off[:-1, None] + np.arange(_HDR.size)).ravel()
        o[dst] = hdr.view(np.uint8)
        # key bytes: ragged gather + ragged scatter
        self._scatter_segments(o, self.key_arena, self.key_offsets[idx],
                               klen, row_off[:-1] + _HDR.size)
        self._scatter_segments(o, self.value_arena, self.value_offsets[idx],
                               vlen, row_off[:-1] + _HDR.size + klen)
        # variable header blocks: rare fix-up loop at the exact wire offset
        if self.headers is not None:
            for j, i in enumerate(idx):
                hs = self.headers[int(i)]
                if not hs:
                    continue
                pos = int(row_off[j] + _HDR.size + klen[j] + vlen[j])
                for k, v in hs:
                    struct.pack_into("<II", out, pos, len(k), len(v))
                    pos += 8
                    out[pos:pos + len(k)] = k
                    pos += len(k)
                    out[pos:pos + len(v)] = v
                    pos += len(v)
        return out

    def _serialize_rows_uniform(self, idx: np.ndarray, kw: int,
                                vw: int) -> bytearray:
        """Fixed-width fast path: the wire buffer is one (m, row) matrix
        filled by column slices and row-level gathers — no per-byte index
        arrays, so serialization runs at near-memcpy speed."""
        m = len(idx)
        row = _HDR.size + kw + vw
        out = bytearray(m * row)
        o = np.frombuffer(out, np.uint8).reshape(m, row)
        hdr = np.zeros(m, _HDR_NP)
        hdr["klen"] = kw
        hdr["vlen"] = vw
        hdr["ts"] = self.timestamps[idx]
        o[:, :_HDR.size] = hdr.view(np.uint8).reshape(m, _HDR.size)
        if kw:
            o[:, _HDR.size:_HDR.size + kw] = \
                self.key_arena.reshape(-1, kw)[idx]
        if vw:
            o[:, _HDR.size + kw:] = self.value_arena.reshape(-1, vw)[idx]
        return out

    @staticmethod
    def _scatter_segments(out: np.ndarray, arena: np.ndarray,
                          src_starts: np.ndarray, lengths: np.ndarray,
                          dst_starts: np.ndarray) -> None:
        total = int(lengths.sum())
        if total == 0:
            return
        seg_off = _offsets_from_lengths(lengths)
        pos = np.arange(total)
        src = np.repeat(src_starts - seg_off[:-1], lengths) + pos
        dst = np.repeat(dst_starts - seg_off[:-1], lengths) + pos
        out[dst] = arena[src]


# -- vectorized partitioner -------------------------------------------------

def fnv1a_batch(key_arena: np.ndarray,
                key_offsets: np.ndarray) -> np.ndarray:
    """(N,) uint64 FNV-1a over the key arena — bit-exact with the scalar
    ``records.default_partitioner`` hash. Vectorized across records:
    iterate byte *positions* (max key length passes), each pass folding
    byte j of every still-active key with wrapping uint64 arithmetic."""
    n = len(key_offsets) - 1
    h = np.full(n, _FNV_OFFSET, np.uint64)
    if n == 0:
        return h
    starts = np.asarray(key_offsets[:-1], np.int64)
    lens = np.asarray(key_offsets[1:], np.int64) - starts
    arena = np.asarray(key_arena, np.uint8)
    with np.errstate(over="ignore"):
        if (starts[0] == 0 and arena.size == key_offsets[-1]
                and (lens == lens[0]).all()):
            # fixed-width keys over a packed arena: column-strided passes,
            # no boolean masks or index arrays
            w = int(lens[0])
            if w:
                mat = arena.reshape(n, w)
                for j in range(w):
                    h = (h ^ mat[:, j].astype(np.uint64)) * _FNV_PRIME
            return h
        for j in range(int(lens.max()) if n else 0):
            sel = lens > j
            b = arena[starts[sel] + j].astype(np.uint64)
            h[sel] = (h[sel] ^ b) * _FNV_PRIME
    return h


def default_partitioner_batch(batch: "RecordBatch",
                              num_partitions: int) -> np.ndarray:
    """(N,) int32 partition ids — vectorized ``default_partitioner``."""
    h = fnv1a_batch(batch.key_arena, batch.key_offsets)
    return (h % np.uint64(num_partitions)).astype(np.int32)
