"""Throughput-capacity model, calibrated to the paper's measurements.

Per-instance processing time per MiB shuffled (ad-hoc throughput regime):

    τ(S, p, N) = A0 + η·p + ζ·N + (B + C·p)/S + D·max(S − 32, 0)

with S the target batch size in MiB, p = partitions per AZ, N the number
of Kafka Streams instances. Terms:
  * A0      — per-byte record handling (serialize, key, copy),
  * η·p     — per-record partition bookkeeping growing with partitions,
  * ζ·N     — cluster coordination overhead (consumer group, fetches),
  * (B+C·p)/S — per-blob overhead (upload mgmt + p notifications/blob),
  * D·(S−32)⁺  — large-batch memory pressure (buffer churn / GC).

Coefficients are least-squares fitted to the paper's anchor set (Fig. 6a
throughput-vs-batch-size incl. the 1.43 GiB/s peak at 32 MiB, Fig. 8
partition scaling ≈ −26% at 3× partitions, Fig. 9 cluster scaling
144.2 → 102.0 MiB/s per node); see benchmarks/fit_capacity.py.
"""

from __future__ import annotations

import dataclasses

MiB = 1024.0 ** 2


@dataclasses.dataclass(frozen=True)
class CapacityModel:
    a0: float = 0.00957812      # s/MiB
    eta: float = 1.89894e-05    # s/MiB per partition-per-AZ
    zeta: float = 0.000144046   # s/MiB per instance
    b: float = 0.000602981      # s per blob-MiB⁻¹ (per-blob overhead)
    c: float = 0.000314289      # s per notification-MiB⁻¹
    d: float = 4.33962e-05      # s/MiB per MiB above 32

    def tau(self, s_batch_mib: float, parts_per_az: float,
            n_inst: int) -> float:
        """Seconds of instance time per MiB of shuffled data."""
        t = (self.a0 + self.eta * parts_per_az + self.zeta * n_inst
             + (self.b + self.c * parts_per_az) / s_batch_mib
             + self.d * max(s_batch_mib - 32.0, 0.0))
        return t

    def max_throughput(self, s_batch_mib: float, partitions: int,
                       n_inst: int, n_az: int = 3) -> float:
        """Cluster ad-hoc throughput in bytes/s."""
        p = partitions / n_az
        return n_inst / self.tau(s_batch_mib, p, n_inst) * MiB

    def max_throughput_gib(self, s_batch_mib: float, partitions: int,
                           n_inst: int, n_az: int = 3) -> float:
        return self.max_throughput(s_batch_mib, partitions, n_inst,
                                   n_az) / 1024.0 ** 3
