"""Debatcher operator (paper §3.2, Fig. 3): notifications → ranged blob
fetch (through the cache layers) → record extraction, with exactly-once
dedup on (blob_id, partition) and commit blocking on in-flight reads."""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Set, Tuple

from repro.core.blob import Notification, extract, extract_batch
from repro.core.cache import DistributedCache, LocalCache
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record


@dataclasses.dataclass
class DebatcherStats:
    notifications: int = 0
    records_out: int = 0
    bytes_out: int = 0
    duplicates_dropped: int = 0
    reads_cache: int = 0
    reads_store: int = 0
    reads_coalesced: int = 0
    reads_local: int = 0


class Debatcher:
    """One Debatcher per stream thread in the destination AZ."""

    #: optional repro.obs.Observability side-table, attached by the
    #: engine when observability is enabled
    obs = None

    def __init__(self, az: int, cache: DistributedCache,
                 local: Optional[LocalCache] = None,
                 exactly_once: bool = True):
        self.az = az
        self.cache = cache
        self.local = local
        self.exactly_once = exactly_once
        self.seen: Set[Tuple[str, int]] = set()
        self.inflight_until: float = 0.0
        self.stats = DebatcherStats()

    def begin(self, note: Notification) -> bool:
        """Admit one notification: False if it is a duplicate that must be
        dropped. Under exactly-once the (blob, partition) key is CLAIMED
        here — before the fetch is issued — so duplicate or reordered
        notifications arriving while the first fetch is still in flight
        cannot trigger a second delivery."""
        self.stats.notifications += 1
        key = (note.blob_id, note.partition)
        if self.exactly_once:
            if key in self.seen:
                self.stats.duplicates_dropped += 1
                return False
            self.seen.add(key)
        return True

    def complete(self, note: Notification, payload: bytes, lat: float,
                 src: str, now: float) -> List[Record]:
        """Deliver one admitted notification from its fetched payload."""
        setattr(self.stats, f"reads_{src}",
                getattr(self.stats, f"reads_{src}") + 1)
        recs = extract(payload, note.byte_range)
        self.stats.records_out += len(recs)
        self.stats.bytes_out += note.byte_range.length
        self.inflight_until = max(self.inflight_until, now + lat)
        if self.obs is not None:
            self.obs.on_extract(self.az, src, len(recs),
                                note.byte_range.length, now)
        return recs

    def complete_batch(self, note: Notification, payload, lat: float,
                       src: str, now: float) -> RecordBatch:
        """Columnar delivery: extract the partition's byte range straight
        into a ``RecordBatch`` (memoryview slice, vectorized arena gather
        — the payload is never re-copied into per-record objects)."""
        setattr(self.stats, f"reads_{src}",
                getattr(self.stats, f"reads_{src}") + 1)
        batch = extract_batch(payload, note.byte_range)
        self.stats.records_out += len(batch)
        self.stats.bytes_out += note.byte_range.length
        self.inflight_until = max(self.inflight_until, now + lat)
        if self.obs is not None:
            self.obs.on_extract(self.az, src, len(batch),
                                note.byte_range.length, now)
        return batch

    def process(self, note: Notification, now: float
                ) -> Tuple[List[Record], float, str]:
        """Resolve one notification synchronously (functional path).
        Returns (records, latency, source)."""
        if not self.begin(note):
            return [], 0.0, "duplicate"
        if self.local is not None:
            payload, lat, src = self.local.read(note.blob_id, now)
        else:
            payload, lat, src = self.cache.read(note.blob_id, now)
        return self.complete(note, payload, lat, src, now), lat, src

    def process_batch(self, note: Notification, now: float
                      ) -> Tuple[RecordBatch, float, str]:
        """Columnar counterpart of ``process``: returns a ``RecordBatch``
        instead of a list of ``Record`` objects."""
        if not self.begin(note):
            return RecordBatch.empty(), 0.0, "duplicate"
        if self.local is not None:
            payload, lat, src = self.local.read(note.blob_id, now)
        else:
            payload, lat, src = self.cache.read(note.blob_id, now)
        return self.complete_batch(note, payload, lat, src, now), lat, src

    def on_commit(self, now: float) -> float:
        """Block the commit until all outstanding reads completed."""
        return max(0.0, self.inflight_until - now)
