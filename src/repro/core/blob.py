"""Blob format: concatenated per-partition buffers + byte-range index.

A finalized batch ("blob") is a single byte buffer composed of the
per-partition byte buffers, such that records for a given partition appear
sequentially within the blob (paper §3.1). The index maps partition id to
its byte range; notifications carry ``(blob_id, partition, range)``.
"""

from __future__ import annotations

import dataclasses
import uuid
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.formats import BlobFormat, detect_format
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record, deserialize_all, serialize


@dataclasses.dataclass(frozen=True)
class ByteRange:
    offset: int
    length: int

    @property
    def end(self) -> int:
        return self.offset + self.length


@dataclasses.dataclass(frozen=True)
class BlobIndex:
    """partition id -> byte range within the blob payload."""
    ranges: Dict[int, ByteRange]

    def partitions(self) -> List[int]:
        return sorted(self.ranges)


@dataclasses.dataclass(frozen=True)
class Blob:
    blob_id: str
    payload: bytes          # any bytes-like (the batch path passes bytearray)
    index: BlobIndex
    target_az: int

    @property
    def size(self) -> int:
        return len(self.payload)


@dataclasses.dataclass(frozen=True)
class Notification:
    """Compact reference flowing through the messaging layer (paper Fig 2)."""
    blob_id: str
    partition: int
    byte_range: ByteRange
    target_az: int

    @property
    def size(self) -> int:
        return 48  # uuid + partition + range + az (wire estimate)


def new_blob_id() -> str:
    return uuid.uuid4().hex


def build_blob_from_buffers(per_partition: Dict[int, Sequence],
                            target_az: int,
                            blob_id: Optional[str] = None,
                            fmt: Optional[BlobFormat] = None
                            ) -> Tuple[Blob, List[Notification]]:
    """Assemble a blob from per-partition lists of already-serialized
    chunks (any bytes-like: ``bytes``, ``bytearray``, ``memoryview``).

    This is the zero-copy batch path: the payload is one preallocated
    buffer sized from the range math that is computed anyway, and every
    chunk is written into its final position exactly once — no
    intermediate chunk list, no join. ``fmt`` routes each partition's
    chunks through a wire format's ``encode_block`` (``None`` keeps the
    raw v1 identity path); byte ranges index the *encoded* blocks, so
    ranged GETs fetch exactly one decodable block and mixed-format blobs
    stay well-formed.
    """
    bid = blob_id or new_blob_id()
    encoded: List[Sequence] = []
    ranges: Dict[int, ByteRange] = {}
    off = 0
    for part in sorted(per_partition):
        enc = per_partition[part]
        if fmt is not None:
            enc = fmt.encode_block(enc)
        ln = sum(len(c) for c in enc)
        if ln == 0:
            continue
        encoded.append(enc)
        ranges[part] = ByteRange(off, ln)
        off += ln
    payload = bytearray(off)
    pos = 0
    for enc in encoded:
        for c in enc:
            ln = len(c)
            payload[pos:pos + ln] = c
            pos += ln
    blob = Blob(bid, payload, BlobIndex(ranges), target_az)
    notes = [Notification(bid, p, r, target_az)
             for p, r in sorted(ranges.items())]
    return blob, notes


def build_blob(per_partition: Dict[int, List[Record]], target_az: int,
               blob_id: Optional[str] = None) -> Tuple[Blob, List[Notification]]:
    """Concatenate per-partition record buffers into one blob + notifications
    (legacy per-``Record`` convenience; payload bytes are identical to the
    chunked path)."""
    return build_blob_from_buffers(
        {p: [serialize(r) for r in recs]
         for p, recs in per_partition.items()},
        target_az, blob_id)


def extract(payload, rng: ByteRange) -> List[Record]:
    """Debatch one partition's records from a blob payload (or sub-blob).
    The byte range is sliced as a ``memoryview`` — no payload copy. The
    block's format is sniffed per block, so blobs mixing raw and framed
    partitions decode transparently."""
    block = memoryview(payload)[rng.offset:rng.end]
    fmt = detect_format(block)
    if fmt.format_id == 1:
        return deserialize_all(block)       # raw v1: decode in place
    return fmt.decode_block_batch(block).to_records()


def extract_batch(payload, rng: ByteRange) -> RecordBatch:
    """Columnar debatch: one partition's byte range -> ``RecordBatch``
    (memoryview slice in, vectorized arena gather out — the payload bytes
    are never copied into intermediate per-record objects). Framed blocks
    are sniffed and decoded straight into the columnar form."""
    block = memoryview(payload)[rng.offset:rng.end]
    return detect_format(block).decode_block_batch(block)
