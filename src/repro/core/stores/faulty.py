"""FaultyStore: composable fault-injection decorator over any BlobStore.

Wraps an inner store and injects, at request-issue time:

  * **503 SlowDown throttling** via a per-prefix token bucket (S3
    throttles per key prefix; blob ids are uuid hex, so ``prefix_len``
    buckets spread uniformly). The error carries a ``retry_after_s``
    hint derived from the bucket refill rate;
  * **transient errors** (500 / connection reset) with probability
    ``transient_p`` per admitted request;
  * **timeout tails** with probability ``timeout_p``: the client burns
    the full ``timeout_s`` deadline before observing the failure.

Failures raise ``StoreError`` subclasses *before* the inner store is
touched: failed requests are not billed, never mutate store state, and
never count in the inner ``StoreStats`` (injector-side counters live in
``FaultStats``). Every draw comes from a dedicated seeded RNG, so a
faulty run is exactly reproducible — retries, backoff, and hedging in
the engine stay bit-deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.blob import ByteRange
from repro.core.stores.base import (BlobStore, SlowDownError, StoreCosts,
                                    StoreStats, StoreTimeoutError,
                                    TransientStoreError)


@dataclasses.dataclass
class FaultStats:
    slowdowns: int = 0
    transients: int = 0
    timeouts: int = 0

    @property
    def total(self) -> int:
        return self.slowdowns + self.transients + self.timeouts


class FaultyStore:
    """Decorator implementing ``BlobStore`` over any inner ``BlobStore``."""

    def __init__(self, inner: BlobStore, *, seed: int = 0,
                 throttle_rate: Optional[float] = None,
                 throttle_burst: float = 20.0,
                 prefix_len: int = 2,
                 transient_p: float = 0.0,
                 timeout_p: float = 0.0,
                 timeout_s: float = 2.0,
                 detect_s: float = 0.05):
        self.inner = inner
        self.rng = np.random.default_rng(seed)
        self.throttle_rate = throttle_rate     # admitted req/s per prefix
        self.throttle_burst = throttle_burst
        self.prefix_len = prefix_len
        self.transient_p = transient_p
        self.timeout_p = timeout_p
        self.timeout_s = timeout_s
        self.detect_s = detect_s
        self.faults = FaultStats()
        self._buckets: Dict[str, List[float]] = {}  # prefix -> [tokens, t]

    # -- delegated state ----------------------------------------------------
    @property
    def stats(self) -> StoreStats:
        return self.inner.stats

    @property
    def costs(self) -> StoreCosts:
        return self.inner.costs

    @property
    def retention_s(self) -> float:
        return self.inner.retention_s

    # -- fault decision -----------------------------------------------------
    def _admit(self, blob_id: str, now: float) -> None:
        if self.throttle_rate is not None:
            prefix = blob_id[:self.prefix_len]
            bucket = self._buckets.setdefault(
                prefix, [self.throttle_burst, now])
            tokens = min(self.throttle_burst,
                         bucket[0] + (now - bucket[1]) * self.throttle_rate)
            bucket[1] = now
            if tokens < 1.0:
                bucket[0] = tokens
                self.faults.slowdowns += 1
                retry = ((1.0 - tokens) / self.throttle_rate
                         if self.throttle_rate > 0 else 1.0)
                raise SlowDownError(
                    f"503 SlowDown on prefix {prefix!r}",
                    detect_after_s=self.detect_s, retry_after_s=retry)
            bucket[0] = tokens - 1.0
        if self.transient_p or self.timeout_p:
            r = float(self.rng.random())
            if r < self.transient_p:
                self.faults.transients += 1
                raise TransientStoreError(
                    f"transient error on {blob_id}",
                    detect_after_s=self.detect_s)
            if r < self.transient_p + self.timeout_p:
                self.faults.timeouts += 1
                raise StoreTimeoutError(
                    f"timeout after {self.timeout_s}s on {blob_id}",
                    detect_after_s=self.timeout_s)

    # -- BlobStore API (fault check, then delegate) -------------------------
    def put(self, blob_id: str, data: bytes, now: float = 0.0,
            az: Optional[int] = None) -> float:
        self._admit(blob_id, now)
        return self.inner.put(blob_id, data, now, az)

    def get(self, blob_id: str, byte_range: Optional[ByteRange] = None,
            now: float = 0.0, az: Optional[int] = None
            ) -> Tuple[bytes, float]:
        self._admit(blob_id, now)
        return self.inner.get(blob_id, byte_range, now, az)

    def begin_put(self, blob_id: str, size: int, now: float = 0.0,
                  az: Optional[int] = None) -> float:
        self._admit(blob_id, now)
        return self.inner.begin_put(blob_id, size, now, az)

    def finish_put(self, blob_id: str, data: bytes, now: float,
                   az: Optional[int] = None) -> None:
        # the request was admitted at begin_put; completion cannot fail
        self.inner.finish_put(blob_id, data, now, az)

    def begin_get(self, blob_id: str, now: float = 0.0,
                  az: Optional[int] = None) -> Tuple[int, float]:
        self._admit(blob_id, now)
        return self.inner.begin_get(blob_id, now, az)

    def payload(self, blob_id: str) -> bytes:
        return self.inner.payload(blob_id)

    def run_retention(self, now: float) -> int:
        return self.inner.run_retention(now)

    def accrue_storage(self, now: float) -> None:
        self.inner.accrue_storage(now)

    def contains(self, blob_id: str) -> bool:
        return self.inner.contains(blob_id)

    def keys(self) -> list:
        return self.inner.keys()

    def delete(self, blob_id: str, now: float = 0.0) -> bool:
        return self.inner.delete(blob_id, now)
