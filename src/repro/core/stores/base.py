"""BlobStore protocol: the swappable object-storage exchange layer.

The paper's economics hinge on the object store being an exchange layer
that can be swapped per deployment — S3 Standard today, S3 Express One
Zone or a premium low-latency tier tomorrow (§5.3, §6). Everything the
dataflow core (cache, engine, pipeline, simulator) needs from a store is
captured here as a structural ``Protocol``; concrete backends live in
sibling modules and decorators (``FaultyStore``) compose over any of
them.

Two call styles, both part of the protocol:

  * synchronous ``put``/``get`` — the functional (unit-test) path, where
    latency is sampled and *reported* but the state change is immediate;
  * event-driven ``begin_put``/``finish_put``/``begin_get``/``payload``
    — the async engine path, where an operation is split into issue time
    (sample latency, account the request) and completion time (apply the
    state change), so many PUTs/GETs overlap on the virtual clock.

Fault injection surfaces as ``StoreError`` subclasses raised at issue
time. Each error carries ``detect_after_s`` — the virtual time until the
*client* observes the failure (throttle responses come back quickly;
timeouts burn the full timeout budget) — so retry scheduling stays on
the deterministic event loop.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Tuple, runtime_checkable

import numpy as np

from repro.core.blob import ByteRange

MiB = 1024 ** 2


@dataclasses.dataclass
class StoreCosts:
    """Per-tier request + storage prices (defaults: S3 Standard,
    us-east-1 list prices, paper §5.1.4). See ``repro.core.costs.TierPrices``
    for the named tiers that produce these."""
    put_per_req: float = 0.005 / 1000
    get_per_req: float = 0.0004 / 1000
    storage_per_gb_month: float = 0.023
    hours_per_month: float = 730.0
    cross_az_per_gb: float = 0.0      # zonal tiers: cross-AZ GET routing

    def storage_cost_per_gb_hour(self) -> float:
        return self.storage_per_gb_month / self.hours_per_month


@dataclasses.dataclass
class LatencyModel:
    """T = lognormal(median = t0 + size/bw, sigma). Long-tail per Fig. 5."""
    put_t0_s: float = 0.200
    put_bw: float = 40 * MiB      # bytes/s transfer component of PUT
    get_t0_s: float = 0.030
    get_bw: float = 350 * MiB
    sigma: float = 0.42           # p95 ≈ 2.0× median, p99 ≈ 2.7× median

    def put_median(self, size: int) -> float:
        return self.put_t0_s + size / self.put_bw

    def get_median(self, size: int) -> float:
        return self.get_t0_s + size / self.get_bw

    def sample_put(self, size: int, rng: np.random.Generator) -> float:
        return float(self.put_median(size) *
                     np.exp(self.sigma * rng.standard_normal()))

    def sample_get(self, size: int, rng: np.random.Generator) -> float:
        return float(self.get_median(size) *
                     np.exp(self.sigma * rng.standard_normal()))


@dataclasses.dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    put_bytes: int = 0
    get_bytes: int = 0
    byte_seconds: float = 0.0     # integral of stored bytes over time
    cross_az_gets: int = 0        # reads routed out of the object's home AZ
    cross_az_get_bytes: int = 0   # bytes billed at cross_az_per_gb

    def cost_usd(self, costs: StoreCosts, retention_s: float = 0.0,
                 explicit_storage: bool = False) -> float:
        """Requests + cross-AZ routing + storage (byte·s integral, or
        puts×retention)."""
        c = self.puts * costs.put_per_req + self.gets * costs.get_per_req
        c += self.cross_az_get_bytes / 1e9 * costs.cross_az_per_gb
        if explicit_storage:
            gb_h = self.byte_seconds / 1e9 / 3600.0
        else:
            gb_h = self.put_bytes * retention_s / 1e9 / 3600.0
        return c + gb_h * costs.storage_per_gb_month / costs.hours_per_month


# -- fault taxonomy --------------------------------------------------------

class StoreError(Exception):
    """A failed store request, observed ``detect_after_s`` after issue.

    Raised at issue time (``put``/``get``/``begin_put``/``begin_get``)
    so the virtual-clock caller can schedule the failure observation and
    its retry deterministically. Failed requests are not billed and do
    not appear in ``StoreStats`` (AWS does not charge 5xx responses);
    injectors keep their own fault counters.
    """

    def __init__(self, msg: str, detect_after_s: float = 0.05,
                 retry_after_s: float = 0.0):
        super().__init__(msg)
        self.detect_after_s = detect_after_s
        self.retry_after_s = retry_after_s   # server backoff hint (503)


class SlowDownError(StoreError):
    """503 SlowDown: the per-prefix request-rate budget is exhausted."""


class TransientStoreError(StoreError):
    """500 / connection reset: safe to retry immediately-ish."""


class StoreTimeoutError(StoreError):
    """Client-side timeout: the tail exceeded the request deadline."""


# -- the protocol ----------------------------------------------------------

@runtime_checkable
class BlobStore(Protocol):
    """Structural interface every storage backend (and decorator) provides.

    ``az`` parameters identify the caller's availability zone; backends
    without AZ topology (S3 Standard's regional namespace) ignore them,
    zonal backends (Express One Zone) use them to price and delay
    cross-AZ access.
    """

    stats: StoreStats
    costs: StoreCosts
    retention_s: float

    # -- synchronous API (functional path) ---------------------------------
    def put(self, blob_id: str, data: bytes, now: float = 0.0,
            az: Optional[int] = None) -> float:
        """Store object; returns sampled completion latency (seconds)."""
        ...

    def get(self, blob_id: str, byte_range: Optional[ByteRange] = None,
            now: float = 0.0, az: Optional[int] = None
            ) -> Tuple[bytes, float]:
        """Fetch object (or ranged sub-object); returns (data, latency)."""
        ...

    # -- event-driven API (async engine path) ------------------------------
    def begin_put(self, blob_id: str, size: int, now: float = 0.0,
                  az: Optional[int] = None) -> float:
        """Start an async PUT; returns sampled latency. The object becomes
        durable only at ``finish_put`` — readers racing the upload must
        not observe it earlier."""
        ...

    def finish_put(self, blob_id: str, data: bytes, now: float,
                   az: Optional[int] = None) -> None:
        """Apply a completed PUT: object is durable as of ``now``."""
        ...

    def begin_get(self, blob_id: str, now: float = 0.0,
                  az: Optional[int] = None) -> Tuple[int, float]:
        """Start an async GET; returns (object size, sampled latency).
        Request accounting happens at issue time, like the real bill."""
        ...

    def payload(self, blob_id: str) -> bytes:
        """Raw object bytes (read at GET completion; never re-billed)."""
        ...

    # -- lifecycle ----------------------------------------------------------
    def run_retention(self, now: float) -> int:
        """Delete objects older than the retention period (paper §3.2)."""
        ...

    def accrue_storage(self, now: float) -> None:
        """Fold storage of still-live objects into ``stats.byte_seconds``
        up to ``now`` (idempotent: each byte·second is counted once)."""
        ...

    def contains(self, blob_id: str) -> bool:
        ...
