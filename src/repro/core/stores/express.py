"""S3 Express One Zone–style premium tier: zonal buckets, low latency.

Models a directory-bucket deployment with one bucket per AZ: a blob is
written to the writer's *home-AZ* bucket and single-digit-millisecond
access only holds within that AZ. A consumer in another AZ must route
the read via the home AZ and pays ``cross_az_penalty_s`` on top of the
sampled latency (and is counted in ``stats.cross_az_gets`` so cost
models can bill the crossing). Request and storage prices are the
premium-tier prices from ``repro.core.costs.EXPRESS_ONE_ZONE``.

With BlobShuffle's per-AZ batching (the Batcher already groups buffers
by destination AZ), most GETs are same-AZ — exactly the access pattern
this tier is priced for.
"""

from __future__ import annotations

from typing import Optional

from repro.core.stores.base import LatencyModel, StoreCosts
from repro.core.stores.simulated_s3 import SimulatedS3


def express_latency() -> LatencyModel:
    """Single-digit-ms first-byte latency, tighter tail than Standard."""
    return LatencyModel(put_t0_s=0.018, put_bw=220 * 1024 ** 2,
                        get_t0_s=0.004, get_bw=700 * 1024 ** 2,
                        sigma=0.22)


class ExpressOneZoneStore(SimulatedS3):
    """Zonal premium tier: per-AZ buckets, cross-AZ reads pay a penalty."""

    def __init__(self, latency: Optional[LatencyModel] = None,
                 costs: Optional[StoreCosts] = None, seed: int = 0,
                 retention_s: float = 3600.0, num_az: int = 3,
                 cross_az_penalty_s: float = 0.020):
        if costs is None:
            from repro.core.costs import EXPRESS_ONE_ZONE
            costs = EXPRESS_ONE_ZONE.store_costs()
        super().__init__(latency or express_latency(), costs, seed,
                         retention_s)
        self.num_az = num_az
        self.cross_az_penalty_s = cross_az_penalty_s

    def _sample_get(self, size: int, az: Optional[int],
                    blob_id: str) -> float:
        lat = super()._sample_get(size, az, blob_id)
        obj = self.objects.get(blob_id)
        home = obj.home_az if obj is not None else None
        if az is not None and home is not None and az != home:
            # routed via the home AZ: pay the inter-AZ round trip in
            # latency, and the per-GB routing charge on the bill
            self.stats.cross_az_gets += 1
            self.stats.cross_az_get_bytes += size
            lat += self.cross_az_penalty_s
        return lat
