"""Simulated S3 Standard: calibrated lognormal latency + cost accounting.

The latency model is calibrated to the paper's Fig. 5 (16 MiB objects,
us-east-1): long-tailed lognormal with size-dependent medians, PUT ≈ 7–9×
slower than GET, p95 ≈ 2.2× median. The cost model uses AWS list prices.
The store is append-only and garbage-tolerant: orphaned blobs are removed
by retention, never by readers (paper §3.1/§3.2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.blob import ByteRange
from repro.core.stores.base import LatencyModel, StoreCosts, StoreStats


@dataclasses.dataclass
class StoredObject:
    data: bytes
    put_at: float        # durability time (drives retention age)
    accrued_to: float    # storage already folded into byte_seconds up to here
    home_az: Optional[int] = None


class SimulatedS3:
    """In-memory object store with simulated latency + cost accounting.

    Implements ``BlobStore``: used both by the functional (unit-test)
    path — where operations are synchronous and latency is just
    *reported* — and by the discrete-event engine, which schedules
    completions at ``now + sampled latency``. S3 Standard has a regional
    namespace, so the ``az`` hints are accepted and ignored.
    """

    def __init__(self, latency: Optional[LatencyModel] = None,
                 costs: Optional[StoreCosts] = None, seed: int = 0,
                 retention_s: float = 3600.0):
        if costs is None:
            # single source of truth for tier prices: repro.core.costs
            from repro.core.costs import STANDARD
            costs = STANDARD.store_costs()
        self.latency = latency or LatencyModel()
        self.costs = costs
        self.rng = np.random.default_rng(seed)
        self.retention_s = retention_s
        self.objects: Dict[str, StoredObject] = {}
        self.stats = StoreStats()

    # -- synchronous API (functional path) --------------------------------
    def put(self, blob_id: str, data: bytes, now: float = 0.0,
            az: Optional[int] = None) -> float:
        lat = self.begin_put(blob_id, len(data), now, az)
        self.finish_put(blob_id, data, now, az)
        return lat

    def get(self, blob_id: str, byte_range: Optional[ByteRange] = None,
            now: float = 0.0, az: Optional[int] = None
            ) -> Tuple[bytes, float]:
        if blob_id not in self.objects:
            raise KeyError(f"no such object {blob_id} (expired or orphan?)")
        data = self.objects[blob_id].data
        if byte_range is not None:
            data = data[byte_range.offset:byte_range.end]
        self.stats.gets += 1
        self.stats.get_bytes += len(data)
        return data, self._sample_get(len(data), az, blob_id)

    # -- event-driven API (async engine path) ------------------------------
    def begin_put(self, blob_id: str, size: int, now: float = 0.0,
                  az: Optional[int] = None) -> float:
        return self._sample_put(size, az)

    def finish_put(self, blob_id: str, data: bytes, now: float,
                   az: Optional[int] = None) -> None:
        self.objects[blob_id] = StoredObject(data, now, now, az)
        self.stats.puts += 1
        self.stats.put_bytes += len(data)

    def begin_get(self, blob_id: str, now: float = 0.0,
                  az: Optional[int] = None) -> Tuple[int, float]:
        if blob_id not in self.objects:
            raise KeyError(f"no such object {blob_id} (expired or orphan?)")
        size = len(self.objects[blob_id].data)
        self.stats.gets += 1
        self.stats.get_bytes += size
        return size, self._sample_get(size, az, blob_id)

    def payload(self, blob_id: str) -> bytes:
        return self.objects[blob_id].data

    # -- lifecycle ----------------------------------------------------------
    def _accrue_object(self, o: StoredObject, now: float) -> None:
        """Fold ``o``'s storage into ``byte_seconds`` up to ``now``,
        capped at the object's expiry: an object stops billing at
        ``put_at + retention_s`` no matter when a sweep or the end-of-run
        accrual actually observes it, so the byte·seconds integral is
        invariant to sweep cadence and cannot double-bill the window
        between expiry and deletion."""
        end = min(now, o.put_at + self.retention_s)
        if end > o.accrued_to:
            self.stats.byte_seconds += len(o.data) * (end - o.accrued_to)
            o.accrued_to = end

    def run_retention(self, now: float) -> int:
        dead = [k for k, o in self.objects.items()
                if now - o.put_at > self.retention_s]
        for k in dead:
            self._accrue_object(self.objects.pop(k), now)
        return len(dead)

    def accrue_storage(self, now: float) -> None:
        for o in self.objects.values():
            self._accrue_object(o, now)

    def contains(self, blob_id: str) -> bool:
        return blob_id in self.objects

    def keys(self) -> list:
        """Namespace listing (S3 LIST analogue) — snapshot of live keys."""
        return list(self.objects)

    def delete(self, blob_id: str, now: float = 0.0) -> bool:
        """Explicit DELETE (beyond retention expiry): bills storage up to
        ``now`` then drops the object. Returns False if absent."""
        o = self.objects.pop(blob_id, None)
        if o is None:
            return False
        self._accrue_object(o, now)
        return True

    # -- latency sampling hooks (overridden by zonal subclasses) ------------
    def _sample_put(self, size: int, az: Optional[int]) -> float:
        return self.latency.sample_put(size, self.rng)

    def _sample_get(self, size: int, az: Optional[int],
                    blob_id: str) -> float:
        return self.latency.sample_get(size, self.rng)
