"""Pluggable object-storage backends behind the ``BlobStore`` protocol.

The dataflow core (cache, engine, pipeline, simulator) depends only on
``BlobStore``; concrete tiers plug in per deployment:

  * ``SimulatedS3``         — S3 Standard, calibrated lognormal latency;
  * ``ExpressOneZoneStore`` — zonal premium tier, low latency, cross-AZ
                              reads route via the home AZ;
  * ``FaultyStore``         — decorator injecting 503-SlowDown throttling
                              (per-prefix token bucket), transient
                              errors, and timeout tails over any backend.
"""

from repro.core.stores.base import (BlobStore, LatencyModel, SlowDownError,
                                    StoreCosts, StoreError, StoreStats,
                                    StoreTimeoutError, TransientStoreError)
from repro.core.stores.simulated_s3 import SimulatedS3, StoredObject
from repro.core.stores.express import ExpressOneZoneStore, express_latency
from repro.core.stores.faulty import FaultStats, FaultyStore

__all__ = [
    "BlobStore", "LatencyModel", "StoreCosts", "StoreStats",
    "StoreError", "SlowDownError", "TransientStoreError",
    "StoreTimeoutError", "SimulatedS3", "StoredObject",
    "ExpressOneZoneStore", "express_latency", "FaultStats", "FaultyStore",
]
