"""Discrete-event simulator of the full BlobShuffle pipeline (paper §5).

Simulates at blob granularity (events: blob fill → PUT completion →
notification → GET / cache → debatch) with per-record latencies sampled
within each blob's fill window — this reproduces the paper's latency
distributions (Fig. 5) and all sweeps (Figs. 6–9) in seconds of CPU time
instead of hours of cluster time.

Throughput uses the calibrated capacity model (ad-hoc throughput method:
offered load above capacity, processed rate = capacity).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import numpy as np

from repro.core.analytical import ModelParams
from repro.core.batcher import BlobShuffleConfig
from repro.core.capacity import CapacityModel
from repro.core.costs import (AwsPrices,
                              actual_batch_frac,
                              blobshuffle_cost_per_hour,
                              kafka_shuffle_cost_per_hour)
from repro.core.engine import AsyncShuffleEngine, EngineConfig
from repro.core.stores import BlobStore, LatencyModel, SimulatedS3
from repro.core.workload import WorkloadConfig, drive, generate

MiB = 1024 ** 2
GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_nodes: int = 12
    inst_per_node: int = 2
    n_az: int = 3
    partitions_factor: int = 9          # partitions = factor × instances
    record_bytes: int = 1024
    batch_bytes: int = 16 * MiB
    max_interval_s: float = 5.0
    commit_interval_s: float = 30.0     # Kafka Streams default commit cadence
    duration_s: float = 540.0           # steady-state window (paper: 9 min)
    warmup_s: float = 60.0
    latency_samples_per_blob: int = 4
    cache_on_write: bool = True
    seed: int = 0
    offered_gib_s: float = 3.16         # load generators (3.24M rec/s × 1KiB)
    wire_format: str = "raw-v1"         # registered blob wire format

    @property
    def n_inst(self) -> int:
        return self.n_nodes * self.inst_per_node

    @property
    def partitions(self) -> int:
        return self.partitions_factor * self.n_inst


@dataclasses.dataclass
class SimResult:
    throughput_bytes_s: float
    shuffle_latencies: np.ndarray      # sampled per-record latencies
    put_latencies: np.ndarray
    get_latencies: np.ndarray
    puts_per_s: float
    gets_per_s: float
    notifications_per_s: float
    cache_reads_per_s: float
    mean_actual_batch: float
    s3_cost_per_hour: float            # at simulated throughput, 1h retention
    s3_cost_per_hour_at_1gib: float    # normalized to 1 GiB/s
    infra_cost_per_hour_at_1gib: float
    kafka_cost_per_hour_at_1gib: float

    def latency_p(self, q: float) -> float:
        return float(np.percentile(self.shuffle_latencies, q))

    @property
    def total_cost_at_1gib(self) -> float:
        return self.s3_cost_per_hour_at_1gib + self.infra_cost_per_hour_at_1gib


def simulate_async(cfg: SimConfig, *, engine_cfg: Optional[EngineConfig]
                   = None, scale: float = 0.01, exactly_once: bool = False,
                   key_skew: float = 0.5,
                   latency: Optional[LatencyModel] = None,
                   store: Optional[BlobStore] = None,
                   ingest_batch_records: Optional[int] = None,
                   strategy=None, obs=None
                   ) -> "tuple[AsyncShuffleEngine, dict]":
    """Measured (not modeled) run of a ``SimConfig`` workload through the
    event-driven engine, scaled down by ``scale`` in offered rate and
    batch size so the per-record simulation stays cheap. Returns the
    engine (for store/cache stats) and its metrics summary — the async
    counterpart of ``simulate``'s analytical percentiles.

    ``store`` swaps the storage backend (any ``BlobStore``: another
    tier, or a ``FaultyStore``-wrapped one for degraded-store runs);
    default is ``SimulatedS3`` with the calibrated ``latency`` model.

    ``ingest_batch_records`` switches the driver to the columnar ingest
    lane: records enter as ``RecordBatch`` micro-batches of that many
    consecutive arrivals (vectorized partition + binning in the Batcher)
    instead of one event per record.

    ``strategy`` selects a shuffle policy (None | registered name |
    ``ShuffleStrategy`` instance — see ``repro.core.strategy``):
    "combining" pre-aggregates hot keys map-side, "push" places blobs
    destination-AZ-local, "merge" runs the two-round compactor.

    ``obs`` enables the observability layer (None | True | ObsConfig |
    Observability — see ``repro.obs``); read it back as ``engine.obs``.
    """
    bcfg = BlobShuffleConfig(
        batch_bytes=max(int(cfg.batch_bytes * scale), 64 * 1024),
        max_interval_s=cfg.max_interval_s,
        num_partitions=cfg.partitions, num_az=cfg.n_az,
        cache_on_write=cfg.cache_on_write, wire_format=cfg.wire_format)
    wl = WorkloadConfig(
        arrival_rate=cfg.offered_gib_s * GiB * scale / cfg.record_bytes,
        duration_s=min(cfg.duration_s, 10.0),
        record_bytes=cfg.record_bytes, key_skew=key_skew, seed=cfg.seed)
    if store is None:
        store = SimulatedS3(latency=latency or LatencyModel(),
                            seed=cfg.seed)
    eng = AsyncShuffleEngine(
        bcfg, engine_cfg or EngineConfig(
            commit_interval_s=cfg.commit_interval_s),
        n_instances=cfg.n_inst, store=store, seed=cfg.seed,
        exactly_once=exactly_once, strategy=strategy, obs=obs)
    drive(eng, wl, batch_records=ingest_batch_records)
    metrics = eng.run()
    return eng, metrics.summary(store)


def simulate_elastic(cfg: SimConfig, *,
                     engine_cfg: Optional[EngineConfig] = None,
                     scale: float = 0.01, mode: str = "cooperative",
                     autoscale: bool = True, policy=None,
                     spike_factor: float = 3.0,
                     phases: Optional[List[tuple]] = None,
                     crash_at: Optional[float] = None,
                     crash_worker: str = "w1",
                     az_outage_at: Optional[float] = None,
                     az_outage: int = 0,
                     heartbeat_timeout_s: float = 0.25,
                     exactly_once: bool = True,
                     store: Optional[BlobStore] = None,
                     max_sim_s: float = 10.0,
                     strategy=None, obs=None
                     ) -> "tuple[AsyncShuffleEngine, object, dict]":
    """Elastic scenario through the cluster subsystem: phased offered
    load (default steady → ``spike_factor``× spike → steady, driving the
    autoscaler), plus optional worker crash and AZ outage. Returns
    (engine, cluster, summary) where the summary extends
    ``simulate_async``'s with elasticity metrics (workers, rebalances,
    partitions moved, replayed entries, infra $).

    ``phases`` overrides the load shape: a list of ``(rate_factor,
    duration_s)`` segments at the scaled base rate. Like
    ``simulate_async``, the per-record simulation clamps the scenario to
    ``max_sim_s`` seconds of virtual load — raise it explicitly for
    long-horizon scenarios.
    """
    from repro.cluster import AutoscalePolicy, ElasticCluster
    bcfg = BlobShuffleConfig(
        batch_bytes=max(int(cfg.batch_bytes * scale), 64 * 1024),
        max_interval_s=cfg.max_interval_s,
        num_partitions=cfg.partitions, num_az=cfg.n_az,
        cache_on_write=cfg.cache_on_write, wire_format=cfg.wire_format)
    base_rate = cfg.offered_gib_s * GiB * scale / cfg.record_bytes
    duration = min(cfg.duration_s, max_sim_s)
    if phases is None:
        phases = [(1.0, 0.3 * duration), (spike_factor, 0.4 * duration),
                  (1.0, 0.3 * duration)]
    if store is None:
        store = SimulatedS3(latency=LatencyModel(), seed=cfg.seed)
    eng = AsyncShuffleEngine(
        bcfg, engine_cfg or EngineConfig(
            commit_interval_s=min(cfg.commit_interval_s, 1.0)),
        n_instances=cfg.n_inst, store=store, seed=cfg.seed,
        exactly_once=exactly_once, strategy=strategy, obs=obs)
    cluster = ElasticCluster(
        eng, mode=mode, heartbeat_timeout_s=heartbeat_timeout_s,
        autoscale=(policy or AutoscalePolicy()) if autoscale else None)
    t0 = 0.0
    for k, (factor, dur) in enumerate(phases):
        wl = WorkloadConfig(arrival_rate=base_rate * factor,
                            duration_s=dur,
                            record_bytes=cfg.record_bytes,
                            seed=cfg.seed + k)
        for t, rec in generate(wl):
            eng.submit(t0 + t, rec)
        t0 += dur
    if crash_at is not None:
        cluster.crash_worker_at(crash_at, crash_worker)
    if az_outage_at is not None:
        cluster.az_outage_at(az_outage_at, az_outage)
    metrics = eng.run()
    s = metrics.summary(store)
    events = [e for e in cluster.rebalancer.events if not e.superseded]
    s.update({
        "workers_final": float(len(cluster.membership.alive())),
        "rebalances": float(len(events)),
        "partitions_moved": float(cluster.rebalancer.partitions_moved),
        "replayed_entries": float(cluster.stats.replayed_entries),
        "handoff_duplicates_dropped":
            float(cluster.stats.handoff_duplicates_dropped),
        "lag_final": float(cluster.total_lag()),
        "infra_cost_usd": cluster.infra_cost_usd(),
        "scale_decisions": float(
            len(cluster.autoscaler.decisions) if cluster.autoscaler
            else 0),
    })
    return eng, cluster, s


def simulate(cfg: SimConfig, capacity: Optional[CapacityModel] = None,
             latency: Optional[LatencyModel] = None) -> SimResult:
    cap = capacity or CapacityModel()
    lat = latency or LatencyModel()
    rng = np.random.default_rng(cfg.seed)

    # --- steady-state throughput: ad-hoc = min(offered, capacity) -------
    tput = min(cfg.offered_gib_s * GiB,
               cap.max_throughput(cfg.batch_bytes / MiB, cfg.partitions,
                                  cfg.n_inst, cfg.n_az))
    b_inst = tput / cfg.n_inst                      # bytes/s per instance
    fill_rate_per_az = b_inst / cfg.n_az            # bytes/s per AZ buffer

    # --- blob-level event simulation -----------------------------------
    t_end = cfg.duration_s
    shuffle_lat: List[float] = []
    put_lat: List[float] = []
    get_lat: List[float] = []
    n_blobs = 0
    n_gets = 0
    n_notes = 0
    n_cache_reads = 0
    blob_sizes: List[int] = []
    parts_per_az = max(cfg.partitions // cfg.n_az, 1)

    # per (instance, target_az) buffer state advances deterministically;
    # we iterate blob completions instance-by-instance for the window.
    for inst in range(cfg.n_inst):
        my_az = inst % cfg.n_az
        for target_az in range(cfg.n_az):
            t = cfg.warmup_s + rng.uniform(0, 1)     # desynchronize
            next_commit = (math.floor(t / cfg.commit_interval_s) + 1) \
                * cfg.commit_interval_s
            while t < t_end:
                t_fill_full = cfg.batch_bytes / fill_rate_per_az
                # commits finalize early (Fig. 6g: actual < target)
                fill_end = t + min(t_fill_full, cfg.max_interval_s)
                if fill_end > next_commit:
                    fill_end = next_commit
                    next_commit += cfg.commit_interval_s
                fill_time = fill_end - t
                size = int(fill_rate_per_az * fill_time)
                if size <= 0:
                    t = fill_end + 1e-3
                    continue
                blob_sizes.append(size)
                n_blobs += 1
                tp = lat.sample_put(size, rng)
                put_lat.append(tp)
                # notifications: one per partition present in the blob
                n_notes += parts_per_az
                n_cache_reads += parts_per_az
                # cross-AZ consumers GET once (single-flight); same-AZ hits
                # the cache-on-write copy.
                crosses = target_az != my_az
                if crosses:
                    tg = lat.sample_get(size, rng)
                    get_lat.append(tg)
                    n_gets += 1
                else:
                    tg = 0.0005
                # sample record latencies: record arrives uniformly in the
                # fill window; waits (fill_end - arrival) + put + get
                for _ in range(cfg.latency_samples_per_blob):
                    wait = rng.uniform(0, fill_time)
                    shuffle_lat.append(wait + tp + tg + 0.01)
                t = fill_end
    window = t_end - cfg.warmup_s

    p = ModelParams(n_inst=cfg.n_inst, n_az=cfg.n_az,
                    rate=tput / cfg.record_bytes, s_rec=cfg.record_bytes,
                    s_batch=cfg.batch_bytes)
    frac = float(np.mean(blob_sizes)) / cfg.batch_bytes if blob_sizes else 1.0
    bs_cost = blobshuffle_cost_per_hour(p, actual_batch_frac=frac)
    # normalized to 1 GiB/s processing rate (paper Figs. 6h/6i/7)
    p1 = ModelParams(n_inst=cfg.n_inst, n_az=cfg.n_az,
                     rate=GiB / cfg.record_bytes, s_rec=cfg.record_bytes,
                     s_batch=cfg.batch_bytes)
    bs_cost_1g = blobshuffle_cost_per_hour(p1, actual_batch_frac=frac)
    prices = AwsPrices()
    node_cost = cfg.n_nodes * prices.ec2_r6in_xlarge_hour
    infra_1g = node_cost / (tput / GiB)
    kafka_1g = kafka_shuffle_cost_per_hour(p1)

    return SimResult(
        throughput_bytes_s=tput,
        shuffle_latencies=np.asarray(shuffle_lat),
        put_latencies=np.asarray(put_lat),
        get_latencies=np.asarray(get_lat),
        puts_per_s=n_blobs / window,
        gets_per_s=n_gets / window,
        notifications_per_s=n_notes / window,
        cache_reads_per_s=n_cache_reads / window,
        mean_actual_batch=frac,
        s3_cost_per_hour=bs_cost.s3_total,
        s3_cost_per_hour_at_1gib=bs_cost_1g.s3_total,
        infra_cost_per_hour_at_1gib=infra_1g,
        kafka_cost_per_hour_at_1gib=kafka_1g,
    )
