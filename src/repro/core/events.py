"""Virtual-clock discrete-event scheduler for the async shuffle engine.

A minimal deterministic event loop: callbacks are ordered by (time,
insertion sequence), so ties resolve in scheduling order and a run with a
fixed RNG seed is exactly reproducible. All simulated concurrency in
``repro.core.engine`` (in-flight PUTs/GETs, notification fan-out, cache
fills racing reads, commit barriers) reduces to events on this loop.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Any, Callable, List, Optional, Tuple


class EventLoop:
    """Single-threaded virtual-time event loop.

    Time only moves forward: scheduling at a time earlier than ``now``
    clamps to ``now`` (the event still runs, just "immediately").
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)
        self._heap: List[Tuple[float, int, Callable, Tuple[Any, ...]]] = []
        self._seq = itertools.count()
        self.events_run = 0

    def at(self, t: float, fn: Callable, *args: Any) -> None:
        """Schedule ``fn(*args)`` at absolute virtual time ``t``."""
        heapq.heappush(self._heap, (max(float(t), self.now),
                                    next(self._seq), fn, args))

    def after(self, delay: float, fn: Callable, *args: Any) -> None:
        """Schedule ``fn(*args)`` ``delay`` seconds from now (>= 0)."""
        self.at(self.now + max(0.0, float(delay)), fn, *args)

    def pending(self) -> int:
        return len(self._heap)

    def run(self, until: Optional[float] = None) -> float:
        """Run events in order until the heap drains (or past ``until``).

        Returns the loop's final virtual time (the makespan when the heap
        drained).
        """
        while self._heap:
            t = self._heap[0][0]
            if until is not None and t > until:
                break
            t, _, fn, args = heapq.heappop(self._heap)
            self.now = t
            self.events_run += 1
            fn(*args)
        return self.now
