"""Pluggable shuffle strategies (Exoshuffle-style policies, ROADMAP 3).

Exoshuffle's thesis is that the classic shuffle variants — map-side
pre-aggregation, push-based placement, multi-round merge — are
*library-level policies* over one exchange substrate, not engine
rewrites. This module is that seam for BlobShuffle: a small hook
protocol the ``AsyncShuffleEngine`` consults at four points of the
blob lifecycle, with the current behavior re-homed as
``DefaultStrategy`` (every hook is the identity — a default-strategy
run is bit-identical to the pre-seam engine, event for event).

Hook points (all invoked on the virtual clock, all deterministic):

  * ``prepare_batch`` — before a ``RecordBatch`` enters the batcher
    (and before arrival-latency bookkeeping). ``CombiningStrategy``
    pre-aggregates duplicate keys here with a declared deterministic
    combiner, shrinking shipped bytes under Zipf skew.
  * ``partition_target_az`` — destination-AZ routing for a partition's
    buffer/blob. ``PushStrategy`` threads the *cluster assignor's*
    current owner AZ through here so blobs land where their consumer
    actually runs.
  * ``put_az`` / ``fill_az`` — which AZ a finalized blob is PUT from /
    cache-filled into. Push-based placement writes into the
    destination AZ's zonal store + cache, so consumers read
    zonal-local from ``ExpressOneZoneStore`` with zero cross-AZ GETs
    (the cross-AZ *routing* bytes are surfaced in
    ``StrategyStats.push_cross_az_bytes`` and priced by the caller).
  * ``on_publish`` — notification interception.
    ``TwoRoundMergeStrategy`` parks small-blob notifications here and
    a background compactor coalesces them into one merged
    per-partition blob (Magnet/Riffle-style two-round merge), cutting
    notification and GET request counts by the merge fan-in.

Exactly-once is preserved by construction: strategies act strictly
upstream of the commit protocol (combining) or strictly downstream of
durable publication (merge — small blobs are already durable and
committed before their notifications are intercepted; the compactor
re-publishes exactly one merged notification per round or falls back
to delivering the originals if any merge step fails permanently).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.blob import (Blob, Notification, build_blob_from_buffers,
                             extract_batch)
from repro.core.formats import detect_format
from repro.core.recordbatch import RecordBatch
from repro.core.stores import StoreError


@dataclasses.dataclass
class StrategyStats:
    """Per-run strategy-side counters (engine/store stats stay the
    source of truth for PUT/GET/byte accounting)."""
    # combining
    records_combined: int = 0        # input records merged away
    bytes_saved_logical: int = 0     # wire bytes removed pre-upload
    # push-based placement: blob bytes routed from the producer's AZ
    # into a different (destination) AZ at PUT time — the zonal store
    # only sees the placement AZ, so this cross-AZ routing volume is
    # surfaced here for the cost model
    push_cross_az_bytes: int = 0
    # two-round merge
    merge_rounds: int = 0
    merged_blobs: int = 0            # merged blobs published
    merged_blob_bytes: int = 0       # bytes the compactor PUT (conservation)
    merged_inputs: int = 0           # small blobs coalesced into them
    merge_cache_hits: int = 0        # compactor reads served zonally
    merge_store_gets: int = 0        # compactor reads that hit the store
    merge_fallback_notes: int = 0    # originals delivered after a failure
    merge_singles: int = 0           # lone notes passed through unmerged
    notes_intercepted: int = 0       # notifications parked by on_publish


class ShuffleStrategy:
    """Default (pass-through) strategy — the pre-seam engine behavior.

    Subclasses override individual hooks; every hook here is the exact
    identity the engine inlined before the seam existed, so running
    with ``DefaultStrategy`` is bit-identical to not having one.
    """

    name = "default"

    def __init__(self) -> None:
        self.engine = None
        self.stats = StrategyStats()

    def bind(self, engine) -> None:
        """Attach to the engine (called once from the engine ctor)."""
        self.engine = engine

    # -- ingest -----------------------------------------------------------
    def prepare_batch(self, batch: RecordBatch,
                      times: Optional[np.ndarray]
                      ) -> Tuple[RecordBatch, Optional[np.ndarray]]:
        """Transform a micro-batch before partitioning/buffering.
        Returns the (possibly smaller) batch and its aligned arrival
        times; must be deterministic."""
        return batch, times

    # -- placement --------------------------------------------------------
    def partition_target_az(self, partition: int) -> int:
        """Destination AZ used for buffering + blob target of
        ``partition`` (consulted through ``Batcher.partition_to_az``)."""
        return self.engine.partition_to_az(partition)

    def put_az(self, blob: Blob, inst_az: int) -> int:
        """AZ the store PUT is attributed to (zonal stores home the
        object there)."""
        return inst_az

    def fill_az(self, blob: Blob, inst_az: int) -> int:
        """AZ whose distributed cache receives the write-through fill."""
        return inst_az

    # -- notification path ------------------------------------------------
    def on_publish(self, note: Notification, inst: Optional[int]) -> bool:
        """Intercept a to-be-published notification. Return True to
        consume it (the strategy takes responsibility for eventual
        delivery or an explicit drop); False routes it normally."""
        return False

    # -- lifecycle ---------------------------------------------------------
    def on_assignment_changed(self) -> None:
        """Cluster partition assignment changed (rebalance completed)."""

    def work_pending(self) -> bool:
        """True while the strategy still has deferred work in flight
        (keeps the engine's retention sweep alive)."""
        return False


DefaultStrategy = ShuffleStrategy


# -- map-side combining ----------------------------------------------------

def _group_keys(batch: RecordBatch) -> Tuple[np.ndarray, int]:
    """(inverse, n_groups): per-row group id over distinct key bytes.

    Fixed-width keys dedup as a void view of the arena (one
    ``np.unique``); ragged keys fall back to a dict memo. Mirrors
    ``Batcher._partitions_by_unique_key`` so grouping is bit-stable
    with the partitioner's own dedup."""
    n = len(batch)
    if n == 0:
        return np.empty(0, np.int64), 0
    klen = np.diff(batch.key_offsets)
    if (klen == klen[0]).all() and klen[0] > 0:
        kw = int(klen[0])
        base = int(batch.key_offsets[0])
        arena = np.ascontiguousarray(batch.key_arena)
        rows = arena[base:base + n * kw].reshape(n, kw) \
            .view(np.dtype((np.void, kw)))[:, 0]
        _, inv = np.unique(rows, return_inverse=True)
        return inv.astype(np.int64, copy=False), int(inv.max()) + 1
    memo: Dict[bytes, int] = {}
    inv = np.empty(n, np.int64)
    for i in range(n):
        inv[i] = memo.setdefault(bytes(batch.key(i)), len(memo))
    return inv, len(memo)


def _last_occurrence(inv: np.ndarray, n_groups: int) -> np.ndarray:
    """Row index of each group's LAST occurrence, in ascending row
    order — the canonical representative set for stream semantics
    (latest record per key wins the timestamp)."""
    last = np.zeros(n_groups, np.int64)
    np.maximum.at(last, inv, np.arange(len(inv), dtype=np.int64))
    return np.sort(last)


class LastWinsCombiner:
    """Keep only the newest record per key (KTable upsert semantics —
    intermediate values for a key are superseded within the batch)."""

    name = "last-wins"

    def combine(self, batch: RecordBatch
                ) -> Tuple[Optional[RecordBatch], Optional[np.ndarray]]:
        """Returns (combined batch, kept-row indices) or (None, None)
        when no combining applies."""
        inv, g = _group_keys(batch)
        if g == len(batch):
            return None, None
        sel = _last_occurrence(inv, g)
        return batch.select(sel), sel


class SumU64Combiner:
    """Sum values as little-endian u64 word vectors per key (the
    wrap-around modular sum a windowed counter/aggregator would keep).
    Applies only to the headerless uniform-width shape whose value
    width is a multiple of 8; anything else passes through unchanged."""

    name = "sum-u64"

    def combine(self, batch: RecordBatch
                ) -> Tuple[Optional[RecordBatch], Optional[np.ndarray]]:
        n = len(batch)
        if n == 0 or batch.headers is not None:
            return None, None
        vlen = np.diff(batch.value_offsets)
        if not (vlen == vlen[0]).all():
            return None, None
        vw = int(vlen[0])
        if vw == 0 or vw % 8:
            return None, None
        if (int(batch.value_offsets[0]) != 0
                or int(batch.value_arena.size) != int(batch.value_offsets[-1])):
            return None, None
        inv, g = _group_keys(batch)
        if g == n:
            return None, None
        words = np.ascontiguousarray(batch.value_arena) \
            .reshape(n, vw).view("<u8")
        acc = np.zeros((g, vw // 8), np.uint64)
        np.add.at(acc, inv, words.astype(np.uint64, copy=False))
        sel = _last_occurrence(inv, g)
        out = batch.select(sel)
        va = np.ascontiguousarray(acc[inv[sel]].astype("<u8")) \
            .view(np.uint8).reshape(-1)
        return RecordBatch(out.key_offsets, out.key_arena,
                           out.value_offsets, va, out.timestamps,
                           None, None), sel


COMBINERS = {c.name: c for c in (LastWinsCombiner, SumU64Combiner)}


class CombiningStrategy(ShuffleStrategy):
    """Map-side combining: pre-aggregate duplicate keys inside each
    ingest micro-batch *before* partitioning, buffering, and latency
    bookkeeping. Under Zipf skew a handful of hot keys dominate the
    byte volume, so this directly shrinks shipped logical bytes (and
    every downstream PUT/GET/cache byte) at zero wire-format cost.

    Delivery differs from the default strategy only by the declared
    combiner — a deterministic, per-batch pure function — so runs stay
    bit-reproducible and auditable against a reference combine of the
    same input batches."""

    name = "combining"

    def __init__(self, combiner=None) -> None:
        super().__init__()
        if isinstance(combiner, str):
            combiner = COMBINERS[combiner]()
        self.combiner = combiner or LastWinsCombiner()

    def prepare_batch(self, batch, times):
        n = len(batch)
        if n <= 1:
            return batch, times
        out, sel = self.combiner.combine(batch)
        if out is None or len(out) == n:
            return batch, times
        st = self.stats
        st.records_combined += n - len(out)
        st.bytes_saved_logical += int(batch.serialized_sizes().sum()
                                      - out.serialized_sizes().sum())
        if times is not None:
            times = np.asarray(times, np.float64)[sel]
        return out, times


# -- push-based placement --------------------------------------------------

class PushStrategy(ShuffleStrategy):
    """Push-based shuffle: place every blob in its *destination* AZ.

    The default strategy PUTs from the producer's AZ (zonal stores
    home the object there; the write-through cache fill lands in the
    producer's cluster), so 2/3 of blobs are consumed cross-AZ — on
    ``ExpressOneZoneStore`` each such blob leads one cross-AZ store
    GET. Pushing instead homes the object *and* the cache fill in
    ``blob.target_az``: every consumer read is zonal (zero cross-AZ
    GETs); the producer pays the routing bytes once at PUT time,
    surfaced in ``stats.push_cross_az_bytes`` for the cost model.

    With an ``ElasticCluster`` attached, the destination AZ tracks the
    *assignor's current owner* of each partition (re-snapshotted after
    every completed rebalance via ``on_assignment_changed``), so blobs
    follow their consumer even when ownership moves cross-AZ."""

    name = "push"

    def put_az(self, blob, inst_az):
        return blob.target_az

    def fill_az(self, blob, inst_az):
        return blob.target_az

    def partition_target_az(self, partition):
        eng = self.engine
        cl = eng.cluster
        if cl is not None:
            st = cl.parts.get(partition)
            owner = st.owner if st is not None else None
            if owner is not None and cl.membership.is_alive_now(owner):
                return cl.membership.workers[owner].az
        return eng.partition_to_az(partition)


# -- two-round merge -------------------------------------------------------

class _MergeRound:
    __slots__ = ("partition", "az", "notes", "payloads", "remaining",
                 "failed")

    def __init__(self, partition: int, notes: List[Notification]):
        self.partition = partition
        self.az = notes[-1].target_az
        self.notes = notes
        self.payloads: List[Optional[bytes]] = [None] * len(notes)
        self.remaining = len(notes)
        self.failed = False


class TwoRoundMergeStrategy(PushStrategy):
    """Two-round merge (Magnet/Riffle-style push-merge) for huge
    fan-in: many small per-batcher blobs are coalesced into one
    per-partition merged blob by a background compactor running on the
    virtual clock in the destination AZ.

    Round one is push-based placement (inherited): small blobs are
    homed + cache-filled in their destination AZ, so the compactor's
    reads are zonal cache hits, not extra store traffic. Round two
    intercepts the smalls' notifications (``on_publish``), groups them
    per partition, and once ``fan_in`` notes accumulate — or
    ``max_wait_s`` elapses — reads the byte ranges, concatenates the
    record blocks (decoding + re-encoding only when blocks are
    framed), PUTs one merged blob, and publishes a single merged
    notification. Consumers therefore issue ~``1/fan_in`` of the
    default strategy's notifications and GETs.

    Exactly-once: interception happens strictly *after* the smalls are
    durable and their producer's commit has published them, so the
    commit protocol is untouched; the merged notification inherits the
    smalls' (blob, partition) dedup domain under a fresh blob id, and
    any permanent failure in the merge pipeline (fetch or PUT past
    ``max_attempts``, expired blob) falls back to delivering the
    original notifications unchanged — never silently dropping them.
    End-to-end latency accounting survives the rewrite: the smalls'
    arrival FIFOs are re-homed under the merged blob id the moment it
    becomes durable."""

    name = "merge"

    def __init__(self, fan_in: int = 8, max_wait_s: float = 0.25) -> None:
        super().__init__()
        self.fan_in = fan_in
        self.max_wait_s = max_wait_s
        self._pending: Dict[int, List[Notification]] = {}
        self._armed: Set[int] = set()
        self._active = 0
        self._seq = 0

    # -- interception ------------------------------------------------------
    def on_publish(self, note, inst):
        self.stats.notes_intercepted += 1
        buf = self._pending.setdefault(note.partition, [])
        buf.append(note)
        if len(buf) >= self.fan_in:
            self._start_round(note.partition)
        elif note.partition not in self._armed:
            self._armed.add(note.partition)
            self.engine.loop.after(self.max_wait_s, self._wait_fire,
                                   note.partition)
        return True

    def _wait_fire(self, partition: int) -> None:
        self._armed.discard(partition)
        if self._pending.get(partition):
            self._start_round(partition)

    def work_pending(self):
        return bool(self._pending) or self._active > 0

    # -- round one: gather the smalls (zonal reads) ------------------------
    def _start_round(self, partition: int) -> None:
        notes = self._pending.pop(partition)
        self.stats.merge_rounds += 1
        if len(notes) == 1:
            self.stats.merge_singles += 1
            self._deliver(notes)      # nothing to merge
            return
        r = _MergeRound(partition, notes)
        self._active += 1
        for idx in range(len(notes)):
            self._fetch_small(r, idx, 0)

    def _fetch_small(self, r: _MergeRound, idx: int, attempt: int,
                     grace: bool = True) -> None:
        if r.failed:
            return
        eng = self.engine
        note = r.notes[idx]
        cache = eng.caches[note.target_az]
        hit = cache.probe(note.blob_id)
        if hit is not None:
            self.stats.merge_cache_hits += 1
            eng.loop.after(eng.ecfg.rpc_latency_s,
                           self._small_ready, r, idx, hit)
            return
        if grace:
            # a commit-time publish can land at the same instant the
            # small became durable — one fill latency BEFORE its
            # write-through fill reaches the zonal cache. Re-probe once
            # after that window instead of leading a redundant store GET.
            eng.loop.after(eng.ecfg.cache_fill_latency_s
                           + eng.ecfg.rpc_latency_s,
                           self._fetch_small, r, idx, attempt, False)
            return
        cache.note_miss(coalesced=False)
        try:
            _, lat = cache.begin_store_get(note.blob_id, now=eng.loop.now)
        except StoreError as e:
            if attempt + 1 >= eng.ecfg.max_attempts:
                self._fail_round(r)
                return
            eng.metrics.get_retries += 1
            delay = eng._backoff(attempt + 1, e)
            eng.loop.after(e.detect_after_s + delay,
                           self._fetch_small, r, idx, attempt + 1)
            return
        except KeyError:
            self._fail_round(r)       # expired: merging cannot help
            return
        self.stats.merge_store_gets += 1
        eng._note_get_latency(lat)
        eng.loop.after(lat, self._small_got, r, idx)

    def _small_got(self, r: _MergeRound, idx: int) -> None:
        if r.failed:
            return
        eng = self.engine
        note = r.notes[idx]
        try:
            payload = eng.store.payload(note.blob_id)
        except KeyError:
            self._fail_round(r)
            return
        eng.caches[note.target_az].fill(note.blob_id, payload)
        self._small_ready(r, idx, payload)

    def _small_ready(self, r: _MergeRound, idx: int, payload) -> None:
        if r.failed:
            return
        r.payloads[idx] = payload
        r.remaining -= 1
        if r.remaining == 0:
            self._build_merged(r)

    # -- round two: merged blob --------------------------------------------
    def _build_merged(self, r: _MergeRound) -> None:
        eng = self.engine
        fmt = eng.batchers[0].fmt if eng.batchers else None
        chunks = []
        for note, payload in zip(r.notes, r.payloads):
            rng = note.byte_range
            block = memoryview(payload)[rng.offset:rng.end]
            if fmt is None and detect_format(block).format_id == 1:
                chunks.append(block)  # raw-in, raw-out: byte identity
            else:
                chunks.append(extract_batch(payload, rng).serialize_rows())
        self._seq += 1
        bid = f"merge-p{r.partition}-{self._seq:06d}"
        blob, notes = build_blob_from_buffers(
            {r.partition: chunks}, target_az=r.az, blob_id=bid, fmt=fmt)
        if eng.obs is not None:
            # the merged blob's lifecycle restarts here: batch_wait for
            # its records absorbs the smalls' whole first-round journey
            eng.obs.on_blob_handed_off(blob, r.az, None, eng.loop.now)
        self._put_merged(r, blob, notes[0], 0)

    def _put_merged(self, r: _MergeRound, blob: Blob,
                    mnote: Notification, attempt: int) -> None:
        eng = self.engine
        try:
            lat = eng.store.begin_put(blob.blob_id, blob.size,
                                      now=eng.loop.now, az=r.az)
        except StoreError as e:
            if attempt + 1 >= eng.ecfg.max_attempts:
                self._fail_round(r)
                return
            eng.metrics.put_retries += 1
            delay = eng._backoff(attempt + 1, e)
            eng.loop.after(e.detect_after_s + delay,
                           self._put_merged, r, blob, mnote, attempt + 1)
            return
        eng.loop.after(lat, self._merged_durable, r, blob, mnote, lat)

    def _merged_durable(self, r: _MergeRound, blob: Blob,
                        mnote: Notification, lat: float) -> None:
        eng = self.engine
        eng.store.finish_put(blob.blob_id, blob.payload, eng.loop.now,
                             az=r.az)
        eng.metrics.put_latencies.append(lat)
        self.stats.merged_blob_bytes += blob.size
        if eng.obs is not None:
            eng.obs.on_blob_durable(blob.blob_id, blob.size, r.az, lat,
                                    eng.loop.now)
        if eng.cfg.cache_on_write:
            eng.loop.after(eng.ecfg.cache_fill_latency_s,
                           eng.caches[r.az].fill, blob.blob_id,
                           blob.payload)
        # re-home the smalls' arrival FIFOs under the merged blob id so
        # end-to-end latency accounting (and duplicate detection) keeps
        # working across the rewrite
        arrivals: List[float] = []
        for note in r.notes:
            arrivals.extend(eng._blob_arrivals.pop(
                (note.blob_id, note.partition), []))
        eng._blob_arrivals[(blob.blob_id, r.partition)] = arrivals
        self.stats.merged_blobs += 1
        self.stats.merged_inputs += len(r.notes)
        self._active -= 1
        self._deliver([mnote], src_az=r.az)

    # -- delivery ----------------------------------------------------------
    def _fail_round(self, r: _MergeRound) -> None:
        if r.failed:
            return
        r.failed = True
        self._active -= 1
        self.stats.merge_fallback_notes += len(r.notes)
        self._deliver(r.notes)

    def _deliver(self, notes: List[Notification],
                 src_az: Optional[int] = None) -> None:
        """Publish notifications downstream, bypassing ``on_publish``
        (these are the strategy's own outputs, not new smalls)."""
        eng = self.engine
        for note in notes:
            eng.published.append(note)
            if eng.obs is not None:
                eng.obs.on_note_published(note, eng.loop.now)
            if eng.cluster is not None:
                eng.cluster.publish(note, src_az)
            else:
                eng.loop.after(eng.ecfg.notification_latency_s,
                               eng._notify, note)


# -- registry --------------------------------------------------------------

STRATEGIES = {
    "default": DefaultStrategy,
    "combining": CombiningStrategy,
    "push": PushStrategy,
    "merge": TwoRoundMergeStrategy,
}


def make_strategy(spec=None, **kwargs) -> ShuffleStrategy:
    """Resolve ``spec`` (None | name | instance) into a strategy."""
    if spec is None:
        return DefaultStrategy()
    if isinstance(spec, ShuffleStrategy):
        return spec
    try:
        cls = STRATEGIES[spec]
    except KeyError:
        raise ValueError(
            f"unknown shuffle strategy {spec!r}; "
            f"registered: {sorted(STRATEGIES)}") from None
    return cls(**kwargs)
