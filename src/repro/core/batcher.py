"""Batcher operator (paper §3.1, Fig. 2).

Per destination partition, an in-memory buffer of serialized records;
buffers of partitions in the same destination AZ are grouped so the
accumulated size per AZ is tracked. A batch is finalized when
  (i)  the target batch size is reached,
  (ii) the max batching interval elapses, or
  (iii) a commit is initiated.
Finalized blobs upload asynchronously; an internal completion queue is
polled from the processing loop; per contributing partition a notification
is emitted. Commits block until all uploads completed + notifications sent.

Hot-path layout: buffers hold **serialized chunks** (bytes-like), not
``Record`` objects. The legacy ``process(record)`` path serializes each
record once on arrival; the columnar ``ingest(RecordBatch)`` path
partitions a whole batch with the vectorized FNV-1a partitioner, groups
rows per destination with one ``np.argsort``, and serializes each group
into a single chunk. ``_finalize`` then joins chunks exactly once into
the blob payload (``build_blob_from_buffers``) — the bytes are never
re-copied between buffering and upload.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.blob import Blob, Notification, build_blob_from_buffers
from repro.core.cache import DistributedCache
from repro.core.formats import get_format
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record, serialize


@dataclasses.dataclass(frozen=True)
class BlobShuffleConfig:
    """Mirrors the constructor arguments in Listing 1."""
    batch_bytes: int = 16 * 1024 * 1024
    max_interval_s: float = 5.0
    num_partitions: int = 9
    num_az: int = 3
    cache_on_write: bool = True
    local_cache_bytes: int = 0           # 0 = disabled (paper default)
    distributed_cache_bytes: int = 4 * 1024 ** 3
    retention_s: float = 3600.0
    #: registered blob wire format used for finalized blocks ("raw-v1"
    #: writes the legacy byte-identical layout; "columnar-v2" compresses)
    wire_format: str = "raw-v1"


@dataclasses.dataclass
class PendingUpload:
    blob: Blob
    notifications: List[Notification]
    started_at: float
    completes_at: float


class _PartitionBuffer:
    """Serialized chunks + record count for one destination partition."""
    __slots__ = ("chunks", "count")

    def __init__(self):
        self.chunks: List = []
        self.count = 0

    def append(self, chunk, n: int) -> None:
        self.chunks.append(chunk)
        self.count += n


@dataclasses.dataclass
class BatcherStats:
    records_in: int = 0
    bytes_in: int = 0
    blobs: int = 0
    blob_bytes: int = 0
    notifications: int = 0
    finalize_size: int = 0
    finalize_interval: int = 0
    finalize_commit: int = 0


class Batcher:
    """One Batcher per stream thread (buffers shared across its tasks)."""

    #: optional repro.obs.Observability side-table, attached by the
    #: engine when observability is enabled (never schedules events)
    obs = None

    def __init__(self, cfg: BlobShuffleConfig,
                 partition_to_az: Callable[[int], int],
                 partitioner: Callable[[bytes], int],
                 cache: DistributedCache,
                 uploader: Optional[Callable[
                     [Blob, List[Notification], Dict[int, int],
                      float], None]] = None,
                 name: Optional[str] = None,
                 partitioner_batch: Optional[Callable[
                     [RecordBatch], np.ndarray]] = None):
        self.cfg = cfg
        # Resolve the wire format once (raises UnknownFormatError on a
        # typo'd name at construction, not at first finalize). Raw v1 is
        # the identity encoding, so it skips the per-block hook entirely.
        fmt = get_format(cfg.wire_format)
        self.fmt = None if fmt.format_id == 1 else fmt
        self.partition_to_az = partition_to_az
        self.partitioner = partitioner
        # vectorized partitioner for RecordBatch ingest; when absent the
        # scalar partitioner is applied row-by-row (correct but slow)
        self.partitioner_batch = partitioner_batch
        self.cache = cache
        # When named, blob ids are "<name>-<seq>" instead of random uuids:
        # deterministic across runs (bit-reproducible virtual-clock runs,
        # stable per-prefix throttle buckets in FaultyStore) and prefixed
        # per producer, mirroring S3 key-prefix layout.
        self.name = name
        self._blob_seq = 0
        # Event-driven hook: when set, finalized blobs are handed to
        # ``uploader(blob, notes, per_partition_counts, now)`` instead of
        # being written synchronously — the async engine queues them on a
        # bounded per-instance upload lane and completes them on the
        # virtual clock. ``pending``/``ready`` stay empty in that mode.
        self.uploader = uploader
        # az -> partition -> serialized chunks; az -> bytes
        self.buffers: Dict[int, Dict[int, _PartitionBuffer]] = {}
        self.buffer_bytes: Dict[int, int] = {}
        self.last_finalize: Dict[int, float] = {}
        # min-heap of (completes_at, seq, PendingUpload): poll/on_commit
        # pop in completion order instead of O(n)-scanning per record
        self.pending: List[Tuple[float, int, PendingUpload]] = []
        self._pending_seq = 0
        self.ready: List[Notification] = []
        self.stats = BatcherStats()
        self._az_table: Optional[np.ndarray] = None

    # -- main processing loop ---------------------------------------------
    def process(self, rec: Record, now: float) -> List[Notification]:
        """Route one record into its per-partition buffer; poll completions."""
        part = self.partitioner(rec.key)
        az = self.partition_to_az(part)
        chunk = serialize(rec)
        self._append(az, part, chunk, 1, len(chunk), now)
        self._check_triggers(az, now)
        return self.poll(now)

    def ingest(self, batch: RecordBatch, now: float) -> List[Notification]:
        """Columnar bulk ingest: partition, group, and serialize a whole
        ``RecordBatch`` with vectorized ops — one stable argsort by
        (AZ, partition), then one serialized wire buffer **per touched
        AZ** whose per-partition chunks are zero-copy memoryview slices.
        Serializing per AZ (not per batch) means a buffered slice pins
        only its own AZ's wire bytes, which are released exactly when
        that AZ finalizes. Finalize triggers run after every partition
        group, so a blob overshoots ``batch_bytes`` by at most one
        group — mirroring the legacy path's at-most-one-record overshoot
        at batch granularity.

        All segment math is one vectorized pass: per-group partition/AZ
        from the group's first sorted row, a single global cumsum over
        ``sizes[order]`` for every group's byte offset, and AZ run
        boundaries from one ``diff``/``flatnonzero`` — the remaining
        Python loop does nothing but slice views and call ``_append``."""
        n = len(batch)
        if n == 0:
            return self.poll(now)
        parts = self.compute_partitions(batch)
        order, starts = self._group(batch)
        sizes = batch.serialized_sizes()
        az_table = self._partition_az_table()
        g_part = parts[order[starts[:-1]]]       # per-group partition id
        g_az = az_table[g_part]                  # per-group destination AZ
        boff = np.zeros(n + 1, np.int64)
        np.cumsum(sizes[order], out=boff[1:])
        goff = boff[starts]                      # per-group byte offsets
        run_bounds = np.concatenate(             # AZ runs within the groups
            ([0], np.flatnonzero(np.diff(g_az)) + 1, [len(g_az)]))
        for k in range(len(run_bounds) - 1):
            i, j = int(run_bounds[k]), int(run_bounds[k + 1])
            az = int(g_az[i])
            wire = memoryview(
                batch.serialize_rows(order[starts[i]:starts[j]]))
            base = int(goff[i])
            for g in range(i, j):
                s = int(goff[g]) - base
                e = int(goff[g + 1]) - base
                self._append(az, int(g_part[g]), wire[s:e],
                             int(starts[g + 1] - starts[g]), e - s, now)
                self._check_triggers(az, now)
        return self.poll(now)

    def _group(self, batch: RecordBatch) -> Tuple[np.ndarray, np.ndarray]:
        """Destination grouping, cached on the batch: ``order`` is the
        stable row permutation sorted by (AZ, partition); ``starts`` the
        (AZ, partition)-group boundaries within it (len = groups + 1).
        Shared by the engine's arrival bookkeeping so the argsort runs
        once per batch."""
        if batch.groups is None:
            parts = self.compute_partitions(batch)
            az_table = self._partition_az_table()
            composite = az_table[parts] * self.cfg.num_partitions + parts
            order = np.argsort(composite, kind="stable")
            sc = composite[order]
            bounds = np.flatnonzero(sc[1:] != sc[:-1]) + 1
            batch.groups = (order, np.concatenate(([0], bounds,
                                                   [len(parts)])))
        return batch.groups

    def compute_partitions(self, batch: RecordBatch) -> np.ndarray:
        """(N,) int32 destination partitions, cached on the batch."""
        if batch.partitions is None:
            if self.partitioner_batch is not None:
                batch.partitions = np.asarray(
                    self.partitioner_batch(batch), np.int32)
            else:
                batch.partitions = self._partitions_by_unique_key(batch)
        return batch.partitions

    def _partitions_by_unique_key(self, batch: RecordBatch) -> np.ndarray:
        """Scalar-partitioner fallback, one call per **unique** key.

        A partitioner is a pure function of the key bytes, so calling it
        per distinct key and broadcasting through ``np.unique``'s inverse
        is bit-equal to the old per-row ``np.fromiter`` sweep — and on
        the Zipf-shaped workloads this repo models (a few hot keys
        dominate) it collapses N Python calls to the distinct-key count.
        Fixed-width keys dedup as a void view of the arena; ragged keys
        fall back to a dict memo (still one partitioner call per unique
        key, just a Python-level dedup)."""
        n = len(batch)
        klen = np.diff(batch.key_offsets)
        if n and (klen == klen[0]).all() and klen[0] > 0:
            kw = int(klen[0])
            base = int(batch.key_offsets[0])
            arena = np.ascontiguousarray(batch.key_arena)
            rows = arena[base:base + n * kw].reshape(n, kw) \
                .view(np.dtype((np.void, kw)))[:, 0]
            uniq, inverse = np.unique(rows, return_inverse=True)
            uparts = np.fromiter(
                (self.partitioner(u.tobytes()) for u in uniq),
                np.int32, len(uniq))
            return uparts[inverse]
        memo: Dict[bytes, int] = {}
        out = np.empty(n, np.int32)
        for i in range(n):
            k = bytes(batch.key(i))
            p = memo.get(k)
            if p is None:
                p = memo[k] = self.partitioner(k)
            out[i] = p
        return out

    def _partition_az_table(self) -> np.ndarray:
        if self._az_table is None:
            self._az_table = np.fromiter(
                (self.partition_to_az(p)
                 for p in range(self.cfg.num_partitions)),
                np.int64, self.cfg.num_partitions)
        return self._az_table

    def _append(self, az: int, part: int, chunk, n: int, nbytes: int,
                now: float) -> None:
        buf = self.buffers.setdefault(az, {})
        pb = buf.get(part)
        if pb is None:
            pb = buf[part] = _PartitionBuffer()
        pb.append(chunk, n)
        self.buffer_bytes[az] = self.buffer_bytes.get(az, 0) + nbytes
        self.stats.records_in += n
        self.stats.bytes_in += nbytes
        self.last_finalize.setdefault(az, now)

    def _check_triggers(self, az: int, now: float) -> None:
        if self.buffer_bytes[az] >= self.cfg.batch_bytes:
            self._finalize(az, now, "size")
        elif now - self.last_finalize[az] >= self.cfg.max_interval_s:
            self._finalize(az, now, "interval")

    def poll(self, now: float) -> List[Notification]:
        """Drain the upload-completion queue (processed from the main
        thread, like the paper's internal result queue). The heap pops
        only completed entries — O(done · log n), not an O(n) scan."""
        out = list(self.ready)
        self.ready.clear()
        while self.pending and self.pending[0][0] <= now:
            _, _, p = heapq.heappop(self.pending)
            out.extend(p.notifications)
            self.stats.notifications += len(p.notifications)
        return out

    def flush_due(self, now: float) -> None:
        """Finalize every buffer whose max batching interval has elapsed
        (called from the engine's per-buffer timer events — the sync path
        piggybacks the same check on record arrival)."""
        for az in list(self.buffers):
            if (self.buffer_bytes.get(az, 0) > 0 and
                    now - self.last_finalize.get(az, now)
                    >= self.cfg.max_interval_s):
                self._finalize(az, now, "interval")

    def flush_all(self, now: float) -> None:
        """Commit-path finalize of every non-empty buffer."""
        for az in list(self.buffers):
            if self.buffer_bytes.get(az, 0) > 0:
                self._finalize(az, now, "commit")

    def buffered_bytes(self) -> int:
        return sum(self.buffer_bytes.values())

    # -- commit protocol ----------------------------------------------------
    def on_commit(self, now: float) -> Tuple[List[Notification], float]:
        """Finalize all buffers and BLOCK until outstanding uploads are
        durable; returns (notifications, commit-block seconds)."""
        self.flush_all(now)
        block_until = now
        notes: List[Notification] = []
        while self.pending:
            completes_at, _, p = heapq.heappop(self.pending)
            block_until = max(block_until, completes_at)
            notes.extend(p.notifications)
            self.stats.notifications += len(p.notifications)
        notes.extend(self.ready)
        self.ready.clear()
        return notes, max(0.0, block_until - now)

    # -- internals -----------------------------------------------------------
    def _finalize(self, az: int, now: float, why: str) -> None:
        parts = self.buffers.pop(az, {})
        self.buffer_bytes[az] = 0
        self.last_finalize[az] = now
        if not parts:
            return
        bid = None
        if self.name is not None:
            bid = f"{self.name}-{self._blob_seq:06d}"
            self._blob_seq += 1
        blob, notes = build_blob_from_buffers(
            {p: pb.chunks for p, pb in parts.items()}, target_az=az,
            blob_id=bid, fmt=self.fmt)
        if self.uploader is not None:
            counts = {p: pb.count for p, pb in parts.items()}
            self.uploader(blob, notes, counts, now)
        else:
            lat = self.cache.write(blob.blob_id, blob.payload, now)
            heapq.heappush(
                self.pending,
                (now + lat, self._pending_seq,
                 PendingUpload(blob, notes, now, now + lat)))
            self._pending_seq += 1
        self.stats.blobs += 1
        self.stats.blob_bytes += blob.size
        setattr(self.stats, f"finalize_{why}",
                getattr(self.stats, f"finalize_{why}") + 1)
        if self.obs is not None:
            self.obs.on_batch_finalized(az, blob, why, now)
