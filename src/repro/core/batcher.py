"""Batcher operator (paper §3.1, Fig. 2).

Per destination partition, an in-memory buffer of serialized records;
buffers of partitions in the same destination AZ are grouped so the
accumulated size per AZ is tracked. A batch is finalized when
  (i)  the target batch size is reached,
  (ii) the max batching interval elapses, or
  (iii) a commit is initiated.
Finalized blobs upload asynchronously; an internal completion queue is
polled from the processing loop; per contributing partition a notification
is emitted. Commits block until all uploads completed + notifications sent.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.blob import Blob, Notification, build_blob
from repro.core.cache import DistributedCache
from repro.core.records import Record, serialized_size


@dataclasses.dataclass(frozen=True)
class BlobShuffleConfig:
    """Mirrors the constructor arguments in Listing 1."""
    batch_bytes: int = 16 * 1024 * 1024
    max_interval_s: float = 5.0
    num_partitions: int = 9
    num_az: int = 3
    cache_on_write: bool = True
    local_cache_bytes: int = 0           # 0 = disabled (paper default)
    distributed_cache_bytes: int = 4 * 1024 ** 3
    retention_s: float = 3600.0


@dataclasses.dataclass
class PendingUpload:
    blob: Blob
    notifications: List[Notification]
    started_at: float
    completes_at: float


@dataclasses.dataclass
class BatcherStats:
    records_in: int = 0
    bytes_in: int = 0
    blobs: int = 0
    blob_bytes: int = 0
    notifications: int = 0
    finalize_size: int = 0
    finalize_interval: int = 0
    finalize_commit: int = 0


class Batcher:
    """One Batcher per stream thread (buffers shared across its tasks)."""

    def __init__(self, cfg: BlobShuffleConfig,
                 partition_to_az: Callable[[int], int],
                 partitioner: Callable[[bytes], int],
                 cache: DistributedCache,
                 uploader: Optional[Callable[
                     [Blob, List[Notification], Dict[int, List[Record]],
                      float], None]] = None,
                 name: Optional[str] = None):
        self.cfg = cfg
        self.partition_to_az = partition_to_az
        self.partitioner = partitioner
        self.cache = cache
        # When named, blob ids are "<name>-<seq>" instead of random uuids:
        # deterministic across runs (bit-reproducible virtual-clock runs,
        # stable per-prefix throttle buckets in FaultyStore) and prefixed
        # per producer, mirroring S3 key-prefix layout.
        self.name = name
        self._blob_seq = 0
        # Event-driven hook: when set, finalized blobs are handed to
        # ``uploader(blob, notes, per_partition_records, now)`` instead of
        # being written synchronously — the async engine queues them on a
        # bounded per-instance upload lane and completes them on the
        # virtual clock. ``pending``/``ready`` stay empty in that mode.
        self.uploader = uploader
        # az -> partition -> [records]; az -> bytes
        self.buffers: Dict[int, Dict[int, List[Record]]] = {}
        self.buffer_bytes: Dict[int, int] = {}
        self.last_finalize: Dict[int, float] = {}
        self.pending: List[PendingUpload] = []
        self.ready: List[Notification] = []
        self.stats = BatcherStats()

    # -- main processing loop ---------------------------------------------
    def process(self, rec: Record, now: float) -> List[Notification]:
        """Route one record into its per-partition buffer; poll completions."""
        part = self.partitioner(rec.key)
        az = self.partition_to_az(part)
        buf = self.buffers.setdefault(az, {})
        buf.setdefault(part, []).append(rec)
        sz = serialized_size(rec)
        self.buffer_bytes[az] = self.buffer_bytes.get(az, 0) + sz
        self.stats.records_in += 1
        self.stats.bytes_in += sz
        self.last_finalize.setdefault(az, now)

        if self.buffer_bytes[az] >= self.cfg.batch_bytes:
            self._finalize(az, now, "size")
        elif now - self.last_finalize[az] >= self.cfg.max_interval_s:
            self._finalize(az, now, "interval")
        return self.poll(now)

    def poll(self, now: float) -> List[Notification]:
        """Drain the upload-completion queue (processed from the main
        thread, like the paper's internal result queue)."""
        done = [p for p in self.pending if p.completes_at <= now]
        self.pending = [p for p in self.pending if p.completes_at > now]
        out = list(self.ready)
        self.ready.clear()
        for p in done:
            out.extend(p.notifications)
            self.stats.notifications += len(p.notifications)
        return out

    def flush_due(self, now: float) -> None:
        """Finalize every buffer whose max batching interval has elapsed
        (called from the engine's per-buffer timer events — the sync path
        piggybacks the same check on record arrival)."""
        for az in list(self.buffers):
            if (self.buffer_bytes.get(az, 0) > 0 and
                    now - self.last_finalize.get(az, now)
                    >= self.cfg.max_interval_s):
                self._finalize(az, now, "interval")

    def flush_all(self, now: float) -> None:
        """Commit-path finalize of every non-empty buffer."""
        for az in list(self.buffers):
            if self.buffer_bytes.get(az, 0) > 0:
                self._finalize(az, now, "commit")

    def buffered_bytes(self) -> int:
        return sum(self.buffer_bytes.values())

    # -- commit protocol ----------------------------------------------------
    def on_commit(self, now: float) -> Tuple[List[Notification], float]:
        """Finalize all buffers and BLOCK until outstanding uploads are
        durable; returns (notifications, commit-block seconds)."""
        self.flush_all(now)
        block_until = max((p.completes_at for p in self.pending),
                          default=now)
        notes: List[Notification] = []
        for p in self.pending:
            notes.extend(p.notifications)
            self.stats.notifications += len(p.notifications)
        self.pending.clear()
        notes.extend(self.ready)
        self.ready.clear()
        return notes, max(0.0, block_until - now)

    # -- internals -----------------------------------------------------------
    def _finalize(self, az: int, now: float, why: str) -> None:
        parts = self.buffers.pop(az, {})
        self.buffer_bytes[az] = 0
        self.last_finalize[az] = now
        if not parts:
            return
        bid = None
        if self.name is not None:
            bid = f"{self.name}-{self._blob_seq:06d}"
            self._blob_seq += 1
        blob, notes = build_blob(parts, target_az=az, blob_id=bid)
        if self.uploader is not None:
            self.uploader(blob, notes, parts, now)
        else:
            lat = self.cache.write(blob.blob_id, blob.payload, now)
            self.pending.append(PendingUpload(blob, notes, now, now + lat))
        self.stats.blobs += 1
        self.stats.blob_bytes += blob.size
        setattr(self.stats, f"finalize_{why}",
                getattr(self.stats, f"finalize_{why}") + 1)
