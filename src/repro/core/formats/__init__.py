"""Pluggable, versioned blob wire formats (see README "Blob wire format
& codecs").

Built-in registrations:

  * ``raw-v1``            — the legacy headerless layout (default; blobs
                            are byte-identical to pre-registry blobs)
  * ``columnar-v2``       — per-column encodings (dict keys, delta
                            timestamps, zlib-framed arenas), lossless
  * ``columnar-v2-int8``  — v2 with the int8 per-row value quantizer
                            (lossy; opt-in for float32 numeric payloads)

Custom formats register via ``register_format`` and become selectable by
name through ``BlobShuffleConfig.wire_format``.
"""

from repro.core.formats.base import (WIRE_MAGIC, BlobFormat,
                                     BlobFormatError, CorruptBlobError,
                                     UnknownFormatError, detect_format,
                                     get_format, register_format,
                                     registered_formats)
from repro.core.formats.columnar_v2 import ColumnarV2
from repro.core.formats.raw_v1 import RawV1

RAW_V1 = register_format(RawV1())
COLUMNAR_V2 = register_format(ColumnarV2())
COLUMNAR_V2_INT8 = register_format(
    ColumnarV2(value_codec="int8", name="columnar-v2-int8"),
    canonical=False)

__all__ = [
    "WIRE_MAGIC", "BlobFormat", "BlobFormatError", "CorruptBlobError",
    "UnknownFormatError", "detect_format", "get_format", "register_format",
    "registered_formats", "RawV1", "ColumnarV2", "RAW_V1", "COLUMNAR_V2",
    "COLUMNAR_V2_INT8",
]
