"""Raw v1: the legacy blob block layout, re-homed behind ``BlobFormat``.

A block is exactly the concatenation of record wire frames — no magic,
no header, nothing between the records. Every blob written before the
format registry existed is a raw-v1 blob, and this class decodes it
byte-identically (it IS the old ``extract`` / ``extract_batch`` path).
"""

from __future__ import annotations

from typing import Sequence

from repro.core.recordbatch import RecordBatch


class RawV1:
    format_id = 1
    name = "raw-v1"

    def encode_block(self, chunks: Sequence) -> Sequence:
        """Identity: the chunks are already the wire layout (zero-copy —
        the caller joins them once into the blob payload)."""
        return chunks

    def decode_block(self, block) -> bytes:
        return block

    def decode_block_batch(self, block) -> RecordBatch:
        return RecordBatch.from_buffer(block)

    def __repr__(self) -> str:
        return f"RawV1({self.name!r})"
