"""Versioned blob wire formats: protocol, registry, and sniffing.

A blob stays what PR 3 made it — concatenated per-partition blocks plus a
byte-range index — but each *block* is now owned by a ``BlobFormat``:

  * ``RawV1`` is today's layout verbatim: the block IS the concatenated
    record wire bytes, with no header at all, so every legacy blob decodes
    byte-identically through it.
  * Framed formats (v2+) prefix each block with ``MAGIC`` + a version
    byte; the registry routes a block to its decoder by that header.

Because v1 has no header, detection is "no known magic → raw v1". A raw
stream can only collide with ``MAGIC`` if its first record claims a
``0x46575342``-byte (~1.1 GiB) key — unreachable for blobs batched at
MiB granularity (see README "Blob wire format & codecs").

Formats register by *name* (what ``BlobShuffleConfig.wire_format``
selects; one name per encoder configuration, e.g. ``columnar-v2`` vs the
lossy ``columnar-v2-int8``) and by *version byte* (what the decoder
sniffs; one canonical decoder per version, able to decode every flag
combination its encoders emit).
"""

from __future__ import annotations

from typing import Dict, List, Protocol, Sequence, runtime_checkable

from repro.core.recordbatch import RecordBatch

#: Frame magic for versioned (v2+) blocks. Raw v1 blocks have no header.
WIRE_MAGIC = b"BSWF"


class BlobFormatError(Exception):
    """Base class for wire-format errors."""


class UnknownFormatError(BlobFormatError):
    """Block carries the frame magic but an unregistered version byte."""


class CorruptBlobError(BlobFormatError):
    """Block is truncated or internally inconsistent (bad section frame,
    failed decompression, length mismatch)."""


@runtime_checkable
class BlobFormat(Protocol):
    """One wire format for a per-partition blob block.

    ``encode_block`` takes the partition's already-serialized record
    chunks (any bytes-like) and returns the chunk list to splice into the
    blob payload — identity for raw v1 (zero-copy), a single encoded
    frame for framed formats. Encoders may *negotiate down*: returning
    the input chunks unchanged is the raw fallback, taken whenever the
    encoded form would not be smaller (or the rows use features the
    format does not cover, e.g. record headers).

    ``decode_block`` returns the raw record wire bytes (bit-exact with
    what ``encode_block`` consumed); ``decode_block_batch`` decodes
    straight into a columnar ``RecordBatch`` without materializing the
    intermediate wire form.
    """

    format_id: int     # version byte in the frame header (1 = headerless raw)
    name: str          # registry key used by BlobShuffleConfig.wire_format

    def encode_block(self, chunks: Sequence) -> Sequence: ...

    def decode_block(self, block) -> bytes: ...

    def decode_block_batch(self, block) -> RecordBatch: ...


_BY_NAME: Dict[str, BlobFormat] = {}
_BY_ID: Dict[int, BlobFormat] = {}


def register_format(fmt: BlobFormat, *, canonical: bool = True) -> BlobFormat:
    """Add a format to the registry. ``canonical=True`` also installs it
    as the decoder for its version byte — pass ``False`` for alternate
    encoder configurations of an already-registered version (they share
    the canonical decoder)."""
    if fmt.name in _BY_NAME:
        raise ValueError(f"wire format {fmt.name!r} already registered")
    if canonical and fmt.format_id in _BY_ID:
        raise ValueError(
            f"wire format version {fmt.format_id} already registered "
            f"(as {_BY_ID[fmt.format_id].name!r})")
    _BY_NAME[fmt.name] = fmt
    if canonical:
        _BY_ID[fmt.format_id] = fmt
    return fmt


def get_format(name: str) -> BlobFormat:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise UnknownFormatError(
            f"unknown wire format {name!r}; registered: "
            f"{sorted(_BY_NAME)}") from None


def registered_formats() -> List[str]:
    return sorted(_BY_NAME)


def detect_format(block) -> BlobFormat:
    """Sniff one block's format from its leading bytes.

    Framed blocks open with ``MAGIC + version``; anything else is the
    headerless raw v1 layout (including the empty block). Raises
    ``UnknownFormatError`` for a framed block whose version byte has no
    registered decoder — a *typed* failure, so readers can distinguish
    "newer writer" from corruption.
    """
    mv = memoryview(block)
    if len(mv) >= len(WIRE_MAGIC) + 1 and bytes(mv[:4]) == WIRE_MAGIC:
        version = mv[4]
        fmt = _BY_ID.get(version)
        if fmt is None:
            raise UnknownFormatError(
                f"block carries wire-format version {version} but only "
                f"{sorted(_BY_ID)} are registered")
        return fmt
    return _BY_ID[1]     # headerless → raw v1
