"""Section codecs for framed blob formats.

Two layers:

  * **frame codecs** — every column section of a v2 block is framed as
    ``u8 codec | u32 enc_len | u32 raw_len | payload`` and the encoder
    negotiates per section: zlib when it wins, stored otherwise. The
    framing is self-describing, so new codecs slot in behind a new id
    without a version bump.
  * **int8 value codec** — the numpy twin of the device-side quantizer
    in ``repro.shuffle.compression`` (same symmetric per-row absmax/127
    semantics), applied to a uniform-width float32 value arena. Lossy:
    only the explicitly-selected ``columnar-v2-int8`` format uses it.
"""

from __future__ import annotations

import struct
import zlib
from typing import Tuple

import numpy as np

from repro.core.formats.base import CorruptBlobError

CODEC_STORED = 0
CODEC_ZLIB = 1

_SECTION_HDR = struct.Struct("<BII")      # codec, enc_len, raw_len

#: zlib level for section compression. Level 1 runs at frame-codec speed
#: (the arenas are the hot path) and captures nearly all of the win on
#: the highly redundant shuffle payloads the codec exists for.
ZLIB_LEVEL = 1


def encode_section(raw: bytes, *, level: int = ZLIB_LEVEL,
                   try_compress: bool = True) -> bytes:
    """Frame one section, negotiating zlib vs stored by encoded size."""
    if try_compress and len(raw) > _SECTION_HDR.size:
        enc = zlib.compress(raw, level)
        if len(enc) < len(raw):
            return _SECTION_HDR.pack(CODEC_ZLIB, len(enc), len(raw)) + enc
    return _SECTION_HDR.pack(CODEC_STORED, len(raw), len(raw)) + raw


def decode_section(block: memoryview, offset: int) -> Tuple[bytes, int]:
    """Decode one framed section at ``offset``; returns (raw bytes, next
    offset). Raises ``CorruptBlobError`` on truncation, an unknown codec
    id, or a decompressed-length mismatch."""
    end = offset + _SECTION_HDR.size
    if end > len(block):
        raise CorruptBlobError("truncated section header")
    codec, enc_len, raw_len = _SECTION_HDR.unpack_from(block, offset)
    if end + enc_len > len(block):
        raise CorruptBlobError(
            f"truncated section payload ({end + enc_len} > {len(block)})")
    payload = bytes(block[end:end + enc_len])
    if codec == CODEC_STORED:
        if enc_len != raw_len:
            raise CorruptBlobError("stored section length mismatch")
        raw = payload
    elif codec == CODEC_ZLIB:
        try:
            raw = zlib.decompress(payload)
        except zlib.error as e:
            raise CorruptBlobError(f"zlib section failed: {e}") from None
        if len(raw) != raw_len:
            raise CorruptBlobError(
                f"section inflated to {len(raw)} bytes, expected {raw_len}")
    else:
        raise CorruptBlobError(f"unknown section codec id {codec}")
    return raw, end + enc_len


# -- int8 value codec --------------------------------------------------------

def quantize_value_arena(arena: np.ndarray, width: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-row int8 quantization of a packed float32 value
    arena (rows of ``width`` bytes, width % 4 == 0). Returns
    (q int8 (n, width/4), scales float32 (n,)) — bit-compatible with
    ``repro.shuffle.compression.int8_quantize`` run per row."""
    x = np.frombuffer(np.ascontiguousarray(arena), "<f4")
    x = x.reshape(-1, width // 4)
    absmax = np.max(np.abs(x), axis=-1)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_value_arena(q: np.ndarray, scales: np.ndarray,
                           width: int) -> np.ndarray:
    """Inverse of ``quantize_value_arena``: back to a packed uint8 arena
    of float32 rows."""
    x = (q.astype(np.float32) * scales[:, None]).astype("<f4")
    return np.ascontiguousarray(x).reshape(-1).view(np.uint8)
