"""Section codecs for framed blob formats.

Two layers:

  * **frame codecs** — every column section of a v2 block is framed as
    ``u8 codec | u32 enc_len | u32 raw_len | payload`` and the encoder
    negotiates per section: constant-pattern when the section is one
    repeating period (proved by a vectorized compare instead of a
    deflate pass), zlib when it wins, stored otherwise. The framing is
    self-describing, so new codecs slot in behind a new id without a
    version bump.
  * **int8 value codec** — the numpy twin of the device-side quantizer
    in ``repro.shuffle.compression`` (same symmetric per-row absmax/127
    semantics), applied to a uniform-width float32 value arena. Lossy:
    only the explicitly-selected ``columnar-v2-int8`` format uses it.
"""

from __future__ import annotations

import struct
import zlib
from typing import Optional, Tuple, Union

import numpy as np

from repro.core.formats.base import CorruptBlobError

CODEC_STORED = 0
CODEC_ZLIB = 1
#: payload is one period of a repeating byte pattern; the section decodes
#: to ``payload * (raw_len // enc_len)``. Constant columns (uniform
#: lengths, all-zero arenas) are common in shuffle payloads, and zlib —
#: even at level 1 — pays a full deflate pass to discover what a single
#: vectorized compare can prove, so CONST is negotiated *before* zlib.
CODEC_CONST = 2

_SECTION_HDR = struct.Struct("<BII")      # codec, enc_len, raw_len

#: zlib level for section compression. Level 1 runs at frame-codec speed
#: (the arenas are the hot path) and captures nearly all of the win on
#: the highly redundant shuffle payloads the codec exists for.
ZLIB_LEVEL = 1

#: periods the constant-pattern probe tries, longest first (8 covers u64
#: columns; 4/2/1 cover u32/u16/byte-constant sections). A longer period
#: that also has a shorter one still round-trips identically, so probe
#: order only affects the (negligible) pattern-bytes overhead.
_CONST_PERIODS = (8, 4, 2, 1)


_PERIOD_DTYPE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _const_period(arr: np.ndarray) -> Optional[int]:
    """Longest probed period ``p`` such that ``arr`` is ``arr[:p]``
    tiled, or None. The first-two-periods screen rejects non-constant
    sections after comparing at most 16 bytes; only candidates that pass
    pay the full compare, done on a view with one integer per period
    (8x fewer compares and an 8x smaller bool temp than a byte-wise
    broadcast compare for the u64 case)."""
    n = arr.size
    for p in _CONST_PERIODS:
        if n % p or n < 2 * p:
            continue
        if not (arr[:p] == arr[p:2 * p]).all():
            continue
        v = arr.view(_PERIOD_DTYPE[p])
        if not (v != v[0]).any():
            return p
    return None


def encode_section(raw: Union[bytes, bytearray, memoryview, np.ndarray],
                   *, level: int = ZLIB_LEVEL,
                   try_compress: bool = True) -> bytes:
    """Frame one section, negotiating constant-pattern vs zlib vs stored
    by encoded size.

    ``raw`` may be bytes-like **or a numpy array** (any dtype; its
    C-contiguous little-endian byte image is framed) — array callers skip
    the ``tobytes`` copy the old bytes-only signature forced."""
    if isinstance(raw, np.ndarray):
        arr = np.ascontiguousarray(raw).reshape(-1).view(np.uint8)
    else:
        arr = np.frombuffer(raw, np.uint8)
    n = arr.size
    if try_compress and n > _SECTION_HDR.size:
        p = _const_period(arr)
        if p is not None:
            return _SECTION_HDR.pack(CODEC_CONST, p, n) + arr[:p].tobytes()
        enc = zlib.compress(arr, level)
        if len(enc) < n:
            return _SECTION_HDR.pack(CODEC_ZLIB, len(enc), n) + enc
    return _SECTION_HDR.pack(CODEC_STORED, n, n) + arr.tobytes()


def decode_section(block: memoryview, offset: int) -> Tuple[bytes, int]:
    """Decode one framed section at ``offset``; returns (raw bytes, next
    offset). Raises ``CorruptBlobError`` on truncation, an unknown codec
    id, or a decompressed-length mismatch."""
    end = offset + _SECTION_HDR.size
    if end > len(block):
        raise CorruptBlobError("truncated section header")
    codec, enc_len, raw_len = _SECTION_HDR.unpack_from(block, offset)
    if end + enc_len > len(block):
        raise CorruptBlobError(
            f"truncated section payload ({end + enc_len} > {len(block)})")
    payload = bytes(block[end:end + enc_len])
    if codec == CODEC_STORED:
        if enc_len != raw_len:
            raise CorruptBlobError("stored section length mismatch")
        raw = payload
    elif codec == CODEC_ZLIB:
        try:
            raw = zlib.decompress(payload)
        except zlib.error as e:
            raise CorruptBlobError(f"zlib section failed: {e}") from None
        if len(raw) != raw_len:
            raise CorruptBlobError(
                f"section inflated to {len(raw)} bytes, expected {raw_len}")
    elif codec == CODEC_CONST:
        if enc_len == 0 or raw_len % enc_len:
            raise CorruptBlobError(
                f"constant section: raw_len {raw_len} is not a multiple "
                f"of pattern length {enc_len}")
        raw = payload * (raw_len // enc_len)
    else:
        raise CorruptBlobError(f"unknown section codec id {codec}")
    return raw, end + enc_len


# -- int8 value codec --------------------------------------------------------

def quantize_value_arena(arena: np.ndarray, width: int
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-row int8 quantization of a packed float32 value
    arena (rows of ``width`` bytes, width % 4 == 0). Returns
    (q int8 (n, width/4), scales float32 (n,)) — bit-compatible with
    ``repro.shuffle.compression.int8_quantize`` run per row."""
    x = np.frombuffer(np.ascontiguousarray(arena), "<f4")
    x = x.reshape(-1, width // 4)
    absmax = np.max(np.abs(x), axis=-1)
    scale = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
    q = np.clip(np.rint(x / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_value_arena(q: np.ndarray, scales: np.ndarray,
                           width: int) -> np.ndarray:
    """Inverse of ``quantize_value_arena``: back to a packed uint8 arena
    of float32 rows."""
    x = (q.astype(np.float32) * scales[:, None]).astype("<f4")
    return np.ascontiguousarray(x).reshape(-1).view(np.uint8)
