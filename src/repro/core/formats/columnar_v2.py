"""Columnar v2: per-column encodings over the SoA arenas, framed per block.

The encoder parses a partition block's record wire bytes into a
``RecordBatch`` (the vectorized PR-3 parser) and re-emits it column by
column:

  * **keys** — dictionary-encoded when the keys are fixed-width and the
    distinct set is small (the Zipf-workload shape: a few hot keys
    dominate), else raw lengths + arena;
  * **timestamps** — delta-encoded from the first value (arrival order
    makes deltas tiny and highly repetitive);
  * **values** — the packed arena, frame-compressed; optionally int8
    per-row quantized first (``value_codec="int8"``, lossy, for float32
    numeric payloads — the blob-layer twin of the DCN quantizer in
    ``repro.shuffle.compression``).

Every section is framed through ``codecs.encode_section`` (zlib vs
stored, negotiated by size). The whole block then negotiates against the
raw form: if the encoded block is not strictly smaller than the wire
bytes — or the rows carry record headers, which v2 does not cover — the
encoder falls back to raw v1 for that block. Decoders sniff per block,
so mixed blobs are fine.

Block layout (little-endian):

    0   4  MAGIC ``b"BSWF"``
    4   1  version = 2
    5   1  flags: bit0 keys-dict, bit1 ts-delta, bit2 values-int8
    6   4  n_records (u32)
    10  4  value_width (u32; nonzero only with values-int8)
    14  …  framed sections, in order:
           keys-dict:  codes | dict_lengths (u32) | dict_arena
           keys-raw:   key_lengths (u32) | key_arena
           timestamps: ts0 (u64) + deltas (i64[n-1])  — or u64[n] raw
           value_lengths (u32)
           values-int8: q (i8) | scales (f32)  — or value_arena raw
"""

from __future__ import annotations

import struct
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.formats.base import WIRE_MAGIC, CorruptBlobError
from repro.core.formats.codecs import (decode_section, encode_section,
                                       dequantize_value_arena,
                                       quantize_value_arena)
from repro.core.recordbatch import RecordBatch, _offsets_from_lengths, \
    _ragged_gather

_BLOCK_HDR = struct.Struct("<4sBBII")    # magic, version, flags, n, vwidth

FLAG_KEYS_DICT = 1
FLAG_TS_DELTA = 2
FLAG_VALUES_INT8 = 4
_KNOWN_FLAGS = FLAG_KEYS_DICT | FLAG_TS_DELTA | FLAG_VALUES_INT8

#: dictionary encoding must at least halve the key column to be chosen
_DICT_MAX_FRACTION = 0.5


def _uniform_width(offsets: np.ndarray) -> Optional[int]:
    lengths = np.diff(offsets)
    if len(lengths) and (lengths == lengths[0]).all():
        return int(lengths[0])
    return None


def _code_dtype(n_dict: int):
    if n_dict <= 0xFF:
        return np.uint8
    if n_dict <= 0xFFFF:
        return np.dtype("<u2")
    return np.dtype("<u4")


class ColumnarV2:
    format_id = 2

    def __init__(self, *, value_codec: str = "zlib",
                 name: str = "columnar-v2"):
        if value_codec not in ("zlib", "int8"):
            raise ValueError(f"unknown value codec {value_codec!r}")
        self.value_codec = value_codec
        self.name = name

    # -- encode -----------------------------------------------------------
    def encode_block(self, chunks: Sequence) -> Sequence:
        wire = chunks[0] if len(chunks) == 1 else b"".join(chunks)
        batch = RecordBatch.from_buffer(wire)
        if len(batch) == 0 or batch.headers is not None:
            return chunks                       # raw fallback
        block = self._encode_batch(batch)
        if len(block) >= len(wire):
            return chunks                       # compression does not pay
        return [block]

    def _encode_batch(self, batch: RecordBatch) -> bytes:
        n = len(batch)
        flags = 0
        sections: List[bytes] = []
        # keys: dictionary when fixed-width and the distinct set is small
        kw = _uniform_width(batch.key_offsets)
        dict_enc = self._dict_encode(batch, kw) if kw else None
        if dict_enc is not None:
            flags |= FLAG_KEYS_DICT
            codes, dict_lengths, dict_arena = dict_enc
            sections.append(encode_section(codes))
            sections.append(encode_section(dict_lengths))
            sections.append(encode_section(dict_arena))
        else:
            klen = np.diff(batch.key_offsets).astype("<u4")
            sections.append(encode_section(klen))
            sections.append(encode_section(
                np.ascontiguousarray(batch.key_arena)))
        # timestamps: delta from ts0 (falls back to raw near the u64 top).
        # ts0-then-diffs is built as one <i8 array — ts0 < 2^63, so its
        # two's-complement bytes equal the <u8 image the format specifies.
        ts = batch.timestamps
        if n >= 1 and bool((ts < np.uint64(1 << 63)).all()):
            flags |= FLAG_TS_DELTA
            signed = ts.astype(np.int64)
            deltas = np.empty(n, "<i8")
            deltas[0] = signed[0]
            np.subtract(signed[1:], signed[:-1], out=deltas[1:])
            sections.append(encode_section(deltas))
        else:
            sections.append(encode_section(ts.astype("<u8")))
        # value lengths + arena (optionally int8-quantized)
        vlen = np.diff(batch.value_offsets).astype("<u4")
        sections.append(encode_section(vlen))
        arena = np.ascontiguousarray(batch.value_arena)
        vw = _uniform_width(batch.value_offsets)
        vwidth = 0
        if (self.value_codec == "int8" and vw and vw % 4 == 0
                and arena.size == n * vw):
            flags |= FLAG_VALUES_INT8
            vwidth = vw
            q, scales = quantize_value_arena(arena, vw)
            sections.append(encode_section(q))
            sections.append(encode_section(scales.astype("<f4", copy=False)))
        else:
            sections.append(encode_section(arena))
        hdr = _BLOCK_HDR.pack(WIRE_MAGIC, self.format_id, flags, n, vwidth)
        return hdr + b"".join(sections)

    @staticmethod
    def _dict_encode(batch: RecordBatch, kw: int
                     ) -> Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """(codes, dict_lengths, dict_arena) when a fixed-width dictionary
        pays, else None. Uniques sort ascending, so the encoding is a
        pure function of the key multiset (deterministic across runs)."""
        n = len(batch)
        arena = np.ascontiguousarray(batch.key_arena)
        if kw in (1, 2, 4, 8):
            flat = arena.view(f"<u{kw}")
            uniq, codes = np.unique(flat, return_inverse=True)
            uniq_bytes = uniq.view(np.uint8)
        else:
            rows = arena.reshape(n, kw).view(np.dtype((np.void, kw)))[:, 0]
            uniq, codes = np.unique(rows, return_inverse=True)
            uniq_bytes = uniq.view(np.uint8).reshape(-1)
        if len(uniq) > n * _DICT_MAX_FRACTION:
            return None
        return (codes.astype(_code_dtype(len(uniq))),
                np.full(len(uniq), kw, "<u4"), uniq_bytes)

    # -- decode -----------------------------------------------------------
    def decode_block(self, block) -> bytes:
        return bytes(self.decode_block_batch(block).serialize_rows())

    def decode_block_batch(self, block) -> RecordBatch:
        mv = memoryview(block)
        if len(mv) < _BLOCK_HDR.size:
            raise CorruptBlobError("truncated v2 block header")
        magic, version, flags, n, vwidth = _BLOCK_HDR.unpack_from(mv, 0)
        if magic != WIRE_MAGIC or version != self.format_id:
            raise CorruptBlobError(
                f"not a v2 block (magic={magic!r}, version={version})")
        if flags & ~_KNOWN_FLAGS:
            raise CorruptBlobError(f"unsupported v2 flags 0x{flags:02x}")
        off = _BLOCK_HDR.size
        # keys
        if flags & FLAG_KEYS_DICT:
            codes_raw, off = decode_section(mv, off)
            dlen_raw, off = decode_section(mv, off)
            darena_raw, off = decode_section(mv, off)
            if n == 0 or len(codes_raw) % n:
                raise CorruptBlobError("dict code section length mismatch")
            itemsize = len(codes_raw) // n
            if itemsize not in (1, 2, 4):
                raise CorruptBlobError(
                    f"dict codes have itemsize {itemsize}")
            codes = np.frombuffer(codes_raw, f"<u{itemsize}").astype(np.int64)
            dlen = np.frombuffer(dlen_raw, "<u4").astype(np.int64)
            darena = np.frombuffer(darena_raw, np.uint8)
            if len(dlen) == 0 or codes.max(initial=-1) >= len(dlen) \
                    or int(dlen.sum()) != darena.size:
                raise CorruptBlobError("dict section inconsistent")
            doff = _offsets_from_lengths(dlen)
            klen = dlen[codes]
            ka = _ragged_gather(darena, doff[:-1][codes], klen)
        else:
            klen_raw, off = decode_section(mv, off)
            ka_raw, off = decode_section(mv, off)
            klen = np.frombuffer(klen_raw, "<u4").astype(np.int64)
            ka = np.frombuffer(ka_raw, np.uint8)
        # timestamps
        ts_raw, off = decode_section(mv, off)
        if flags & FLAG_TS_DELTA:
            if len(ts_raw) != 8 * n:
                raise CorruptBlobError("delta timestamp section mismatch")
            if n == 0:
                ts = np.zeros(0, np.uint64)
            else:
                ts0 = np.frombuffer(ts_raw[:8], "<u8").astype(np.int64)
                deltas = np.frombuffer(ts_raw[8:], "<i8")
                ts = np.concatenate([ts0, ts0 + np.cumsum(deltas)]) \
                    .astype(np.uint64)
        else:
            ts = np.frombuffer(ts_raw, "<u8").astype(np.uint64)
        # values
        vlen_raw, off = decode_section(mv, off)
        vlen = np.frombuffer(vlen_raw, "<u4").astype(np.int64)
        if flags & FLAG_VALUES_INT8:
            q_raw, off = decode_section(mv, off)
            scales_raw, off = decode_section(mv, off)
            if vwidth <= 0 or vwidth % 4 or len(q_raw) != n * (vwidth // 4):
                raise CorruptBlobError("int8 value section mismatch")
            q = np.frombuffer(q_raw, np.int8).reshape(n, vwidth // 4)
            scales = np.frombuffer(scales_raw, "<f4")
            if len(scales) != n:
                raise CorruptBlobError("int8 scale section mismatch")
            va = dequantize_value_arena(q, scales, vwidth)
        else:
            va_raw, off = decode_section(mv, off)
            va = np.frombuffer(va_raw, np.uint8)
        if off != len(mv):
            raise CorruptBlobError(
                f"{len(mv) - off} trailing bytes after the last section")
        if len(klen) != n or len(vlen) != n or len(ts) != n \
                or int(klen.sum()) != ka.size or int(vlen.sum()) != va.size:
            raise CorruptBlobError("column lengths inconsistent with header")
        return RecordBatch(_offsets_from_lengths(klen), ka,
                           _offsets_from_lengths(vlen), va, ts)

    def __repr__(self) -> str:
        return f"ColumnarV2({self.name!r}, value_codec={self.value_codec!r})"
