"""Multi-layer caching: LRU + single-flight + distributed (per-AZ) + local.

Implements the paper §3.3 invariants:
  * distributed cache is organized per AZ; all instances in an AZ form a
    cache cluster; each member owns a subset of blobs (consistent routing);
  * concurrent reads for the same blob are coalesced (single-flight) so a
    blob is downloaded from object storage **at most once per AZ** while
    the entry is live;
  * optional per-instance local LRU removes repeated remote lookups.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.stores import BlobStore, StoreError
from repro.utils import stable_hash64


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    coalesced: int = 0       # requests served by an in-flight download
    evictions: int = 0
    insertions: int = 0
    store_gets: int = 0      # store GETs this cluster led (misses it filled)
    reroutes: int = 0        # entries moved owner-to-owner on resize

    @property
    def requests(self) -> int:
        return self.hits + self.misses + self.coalesced


class LRUCache:
    """Byte-capacity LRU of blob payloads."""

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self.entries: "OrderedDict[str, bytes]" = OrderedDict()
        self.size = 0
        self.stats = CacheStats()

    def get(self, key: str) -> Optional[bytes]:
        if key in self.entries:
            self.entries.move_to_end(key)
            self.stats.hits += 1
            return self.entries[key]
        self.stats.misses += 1
        return None

    def put(self, key: str, value: bytes) -> None:
        if key in self.entries:
            self.size -= len(self.entries.pop(key))
        if len(value) > self.capacity:
            return  # larger than the whole cache: skip
        while self.size + len(value) > self.capacity and self.entries:
            _, old = self.entries.popitem(last=False)
            self.size -= len(old)
            self.stats.evictions += 1
        self.entries[key] = value
        self.size += len(value)
        self.stats.insertions += 1

    def __contains__(self, key: str) -> bool:
        return key in self.entries


class SingleFlight:
    """Coalesce concurrent fetches of the same key (paper: "subsequent
    requests are blocked until the initial download completes")."""

    def __init__(self):
        self.inflight: Dict[str, List[Callable]] = {}

    def begin(self, key: str) -> bool:
        """True if caller is the leader (must fetch); False → coalesced."""
        if key in self.inflight:
            return False
        self.inflight[key] = []
        return True

    def wait(self, key: str, callback: Callable) -> None:
        self.inflight[key].append(callback)

    def complete(self, key: str, value: bytes) -> List[Callable]:
        waiters = self.inflight.pop(key, [])
        return waiters


class DistributedCache:
    """Per-AZ cache cluster: members own key-ranges; reads route through
    the owner, which fetches from object storage at most once per entry."""

    #: optional repro.obs.Observability side-table, attached by the
    #: engine when observability is enabled
    obs = None

    def __init__(self, az: int, members: int, capacity_per_member: int,
                 store: BlobStore, cache_on_write: bool = True):
        self.az = az
        self.members = [LRUCache(capacity_per_member)
                        for _ in range(members)]
        self.flight = SingleFlight()
        self.store = store
        self.cache_on_write = cache_on_write
        self.stats = CacheStats()

    @property
    def store_gets(self) -> int:
        """Store GETs led by this cluster (all counting routes through
        ``stats.store_gets`` — never bumped ad hoc by callers)."""
        return self.stats.store_gets

    def owner_of(self, blob_id: str) -> int:
        """Rendezvous (highest-random-weight) routing: the owner is the
        member with the highest hash(blob, member). Unlike mod-N, growing
        or shrinking the member set re-routes only the minimal share of
        keys — the property ``resize`` relies on during rebalances."""
        n = len(self.members)
        if n == 1:
            return 0
        key = blob_id.encode()
        best, owner = -1, 0
        for m in range(n):
            w = stable_hash64(key + bytes((m & 0xFF, (m >> 8) & 0xFF)))
            if w > best:
                best, owner = w, m
        return owner

    def resize(self, n_members: int) -> int:
        """Change the member count WITHOUT flushing: every cached payload
        is re-routed to its new rendezvous owner (entries on surviving
        members that keep their owner do not move at all). Called by the
        cluster layer when a rebalance changes the per-AZ worker set; the
        moved count lands in ``stats.reroutes``."""
        n = max(1, int(n_members))
        old = len(self.members)
        if n == old:
            return 0
        cap = self.members[0].capacity
        if n > old:
            self.members.extend(LRUCache(cap) for _ in range(n - old))
            removed: List[LRUCache] = []
        else:
            removed = self.members[n:]
            del self.members[n:]
        moved = 0
        for idx, m in enumerate(self.members):
            stale = [(k, own) for k in m.entries
                     if (own := self.owner_of(k)) != idx]
            for key, own in stale:
                payload = m.entries.pop(key)
                m.size -= len(payload)
                self.members[own].put(key, payload)
                moved += 1
        for m in removed:
            for key, payload in m.entries.items():
                self.members[self.owner_of(key)].put(key, payload)
                moved += 1
        self.stats.reroutes += moved
        return moved

    def write(self, blob_id: str, payload: bytes, now: float = 0.0) -> float:
        """Write path: member uploads to the store; optionally caches."""
        lat = self.store.put(blob_id, payload, now, az=self.az)
        if self.cache_on_write:
            self.members[self.owner_of(blob_id)].put(blob_id, payload)
        return lat

    # -- event-driven API (async engine path) ------------------------------
    def probe(self, blob_id: str) -> Optional[bytes]:
        """Non-blocking owner lookup used by the engine's GET path: returns
        the payload on a hit (counting it), None on a miss. The engine then
        decides between coalescing onto an in-flight download and leading a
        store GET, and inserts via ``fill`` at the completion event — so
        cache fills genuinely race concurrent reads on the virtual clock."""
        hit = self.members[self.owner_of(blob_id)].get(blob_id)
        if hit is not None:
            self.stats.hits += 1
        return hit

    def note_miss(self, coalesced: bool = False) -> None:
        """Account a probe miss (coalesced = served by in-flight leader)."""
        if coalesced:
            self.stats.coalesced += 1
        else:
            self.stats.misses += 1

    def fill(self, blob_id: str, payload: bytes) -> None:
        """Insert into the owning member (write-through or GET completion)."""
        self.members[self.owner_of(blob_id)].put(blob_id, payload)

    def begin_store_get(self, blob_id: str, now: float = 0.0
                        ) -> Tuple[int, float]:
        """Lead one store GET on behalf of this cluster (async engine
        path): the single choke point for request accounting, so
        ``store.stats.gets`` and ``stats.store_gets`` stay consistent.
        Raises ``StoreError`` without counting if the request fails."""
        size, lat = self.store.begin_get(blob_id, now=now, az=self.az)
        self.stats.store_gets += 1
        if self.obs is not None:
            self.obs.on_store_get(self.az, size, lat, now)
        return size, lat

    def read(self, blob_id: str, now: float = 0.0) -> Tuple[bytes, float, str]:
        """Read path. Returns (payload, latency, source) where source is
        one of "cache" | "store" | "coalesced" (latency excludes queueing
        behind an in-flight download — the simulator handles that)."""
        member = self.members[self.owner_of(blob_id)]
        hit = member.get(blob_id)
        if hit is not None:
            self.stats.hits += 1
            return hit, 0.0005, "cache"  # intra-AZ RPC
        if not self.flight.begin(blob_id):
            # single-flight invariant: a coalesced request rides the
            # leader's download — served from the store's payload view,
            # never issuing (or accounting) a second store GET
            self.stats.coalesced += 1
            payload = self.store.payload(blob_id)
            return payload, 0.0005, "coalesced"
        self.stats.misses += 1
        try:
            payload, lat = self.store.get(blob_id, now=now, az=self.az)
        except (StoreError, KeyError):
            # leader failed before filling (fault injection, or the
            # object expired): release leadership so the retry — or the
            # next reader — can lead a fresh download, and so a later
            # success fills the member exactly once
            self.flight.complete(blob_id, b"")
            raise
        self.stats.store_gets += 1
        member.put(blob_id, payload)
        self.flight.complete(blob_id, payload)
        return payload, lat, "store"


class LocalCache:
    """Optional per-instance layer in front of the distributed cache."""

    def __init__(self, capacity_bytes: int, remote: DistributedCache):
        self.lru = LRUCache(capacity_bytes)
        self.remote = remote

    def probe(self, blob_id: str) -> Optional[bytes]:
        return self.lru.get(blob_id)

    def fill(self, blob_id: str, payload: bytes) -> None:
        self.lru.put(blob_id, payload)

    def read(self, blob_id: str, now: float = 0.0) -> Tuple[bytes, float, str]:
        hit = self.lru.get(blob_id)
        if hit is not None:
            return hit, 0.00005, "local"
        payload, lat, src = self.remote.read(blob_id, now)
        self.lru.put(blob_id, payload)
        return payload, lat, src
