"""Event-driven async BlobShuffle engine (virtual clock).

Replaces the strictly sequential PUT → notify → GET → commit execution of
the original pipeline facade with a discrete-event model of the paper's
actual concurrency structure (§3, §5):

  * finalized blobs enter a **bounded per-instance upload lane**
    (``upload_parallelism`` in-flight PUTs; the rest queue), with PUT
    completions sampled from ``SimulatedS3``'s lognormal latency model;
  * notification **fan-out** is asynchronous: each contributing partition's
    notification is delivered to the destination AZ's Debatcher after a
    messaging delay;
  * Debatchers **prefetch**: up to ``fetch_parallelism`` speculative GETs
    are issued the moment notifications arrive, so retrieval latency
    overlaps both other GETs and the producers' uploads;
  * **cache fills race reads**: the write-through fill lands one event
    after PUT completion, so an early prefetch can miss the cache, lead a
    store GET, and later requests coalesce onto it (single-flight);
  * **commits route through ``CommitCoordinator``**: a commit begins by
    flushing buffers into the upload lane and finishes only when every
    outstanding PUT is durable; under exactly-once, notifications become
    visible in commit batches (read-committed), so duplicate, reordered,
    or replayed work never double-delivers downstream.

Both lanes are resilient against an unreliable ``BlobStore`` (e.g. a
``FaultyStore``-wrapped tier): failed PUTs/GETs retry with exponential
backoff + deterministic jitter (503 SlowDown responses additionally
honor the server's retry-after hint and put the lane under a
backpressure penalty that collapses its parallelism to 1); slow GETs can
be hedged with a second request once the observed latency quantile is
exceeded, first completion wins. A periodic retention sweep deletes
expired blobs on the virtual clock, and end-of-run storage accrual folds
still-live objects into ``StoreStats.byte_seconds``.

Everything runs on the deterministic ``EventLoop`` in
``repro.core.events`` — a fixed seed reproduces the exact event order,
including every retry, backoff draw, and hedge.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.blob import Blob, Notification
from repro.core.cache import DistributedCache, LocalCache
from repro.core.commit import CommitCoordinator
from repro.core.debatcher import Debatcher
from repro.core.events import EventLoop
from repro.core.recordbatch import RecordBatch, default_partitioner_batch
from repro.core.records import Record, default_partitioner
from repro.core.stores import BlobStore, SimulatedS3, SlowDownError, StoreError
from repro.core.strategy import make_strategy
from repro.obs import make_observability
from repro.obs.sketch import QuantileSketch

GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Concurrency + resilience knobs of the async engine.

    ``upload_parallelism = fetch_parallelism = 1`` degenerates to the old
    synchronous single-in-flight execution — the baseline the paper's
    batching/caching design is measured against.
    """
    upload_parallelism: int = 4        # in-flight PUTs per instance
    fetch_parallelism: int = 8         # in-flight GETs per AZ Debatcher
    commit_interval_s: Optional[float] = None  # None: commit on drain only
    notification_latency_s: float = 0.002      # messaging-layer delay
    # extra delay for a notification whose producer and consumer sit in
    # different AZs (mirrors the cross-AZ penalties of stores/express.py);
    # 0.0 keeps the legacy uniform-latency behavior bit-identical
    cross_az_notification_extra_s: float = 0.0
    cache_fill_latency_s: float = 0.001        # write-through fill delay
    rpc_latency_s: float = 0.0005              # intra-AZ cache RPC
    local_latency_s: float = 0.00005           # local-cache lookup
    # -- retry / backoff (per failed PUT or GET attempt) -------------------
    max_attempts: int = 8              # attempts before a request aborts
    backoff_base_s: float = 0.05       # exponential: base × 2^(attempt-1)
    backoff_max_s: float = 5.0
    backoff_jitter: float = 0.5        # uniform [0, jitter] × backoff extra
    throttle_penalty_s: float = 0.25   # lane parallelism → 1 after a 503
    # -- hedged GETs --------------------------------------------------------
    hedge_quantile: Optional[float] = None  # e.g. 95.0; None disables
    hedge_min_samples: int = 20        # observed GETs before hedging arms
    # cross-check the streaming hedge-threshold sketch against an exact
    # np.percentile pass on every refresh (test/debug only: restores the
    # O(n log n) cost the sketch removes)
    hedge_debug_exact: bool = False
    # -- retention ----------------------------------------------------------
    retention_sweep_s: Optional[float] = None  # periodic expiry sweep


@dataclasses.dataclass
class ShuffleMetrics:
    """Per-run measurements: end-to-end record latency = delivery time
    minus source arrival time (includes batching wait, upload-lane
    queueing, PUT, notification, fetch queueing, and GET)."""
    records_in: int = 0
    records_delivered: int = 0
    records_replayed: int = 0
    bytes_delivered: int = 0
    duplicates_delivered: int = 0
    makespan_s: float = 0.0
    record_latencies: List[float] = dataclasses.field(default_factory=list)
    # delivery (virtual) time of each latency sample, index-aligned with
    # record_latencies — lets callers window percentiles (e.g. "p95 during
    # the rebalance") without changing the latency list itself
    record_latency_times: List[float] = dataclasses.field(
        default_factory=list)
    put_latencies: List[float] = dataclasses.field(default_factory=list)
    get_latencies: List[float] = dataclasses.field(default_factory=list)
    # resilience counters
    put_retries: int = 0
    get_retries: int = 0
    uploads_aborted: int = 0           # blobs dropped after max_attempts
    uploads_aborted_bytes: int = 0
    # blobs that died with a crashed instance: queued in its upload lane,
    # or in flight when the epoch bumped (their completion events no-op)
    uploads_lost: int = 0
    uploads_lost_bytes: int = 0
    fetches_aborted: int = 0
    throttle_events: int = 0           # 503 SlowDown responses observed
    hedges_issued: int = 0
    hedges_won: int = 0                # hedge completed before the primary
    retention_sweeps: int = 0
    retention_deleted: int = 0

    def latency_p(self, q: float) -> float:
        if not self.record_latencies:
            return float("nan")
        return float(np.percentile(self.record_latencies, q))

    def summary(self, store: BlobStore) -> Dict[str, float]:
        shuffled_gib = store.stats.put_bytes / GiB
        cost = store.stats.cost_usd(store.costs, store.retention_s)
        return {
            "records": float(self.records_delivered),
            "p50_s": self.latency_p(50),
            "p95_s": self.latency_p(95),
            "p99_s": self.latency_p(99),
            "makespan_s": self.makespan_s,
            "throughput_bytes_s": (self.bytes_delivered / self.makespan_s
                                   if self.makespan_s > 0 else 0.0),
            "cost_usd": cost,
            "cost_per_gib": cost / shuffled_gib if shuffled_gib else 0.0,
        }


@dataclasses.dataclass
class _Fetch:
    note: Notification
    enqueued_at: float
    attempt: int = 0
    done: bool = False      # set by the first completion (primary or hedge)
    # cluster-mode provenance: the notification-log offset being delivered
    # and the worker it was scheduled for (None on the direct fan-out path)
    offset: Optional[int] = None
    worker: Optional[str] = None


class AsyncShuffleEngine:
    """Virtual-clock BlobShuffle topology: n instances × num_az AZs."""

    def __init__(self, cfg: BlobShuffleConfig,
                 engine_cfg: Optional[EngineConfig] = None, *,
                 n_instances: int = 3, store: Optional[BlobStore] = None,
                 seed: int = 0, exactly_once: bool = True,
                 strategy=None, obs=None):
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.n_instances = n_instances
        self.exactly_once = exactly_once
        self.loop = EventLoop()
        # opt-in observability (None | True | ObsConfig | Observability):
        # pure side-tables — hooks never schedule events or consume RNG,
        # so observed and unobserved runs are bit-identical
        self.obs = make_observability(obs)
        self.store = store or SimulatedS3(seed=seed,
                                          retention_s=cfg.retention_s)
        self.caches = [
            DistributedCache(az, max(n_instances // cfg.num_az, 1),
                             cfg.distributed_cache_bytes, self.store,
                             cfg.cache_on_write)
            for az in range(cfg.num_az)]
        self.debatchers: List[Debatcher] = []
        for az in range(cfg.num_az):
            local = (LocalCache(cfg.local_cache_bytes, self.caches[az])
                     if cfg.local_cache_bytes else None)
            self.debatchers.append(
                Debatcher(az, self.caches[az], local,
                          exactly_once=exactly_once))
        if self.obs is not None:
            for c in self.caches:
                c.obs = self.obs
            for d in self.debatchers:
                d.obs = self.obs
        # elastic-cluster hook: when an ``ElasticCluster`` is attached,
        # notification fan-out routes through its durable log instead of
        # the fixed-delay direct delivery, and instances can join/leave
        self.cluster = None
        # pluggable shuffle policy (None | registered name | instance);
        # DefaultStrategy makes every hook the identity — bit-identical
        # to the pre-seam engine
        self.strategy = make_strategy(strategy)
        self.strategy.bind(self)
        # per-instance state: the instance set is DYNAMIC — every list
        # below grows via add_instance() and entries deactivate (but are
        # never removed, so indices stay stable) via remove_instance/_fail
        self.batchers: List[Batcher] = []
        self.coordinators: List[CommitCoordinator] = []
        self._inst_az: List[int] = []
        self.active: List[bool] = []
        # producer side: per-instance bounded upload lanes
        # queue entries are (blob, notes, attempt)
        self._upload_q: List[Deque[Tuple[Blob, List[Notification], int]]] = []
        self._uploads_inflight: List[int] = []
        self._epoch: List[int] = []        # bumped on failure injection
        self._upload_penalty: List[float] = []
        # consumer side: per-AZ fetch queues + single-flight tracking
        self._fetch_q: List[Deque[_Fetch]] = [deque()
                                              for _ in range(cfg.num_az)]
        self._fetch_inflight = [0] * cfg.num_az
        # (az, blob_id) -> waiters parked behind the leading GET; key
        # presence marks a leader in flight (kept across leader retries)
        self._get_waiters: Dict[Tuple[int, str], List[_Fetch]] = {}
        # throttle backpressure: lane parallelism collapses to 1 until t
        self._fetch_penalty = [0.0] * cfg.num_az
        # deterministic jitter for retry backoff (separate stream from the
        # store's latency RNG so adding retries never perturbs latencies)
        self._retry_rng = np.random.default_rng(
            np.random.SeedSequence([seed, 0x5E7]))
        self._hedge_cached: Optional[Tuple[int, float]] = None
        # streaming GET-latency sketch backing the hedge threshold —
        # maintained only when hedging can read it, so the no-hedge hot
        # path is untouched
        self._get_sketch = (QuantileSketch()
                            if self.ecfg.hedge_quantile is not None
                            else None)
        # source arrival bookkeeping for end-to-end latency
        self._arrivals: Dict[Tuple[int, int], Deque[float]] = \
            defaultdict(deque)
        self._blob_arrivals: Dict[Tuple[str, int], List[float]] = {}
        self._flush_timers: Set[Tuple[int, int]] = set()
        self._pending_ingests = 0
        self._rr = 0
        self._t_done = 0.0
        self._started = False
        self.out: Dict[int, List[Record]] = defaultdict(list)
        self.published: List[Notification] = []
        self.metrics = ShuffleMetrics()
        for _ in range(n_instances):
            self.add_instance()

    def partition_to_az(self, partition: int) -> int:
        return partition % self.cfg.num_az

    def _partition_target_az(self, partition: int) -> int:
        """Destination AZ for buffering/blob placement — routed through
        the strategy so policies like push-based shuffle can follow the
        cluster assignor instead of the static layout."""
        return self.strategy.partition_target_az(partition)

    def on_assignment_changed(self) -> None:
        """Cluster hook: the partition→worker assignment changed. The
        batchers' cached partition→AZ tables may now be stale (a
        strategy can route by owner AZ), so drop them for lazy
        recompute; then let the strategy re-snapshot."""
        for b in self.batchers:
            b._az_table = None
        self.strategy.on_assignment_changed()

    # -- elastic instance set ---------------------------------------------
    def add_instance(self, az: Optional[int] = None) -> int:
        """Provision one more batcher instance (elastic scale-out). The
        new instance joins the ingest round-robin immediately; its AZ
        defaults to the round-robin AZ layout. Returns the instance id."""
        cfg = self.cfg
        i = len(self.batchers)
        if az is None:
            az = i % cfg.num_az
        self._inst_az.append(az)
        self.active.append(True)
        b = Batcher(cfg, self._partition_target_az,
                    lambda key: default_partitioner(
                        key, cfg.num_partitions),
                    self.caches[az], uploader=self._make_uploader(i),
                    name=f"i{i}",
                    partitioner_batch=lambda batch: (
                        default_partitioner_batch(
                            batch, cfg.num_partitions)))
        b.obs = self.obs
        self.batchers.append(b)
        self.coordinators.append(
            CommitCoordinator(b, self.debatchers, self._make_publisher(i)))
        self._upload_q.append(deque())
        self._uploads_inflight.append(0)
        self._epoch.append(0)
        self._upload_penalty.append(0.0)
        self.n_instances = len(self.batchers)
        return i

    def remove_instance(self, i: int) -> None:
        """Gracefully drain instance ``i`` (elastic scale-in): it leaves
        the ingest round-robin now, flushes its buffers, and commits once
        its outstanding uploads are durable."""
        self.active[i] = False
        c = self.coordinators[i]
        c.begin_commit(self.loop.now)
        if c.try_finish_commit(self.loop.now):
            self._t_done = max(self._t_done, self.loop.now)

    def attach_cluster(self, cluster) -> None:
        self.cluster = cluster

    def _make_publisher(self, i: int) -> Callable[[Notification], None]:
        def publish(note: Notification) -> None:
            self._publish(note, i)
        return publish

    def _next_inst(self) -> int:
        n = self.n_instances
        for _ in range(n):
            i = self._rr
            self._rr = (self._rr + 1) % n
            if self.active[i]:
                return i
        return self._rr    # no active instance left: route anywhere

    # -- ingest -----------------------------------------------------------
    def submit(self, t: float, rec: Record,
               inst: Optional[int] = None) -> None:
        """Schedule one source record to arrive at instance ``inst`` (or
        round-robin over the instances ACTIVE at arrival time) at virtual
        time ``t``."""
        self._pending_ingests += 1
        self.metrics.records_in += 1
        if inst is not None:
            self.loop.at(t, self._ingest, inst, rec)
        else:
            self.loop.at(t, self._ingest_rr, rec)

    def _ingest_rr(self, rec: Record) -> None:
        # the instance is picked when the record ARRIVES, not when it was
        # scheduled — a load balancer routes around left/crashed instances
        # and onto ones that joined mid-stream
        self._ingest(self._next_inst(), rec)

    def _ingest(self, i: int, rec: Record) -> None:
        now = self.loop.now
        b = self.batchers[i]
        part = b.partitioner(rec.key)
        az = self._partition_target_az(part)
        # arrival enters the FIFO before Batcher.process so a size-triggered
        # finalize inside process() already sees it
        self._arrivals[(i, part)].append(now)
        self.coordinators[i].process(rec, now)
        self._arm_flush_timer(i, az)
        if self.obs is not None:
            self.obs.on_ingest(self._inst_az[i], 1, now)
        self._note_ingested(1)

    def submit_batch(self, t: float, batch: RecordBatch,
                     inst: Optional[int] = None,
                     times: Optional[np.ndarray] = None) -> None:
        """Schedule a whole ``RecordBatch`` to arrive at instance ``inst``
        (or round-robin) at virtual time ``t`` — the columnar ingest lane.

        ``times`` optionally carries each record's true source arrival
        time (for end-to-end latency accounting); the batch itself is
        processed when it is delivered at ``t``, like an upstream consumer
        poll that hands over one micro-batch."""
        self._pending_ingests += len(batch)
        self.metrics.records_in += len(batch)
        self.loop.at(t, self._ingest_batch, inst, batch, times)

    def _ingest_batch(self, inst: Optional[int], batch: RecordBatch,
                      times: Optional[np.ndarray]) -> None:
        i = self._next_inst() if inst is None else inst
        now = self.loop.now
        n0 = len(batch)
        if n0 == 0:
            self._note_ingested(0)
            return
        # strategy hook: map-side combining shrinks the batch (and its
        # aligned arrival times) BEFORE partitioning and the arrival
        # FIFOs, so latency bookkeeping tracks the surviving records
        batch, times = self.strategy.prepare_batch(batch, times)
        n = len(batch)
        b = self.batchers[i]
        parts = b.compute_partitions(batch)
        # arrivals enter the per-partition FIFOs (in row = arrival order)
        # before ingest so finalizes inside ingest() already see them;
        # the (AZ, partition) grouping is computed once and cached on the
        # batch — Batcher.ingest reuses it instead of re-sorting
        order, starts = b._group(batch)
        for s, e in zip(starts[:-1], starts[1:]):
            g = order[s:e]
            part = int(parts[g[0]])
            fifo = self._arrivals[(i, part)]
            if times is None:
                fifo.extend([now] * len(g))
            else:
                fifo.extend(float(times[j]) for j in g)
        self.coordinators[i].ingest(batch, now)
        az_table = b._partition_az_table()
        for az in dict.fromkeys(int(a) for a in az_table[parts]):
            self._arm_flush_timer(i, az)
        if self.obs is not None:
            self.obs.on_ingest(self._inst_az[i], n, now)
        self._note_ingested(n0)

    def _arm_flush_timer(self, i: int, az: int) -> None:
        if (self.batchers[i].buffer_bytes.get(az, 0) > 0
                and (i, az) not in self._flush_timers):
            self._flush_timers.add((i, az))
            self.loop.after(self.cfg.max_interval_s + 1e-9,
                            self._flush_check, i, az)

    def _note_ingested(self, n: int) -> None:
        self._pending_ingests -= n
        if self._pending_ingests == 0:
            # sources drained: flush + commit whatever remains
            self.loop.after(1e-6, self._commit_all)

    def _flush_check(self, i: int, az: int) -> None:
        b = self.batchers[i]
        self._flush_timers.discard((i, az))
        if b.buffer_bytes.get(az, 0) <= 0:
            return
        due = b.last_finalize.get(az, self.loop.now) + b.cfg.max_interval_s
        if self.loop.now >= due - 1e-12:
            b.flush_due(self.loop.now)
        else:
            self._flush_timers.add((i, az))
            self.loop.at(due + 1e-9, self._flush_check, i, az)

    # -- retry/backoff helpers --------------------------------------------
    def _backoff(self, attempt: int, err: StoreError) -> float:
        """Exponential backoff with deterministic jitter; 503 responses
        additionally honor the server's retry-after hint."""
        base = min(self.ecfg.backoff_max_s,
                   self.ecfg.backoff_base_s * 2.0 ** max(attempt - 1, 0))
        jit = base * self.ecfg.backoff_jitter * float(self._retry_rng.random())
        return max(base + jit, err.retry_after_s)

    def _note_throttle(self, penalties: List[float], lane: int,
                       err: StoreError) -> None:
        if isinstance(err, SlowDownError):
            self.metrics.throttle_events += 1
            penalties[lane] = max(penalties[lane],
                                  self.loop.now + self.ecfg.throttle_penalty_s)

    def _lane_cap(self, penalties: List[float], lane: int,
                  cap: int) -> int:
        return 1 if self.loop.now < penalties[lane] else max(1, cap)

    # -- upload lane ------------------------------------------------------
    def _make_uploader(self, i: int) -> Callable:
        def uploader(blob: Blob, notes: List[Notification],
                     counts: Dict[int, int], now: float) -> None:
            for part, cnt in counts.items():
                q = self._arrivals.get((i, part))
                n = min(cnt, len(q)) if q else 0
                self._blob_arrivals[(blob.blob_id, part)] = \
                    [q.popleft() for _ in range(n)]
            self.coordinators[i].note_upload_started(blob.blob_id)
            self._upload_q[i].append((blob, notes, 0))
            if self.obs is not None:
                first = min(
                    (a[0] for part in counts
                     if (a := self._blob_arrivals[(blob.blob_id, part)])),
                    default=None)
                self.obs.on_blob_handed_off(blob, self._inst_az[i],
                                            first, now)
            self._pump_uploads(i)
        return uploader

    def _pump_uploads(self, i: int) -> None:
        cap = self._lane_cap(self._upload_penalty, i,
                             self.ecfg.upload_parallelism)
        while self._uploads_inflight[i] < cap and self._upload_q[i]:
            blob, notes, attempt = self._upload_q[i].popleft()
            self._uploads_inflight[i] += 1
            self._start_put(i, blob, notes, attempt)

    def _start_put(self, i: int, blob: Blob, notes: List[Notification],
                   attempt: int) -> None:
        # placement hook: push-based strategies PUT into the blob's
        # destination AZ so zonal stores home it next to its consumer
        az = self.strategy.put_az(blob, self._inst_az[i])
        try:
            lat = self.store.begin_put(blob.blob_id, blob.size,
                                       now=self.loop.now, az=az)
        except StoreError as e:
            self._note_throttle(self._upload_penalty, i, e)
            delay = self._backoff(attempt + 1, e)
            self.loop.after(e.detect_after_s, self._upload_failed, i, blob,
                            notes, attempt, delay, self._epoch[i])
            return
        self.loop.after(lat, self._upload_done, i, blob, notes, lat,
                        self._epoch[i])

    def _upload_failed(self, i: int, blob: Blob, notes: List[Notification],
                       attempt: int, delay: float, epoch: int) -> None:
        """Failure observed: release the lane slot and either requeue the
        blob after backoff or abort it past ``max_attempts``."""
        if epoch != self._epoch[i]:
            self.metrics.uploads_lost += 1
            self.metrics.uploads_lost_bytes += blob.size
            return
        self._uploads_inflight[i] -= 1
        if attempt + 1 >= self.ecfg.max_attempts:
            # persistent failure: drop the blob so commits don't hang (the
            # loss is visible in uploads_aborted and records_delivered)
            self.metrics.uploads_aborted += 1
            self.metrics.uploads_aborted_bytes += blob.size
            c = self.coordinators[i]
            c.note_upload_aborted(blob.blob_id)
            if c.try_finish_commit(self.loop.now):
                self._t_done = max(self._t_done, self.loop.now)
        else:
            self.metrics.put_retries += 1
            self.loop.after(delay, self._requeue_upload, i, blob, notes,
                            attempt + 1, epoch)
        self._pump_uploads(i)

    def _requeue_upload(self, i: int, blob: Blob,
                        notes: List[Notification], attempt: int,
                        epoch: int) -> None:
        if epoch != self._epoch[i]:
            self.metrics.uploads_lost += 1
            self.metrics.uploads_lost_bytes += blob.size
            return
        self._upload_q[i].appendleft((blob, notes, attempt))
        self._pump_uploads(i)

    def _upload_done(self, i: int, blob: Blob, notes: List[Notification],
                     lat: float, epoch: int) -> None:
        if epoch != self._epoch[i]:
            # instance crashed mid-upload: connection died with it
            self.metrics.uploads_lost += 1
            self.metrics.uploads_lost_bytes += blob.size
            return
        now = self.loop.now
        inst_az = self._inst_az[i]
        put_az = self.strategy.put_az(blob, inst_az)
        self.store.finish_put(blob.blob_id, blob.payload, now, az=put_az)
        if put_az != inst_az:
            # zonal stores only see the placement AZ; surface the bytes
            # the producer routed cross-AZ so the cost model can price
            # the push (once per durable blob, not per attempt)
            self.strategy.stats.push_cross_az_bytes += blob.size
        self.metrics.put_latencies.append(lat)
        if self.obs is not None:
            self.obs.on_blob_durable(blob.blob_id, blob.size, put_az, lat,
                                     now)
        self._uploads_inflight[i] -= 1
        if self.cfg.cache_on_write:
            # write-through lands in the WRITER's AZ cluster (paper §3.3):
            # same-AZ consumers hit it; cross-AZ consumers still lead one
            # store GET into their own cluster (model's 2/3 GET ratio).
            # Push-based strategies redirect the fill to the destination
            # AZ's cluster instead, making consumer reads zonal.
            self.loop.after(self.ecfg.cache_fill_latency_s,
                            self.caches[
                                self.strategy.fill_az(blob, inst_az)].fill,
                            blob.blob_id, blob.payload)
        c = self.coordinators[i]
        c.note_upload_complete(blob.blob_id, notes,
                               publish_now=not self.exactly_once)
        if c.try_finish_commit(now):
            self._t_done = max(self._t_done, now)
        self._pump_uploads(i)

    # -- notification fan-out + prefetching fetch lane --------------------
    def _publish(self, note: Notification, inst: Optional[int] = None) -> None:
        if self.strategy.on_publish(note, inst):
            # intercepted (e.g. parked for a two-round merge): the
            # strategy now owns eventual delivery, and the note does not
            # count as published downstream
            return
        self.published.append(note)
        if self.obs is not None:
            self.obs.on_note_published(note, self.loop.now)
        if self.cluster is not None:
            # elastic mode: the notification becomes a durable log entry
            # and is delivered to the partition's current OWNER (which may
            # sit in any AZ) — or replayed later if ownership is in flux
            self.cluster.publish(
                note, None if inst is None else self._inst_az[inst])
            return
        delay = self.ecfg.notification_latency_s
        if (inst is not None
                and self._inst_az[inst] != note.target_az):
            delay += self.ecfg.cross_az_notification_extra_s
        self.loop.after(delay, self._notify, note)

    def _notify(self, note: Notification) -> None:
        az = note.target_az
        if not self.debatchers[az].begin(note):
            return  # duplicate claimed/dropped before any fetch is issued
        self._fetch_q[az].append(_Fetch(note, self.loop.now))
        self._pump_fetches(az)

    def cluster_deliver(self, az: int, note: Notification, offset: int,
                        worker: str) -> None:
        """Cluster-mode delivery of one notification-log entry to the
        owning worker's AZ fetch lane. Dedup moves from
        ``Debatcher.begin`` (claim-on-admit) to delivery completion
        (``ElasticCluster.on_delivery`` — by log offset AND (blob,
        partition)): a crashed owner's claimed-but-undelivered entries
        must REPLAY to the next owner instead of being dropped."""
        if (self.cluster is not None
                and not self.cluster.membership.is_alive_now(worker)):
            self.cluster.stats.stale_drops += 1
            return      # the owner died in transit: replay covers this
        self.debatchers[az].stats.notifications += 1
        self._fetch_q[az].append(_Fetch(note, self.loop.now, offset=offset,
                                        worker=worker))
        self._pump_fetches(az)

    def _pump_fetches(self, az: int) -> None:
        cap = self._lane_cap(self._fetch_penalty, az,
                             self.ecfg.fetch_parallelism)
        while self._fetch_inflight[az] < cap and self._fetch_q[az]:
            f = self._fetch_q[az].popleft()
            self._fetch_inflight[az] += 1
            self._issue_fetch(az, f)

    def _issue_fetch(self, az: int, f: _Fetch) -> None:
        blob_id = f.note.blob_id
        d = self.debatchers[az]
        cache = self.caches[az]
        if d.local is not None:
            hit = d.local.probe(blob_id)
            if hit is not None:
                self.loop.after(self.ecfg.local_latency_s,
                                self._fetch_done, az, f, hit, "local")
                return
        hit = cache.probe(blob_id)
        if hit is not None:
            self.loop.after(self.ecfg.rpc_latency_s,
                            self._fetch_done, az, f, hit, "cache")
            return
        key = (az, blob_id)
        waiters = self._get_waiters.get(key)
        if waiters is not None:
            # single-flight: park behind the in-flight leader (the slot
            # stays held) and complete when the leader's download lands —
            # robust to the leader retrying or aborting in between
            cache.note_miss(coalesced=True)
            waiters.append(f)
            return
        cache.note_miss(coalesced=False)
        self._get_waiters[key] = []
        self._lead_get(az, f)

    def _note_get_latency(self, lat: float) -> None:
        """Record one issued store GET's latency (lead, hedge, or merge
        compactor read): the list feeds end-of-run summaries, the sketch
        feeds the streaming hedge threshold."""
        self.metrics.get_latencies.append(lat)
        if self._get_sketch is not None:
            self._get_sketch.add(lat)

    def _lead_get(self, az: int, f: _Fetch) -> None:
        """Issue (or re-issue after a failure) the leading store GET."""
        try:
            _, lat = self.caches[az].begin_store_get(f.note.blob_id,
                                                     now=self.loop.now)
        except StoreError as e:
            self._note_throttle(self._fetch_penalty, az, e)
            delay = self._backoff(f.attempt + 1, e)
            self.loop.after(e.detect_after_s, self._get_failed, az, f,
                            delay)
            return
        except KeyError:
            # blob expired (retention) or was never durable: permanent
            # miss — retrying cannot help, abort the whole flight
            self._abort_flight(az, f)
            return
        self._note_get_latency(lat)
        done = self.loop.now + lat
        self.loop.after(lat, self._store_get_done, az, f)
        hedge_at = self._hedge_threshold()
        if hedge_at is not None and lat > hedge_at:
            self.loop.after(hedge_at, self._hedge_fire, az, f, done)

    def _hedge_threshold(self) -> Optional[float]:
        q = self.ecfg.hedge_quantile
        if q is None:
            return None
        sk = self._get_sketch
        n = sk.count
        if n < self.ecfg.hedge_min_samples:
            return None
        # the threshold comes from the streaming sketch: O(1) per
        # observed GET, O(bins) per refresh — the full-list
        # np.percentile pass this used to take grew O(n log n) with the
        # run. Refreshing every 32 samples keeps the threshold stable
        # between refreshes (same cadence as before).
        bucket = n // 32
        if self._hedge_cached is None or self._hedge_cached[0] != bucket:
            est = float(sk.percentile(q))
            if self.ecfg.hedge_debug_exact:
                exact = float(np.percentile(self.metrics.get_latencies, q))
                if exact > 0.0 and abs(est - exact) > 0.02 * exact:
                    raise AssertionError(
                        f"hedge sketch diverged from exact percentile: "
                        f"sketch {est:.6g} vs exact {exact:.6g} at "
                        f"q={q} (n={n})")
            self._hedge_cached = (bucket, est)
        return self._hedge_cached[1]

    def _hedge_fire(self, az: int, f: _Fetch, primary_done: float) -> None:
        """The primary GET exceeded the hedge quantile: race a second
        request against it; the first completion wins (``f.done``)."""
        if f.done:
            return
        self.metrics.hedges_issued += 1
        try:
            _, lat = self.caches[az].begin_store_get(f.note.blob_id,
                                                     now=self.loop.now)
        except (StoreError, KeyError):
            return      # hedge hit a fault: the primary is still running
        self._note_get_latency(lat)
        if self.loop.now + lat < primary_done:
            self.metrics.hedges_won += 1
            self.loop.after(lat, self._store_get_done, az, f)

    def _abort_flight(self, az: int, f: _Fetch) -> None:
        """Permanently fail a leader fetch and every parked waiter (the
        object is gone — expired before delivery): release their lane
        slots and surface the loss in ``fetches_aborted``."""
        f.done = True
        waiters = self._get_waiters.pop((az, f.note.blob_id), [])
        self.metrics.fetches_aborted += 1 + len(waiters)
        self._fetch_inflight[az] -= 1 + len(waiters)
        self._pump_fetches(az)

    def _get_failed(self, az: int, f: _Fetch, delay: float) -> None:
        """Leader GET failure observed: back off and retry, or abort past
        ``max_attempts`` (promoting a parked waiter to leader)."""
        if f.done:
            return      # a hedge completed the fetch meanwhile
        f.attempt += 1
        if f.attempt >= self.ecfg.max_attempts:
            f.done = True
            self.metrics.fetches_aborted += 1
            key = (az, f.note.blob_id)
            waiters = self._get_waiters.pop(key, [])
            self._fetch_inflight[az] -= 1
            if waiters:
                leader, rest = waiters[0], waiters[1:]
                self._get_waiters[key] = rest
                self._lead_get(az, leader)
            self._pump_fetches(az)
            return
        self.metrics.get_retries += 1
        self.loop.after(delay, self._retry_get, az, f)

    def _retry_get(self, az: int, f: _Fetch) -> None:
        if f.done:
            return
        self._lead_get(az, f)

    def _store_get_done(self, az: int, f: _Fetch) -> None:
        if f.done:
            return      # the other of primary/hedge completed it first
        blob_id = f.note.blob_id
        try:
            payload = self.store.payload(blob_id)
        except KeyError:
            # expired between GET issue and completion: permanent loss
            self._abort_flight(az, f)
            return
        f.done = True
        self.caches[az].fill(blob_id, payload)
        waiters = self._get_waiters.pop((az, blob_id), [])
        for w in waiters:
            self.loop.after(self.ecfg.rpc_latency_s, self._fetch_done,
                            az, w, payload, "coalesced")
        self._fetch_done(az, f, payload, "store")

    def _fetch_done(self, az: int, f: _Fetch, payload: bytes,
                    src: str) -> None:
        now = self.loop.now
        if f.offset is not None:
            # cluster mode: the delivery point is the exactly-once gate —
            # stale owners (crashed/reassigned mid-fetch) and replayed
            # duplicates are dropped here, releasing the lane slot
            if not self.cluster.on_delivery(f.note, f.offset, f.worker):
                self._fetch_inflight[az] -= 1
                self._pump_fetches(az)
                return
        d = self.debatchers[az]
        if d.local is not None and src != "local":
            d.local.fill(f.note.blob_id, payload)
        recs = d.complete(f.note, payload, 0.0, src, now)
        self.out[f.note.partition].extend(recs)
        self.metrics.records_delivered += len(recs)
        self.metrics.bytes_delivered += f.note.byte_range.length
        arrivals = self._blob_arrivals.pop(
            (f.note.blob_id, f.note.partition), None)
        if arrivals is None:
            self.metrics.duplicates_delivered += len(recs)
            if self.obs is not None:
                self.obs.on_duplicate_delivery(az, len(recs), now)
        else:
            for t0 in arrivals:
                self.metrics.record_latencies.append(now - t0)
                self.metrics.record_latency_times.append(now)
            if self.obs is not None:
                self.obs.on_delivery(f.note, f.enqueued_at, arrivals,
                                     src, az, now)
        self._t_done = max(self._t_done, now)
        self._fetch_inflight[az] -= 1
        self._pump_fetches(az)

    # -- commits + failure injection --------------------------------------
    def commit_at(self, t: float) -> None:
        self.loop.at(t, self._commit_all)

    def _commit_all(self) -> None:
        now = self.loop.now
        for c in self.coordinators:
            if (c.batcher.buffered_bytes() == 0 and not c.outstanding
                    and not c.unpublished and not c.uncommitted
                    and c._commit_started is None):
                continue    # nothing to commit: don't extend the makespan
            if c._commit_started is not None and not c.uncommitted \
                    and c.batcher.buffered_bytes() == 0:
                continue    # in-flight commit already covers everything
            c.begin_commit(now)
            if c.try_finish_commit(now):
                self._t_done = max(self._t_done, now)
        if self.cluster is not None:
            # consumer-group offsets commit on the same cadence as the
            # engine's commit protocol (Kafka Streams commits source and
            # consumer offsets inside one commit)
            self.cluster.commit_offsets(now)

    def _commit_tick(self, interval: float) -> None:
        self._commit_all()
        if (self._pending_ingests > 0
                or any(b.buffered_bytes() for b in self.batchers)):
            self.loop.after(interval, self._commit_tick, interval)

    # -- retention ---------------------------------------------------------
    def _work_pending(self) -> bool:
        return (self._pending_ingests > 0
                or any(self._uploads_inflight)
                or any(self._upload_q)
                or any(self._fetch_inflight)
                or any(self._fetch_q)
                or any(b.buffered_bytes() for b in self.batchers)
                or self.strategy.work_pending())

    def _retention_tick(self, interval: float) -> None:
        """Periodic expiry sweep (paper §3.2): deletes blobs past the
        retention period and accrues their byte·seconds; reschedules
        itself while shuffle work is still in flight."""
        self.metrics.retention_sweeps += 1
        self.metrics.retention_deleted += \
            self.store.run_retention(self.loop.now)
        if self._work_pending():
            self.loop.after(interval, self._retention_tick, interval)

    def fail_at(self, t: float, inst: int, permanent: bool = False) -> None:
        """Inject a crash of ``inst`` at time ``t``: queued/in-flight
        uploads and buffers are lost, uncommitted records replay.
        ``permanent`` removes the instance from the round-robin (the
        elastic-cluster fail-stop model) instead of restarting it."""
        self.loop.at(t, self._fail, inst, permanent)

    def _fail(self, i: int, permanent: bool = False) -> None:
        now = self.loop.now
        self._epoch[i] += 1
        for blob, _notes, _attempt in self._upload_q[i]:
            # queued blobs die with the lane (in-flight ones are counted
            # when their completion events observe the stale epoch)
            self.metrics.uploads_lost += 1
            self.metrics.uploads_lost_bytes += blob.size
        self._upload_q[i].clear()
        self._uploads_inflight[i] = 0
        if self.obs is not None:
            self.obs.mark(f"crash:i{i}", now)
        if permanent:
            self.active[i] = False
        replay = self.coordinators[i].fail_and_restart(now)
        for key in [k for k in self._arrivals if k[0] == i]:
            self._arrivals[key].clear()   # buffered records were lost
        self.metrics.records_replayed += len(replay)
        for k, rec in enumerate(replay):
            self.submit(now + (k + 1) * 1e-6, rec)

    # -- driver ------------------------------------------------------------
    def start(self) -> None:
        """Arm the periodic commit/retention timers without running the
        loop. Idempotent. Callers that drive the clock incrementally
        (``loop.run(until=...)`` — e.g. the training input pipeline in
        ``repro.train_input``) need the commit cadence armed up front;
        otherwise, under exactly-once, nothing becomes visible until the
        sources fully drain."""
        if self._started:
            return
        self._started = True
        ci = self.ecfg.commit_interval_s
        if ci:
            self.loop.after(ci, self._commit_tick, ci)
        rs = self.ecfg.retention_sweep_s
        if rs:
            self.loop.after(rs, self._retention_tick, rs)

    def run(self, until: Optional[float] = None) -> ShuffleMetrics:
        """Run the event loop to completion (all submitted records
        delivered, all commits finished) and return the metrics."""
        self.start()
        self.loop.run(until)
        if self.cluster is not None:
            self.cluster.finalize(self.loop.now)
        # storage-cost correctness: fold still-live objects into the
        # byte·seconds integral so cost_usd(explicit_storage=True) is
        # exact even when nothing expired within the run
        self.store.accrue_storage(self.loop.now)
        self.metrics.makespan_s = self._t_done
        if self.obs is not None:
            self.obs.finalize_run(self)
        return self.metrics
