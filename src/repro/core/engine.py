"""Event-driven async BlobShuffle engine (virtual clock).

Replaces the strictly sequential PUT → notify → GET → commit execution of
the original pipeline facade with a discrete-event model of the paper's
actual concurrency structure (§3, §5):

  * finalized blobs enter a **bounded per-instance upload lane**
    (``upload_parallelism`` in-flight PUTs; the rest queue), with PUT
    completions sampled from ``SimulatedS3``'s lognormal latency model;
  * notification **fan-out** is asynchronous: each contributing partition's
    notification is delivered to the destination AZ's Debatcher after a
    messaging delay;
  * Debatchers **prefetch**: up to ``fetch_parallelism`` speculative GETs
    are issued the moment notifications arrive, so retrieval latency
    overlaps both other GETs and the producers' uploads;
  * **cache fills race reads**: the write-through fill lands one event
    after PUT completion, so an early prefetch can miss the cache, lead a
    store GET, and later requests coalesce onto it (single-flight);
  * **commits route through ``CommitCoordinator``**: a commit begins by
    flushing buffers into the upload lane and finishes only when every
    outstanding PUT is durable; under exactly-once, notifications become
    visible in commit batches (read-committed), so duplicate, reordered,
    or replayed work never double-delivers downstream.

Everything runs on the deterministic ``EventLoop`` in
``repro.core.events`` — a fixed seed reproduces the exact event order.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.blob import Blob, Notification
from repro.core.cache import DistributedCache, LocalCache
from repro.core.commit import CommitCoordinator
from repro.core.debatcher import Debatcher
from repro.core.events import EventLoop
from repro.core.records import Record, default_partitioner
from repro.core.store import SimulatedS3

GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Concurrency knobs of the async engine.

    ``upload_parallelism = fetch_parallelism = 1`` degenerates to the old
    synchronous single-in-flight execution — the baseline the paper's
    batching/caching design is measured against.
    """
    upload_parallelism: int = 4        # in-flight PUTs per instance
    fetch_parallelism: int = 8         # in-flight GETs per AZ Debatcher
    commit_interval_s: Optional[float] = None  # None: commit on drain only
    notification_latency_s: float = 0.002      # messaging-layer delay
    cache_fill_latency_s: float = 0.001        # write-through fill delay
    rpc_latency_s: float = 0.0005              # intra-AZ cache RPC
    local_latency_s: float = 0.00005           # local-cache lookup


@dataclasses.dataclass
class ShuffleMetrics:
    """Per-run measurements: end-to-end record latency = delivery time
    minus source arrival time (includes batching wait, upload-lane
    queueing, PUT, notification, fetch queueing, and GET)."""
    records_in: int = 0
    records_delivered: int = 0
    records_replayed: int = 0
    bytes_delivered: int = 0
    duplicates_delivered: int = 0
    makespan_s: float = 0.0
    record_latencies: List[float] = dataclasses.field(default_factory=list)
    put_latencies: List[float] = dataclasses.field(default_factory=list)
    get_latencies: List[float] = dataclasses.field(default_factory=list)

    def latency_p(self, q: float) -> float:
        if not self.record_latencies:
            return float("nan")
        return float(np.percentile(self.record_latencies, q))

    def summary(self, store: SimulatedS3) -> Dict[str, float]:
        shuffled_gib = store.stats.put_bytes / GiB
        cost = store.stats.cost_usd(store.costs, store.retention_s)
        return {
            "records": float(self.records_delivered),
            "p50_s": self.latency_p(50),
            "p95_s": self.latency_p(95),
            "p99_s": self.latency_p(99),
            "makespan_s": self.makespan_s,
            "throughput_bytes_s": (self.bytes_delivered / self.makespan_s
                                   if self.makespan_s > 0 else 0.0),
            "cost_usd": cost,
            "cost_per_gib": cost / shuffled_gib if shuffled_gib else 0.0,
        }


@dataclasses.dataclass
class _Fetch:
    note: Notification
    enqueued_at: float


class AsyncShuffleEngine:
    """Virtual-clock BlobShuffle topology: n instances × num_az AZs."""

    def __init__(self, cfg: BlobShuffleConfig,
                 engine_cfg: Optional[EngineConfig] = None, *,
                 n_instances: int = 3, store: Optional[SimulatedS3] = None,
                 seed: int = 0, exactly_once: bool = True):
        self.cfg = cfg
        self.ecfg = engine_cfg or EngineConfig()
        self.n_instances = n_instances
        self.exactly_once = exactly_once
        self.loop = EventLoop()
        self.store = store or SimulatedS3(seed=seed,
                                          retention_s=cfg.retention_s)
        self.caches = [
            DistributedCache(az, max(n_instances // cfg.num_az, 1),
                             cfg.distributed_cache_bytes, self.store,
                             cfg.cache_on_write)
            for az in range(cfg.num_az)]
        self.debatchers: List[Debatcher] = []
        for az in range(cfg.num_az):
            local = (LocalCache(cfg.local_cache_bytes, self.caches[az])
                     if cfg.local_cache_bytes else None)
            self.debatchers.append(
                Debatcher(az, self.caches[az], local,
                          exactly_once=exactly_once))
        self.batchers: List[Batcher] = []
        self.coordinators: List[CommitCoordinator] = []
        for i in range(n_instances):
            az = i % cfg.num_az
            b = Batcher(cfg, self.partition_to_az,
                        lambda key: default_partitioner(
                            key, cfg.num_partitions),
                        self.caches[az], uploader=self._make_uploader(i))
            self.batchers.append(b)
            self.coordinators.append(
                CommitCoordinator(b, self.debatchers, self._publish))

        # producer side: per-instance bounded upload lanes
        self._upload_q: List[Deque[Tuple[Blob, List[Notification]]]] = \
            [deque() for _ in range(n_instances)]
        self._uploads_inflight = [0] * n_instances
        self._epoch = [0] * n_instances    # bumped on failure injection
        # consumer side: per-AZ fetch queues + single-flight tracking
        self._fetch_q: List[Deque[_Fetch]] = [deque()
                                              for _ in range(cfg.num_az)]
        self._fetch_inflight = [0] * cfg.num_az
        self._get_inflight: Dict[Tuple[int, str], float] = {}
        # source arrival bookkeeping for end-to-end latency
        self._arrivals: Dict[Tuple[int, int], Deque[float]] = \
            defaultdict(deque)
        self._blob_arrivals: Dict[Tuple[str, int], List[float]] = {}
        self._flush_timers: Set[Tuple[int, int]] = set()
        self._pending_ingests = 0
        self._rr = 0
        self._t_done = 0.0
        self.out: Dict[int, List[Record]] = defaultdict(list)
        self.published: List[Notification] = []
        self.metrics = ShuffleMetrics()

    def partition_to_az(self, partition: int) -> int:
        return partition % self.cfg.num_az

    # -- ingest -----------------------------------------------------------
    def submit(self, t: float, rec: Record,
               inst: Optional[int] = None) -> None:
        """Schedule one source record to arrive at instance ``inst`` (or
        round-robin) at virtual time ``t``."""
        if inst is None:
            inst = self._rr
            self._rr = (self._rr + 1) % self.n_instances
        self._pending_ingests += 1
        self.metrics.records_in += 1
        self.loop.at(t, self._ingest, inst, rec)

    def _ingest(self, i: int, rec: Record) -> None:
        now = self.loop.now
        b = self.batchers[i]
        part = b.partitioner(rec.key)
        az = self.partition_to_az(part)
        # arrival enters the FIFO before Batcher.process so a size-triggered
        # finalize inside process() already sees it
        self._arrivals[(i, part)].append(now)
        self.coordinators[i].process(rec, now)
        if (b.buffer_bytes.get(az, 0) > 0
                and (i, az) not in self._flush_timers):
            self._flush_timers.add((i, az))
            self.loop.after(self.cfg.max_interval_s + 1e-9,
                            self._flush_check, i, az)
        self._pending_ingests -= 1
        if self._pending_ingests == 0:
            # sources drained: flush + commit whatever remains
            self.loop.after(1e-6, self._commit_all)

    def _flush_check(self, i: int, az: int) -> None:
        b = self.batchers[i]
        self._flush_timers.discard((i, az))
        if b.buffer_bytes.get(az, 0) <= 0:
            return
        due = b.last_finalize.get(az, self.loop.now) + b.cfg.max_interval_s
        if self.loop.now >= due - 1e-12:
            b.flush_due(self.loop.now)
        else:
            self._flush_timers.add((i, az))
            self.loop.at(due + 1e-9, self._flush_check, i, az)

    # -- upload lane ------------------------------------------------------
    def _make_uploader(self, i: int) -> Callable:
        def uploader(blob: Blob, notes: List[Notification],
                     parts: Dict[int, List[Record]], now: float) -> None:
            for part, recs in parts.items():
                q = self._arrivals.get((i, part))
                n = min(len(recs), len(q)) if q else 0
                self._blob_arrivals[(blob.blob_id, part)] = \
                    [q.popleft() for _ in range(n)]
            self.coordinators[i].note_upload_started(blob.blob_id)
            self._upload_q[i].append((blob, notes))
            self._pump_uploads(i)
        return uploader

    def _pump_uploads(self, i: int) -> None:
        cap = max(1, self.ecfg.upload_parallelism)
        while self._uploads_inflight[i] < cap and self._upload_q[i]:
            blob, notes = self._upload_q[i].popleft()
            self._uploads_inflight[i] += 1
            lat = self.store.begin_put(blob.size)
            self.loop.after(lat, self._upload_done, i, blob, notes, lat,
                            self._epoch[i])

    def _upload_done(self, i: int, blob: Blob, notes: List[Notification],
                     lat: float, epoch: int) -> None:
        if epoch != self._epoch[i]:
            return  # instance crashed mid-upload: connection died with it
        now = self.loop.now
        self.store.finish_put(blob.blob_id, blob.payload, now)
        self.metrics.put_latencies.append(lat)
        self._uploads_inflight[i] -= 1
        if self.cfg.cache_on_write:
            # write-through lands in the WRITER's AZ cluster (paper §3.3):
            # same-AZ consumers hit it; cross-AZ consumers still lead one
            # store GET into their own cluster (model's 2/3 GET ratio)
            self.loop.after(self.ecfg.cache_fill_latency_s,
                            self.caches[i % self.cfg.num_az].fill,
                            blob.blob_id, blob.payload)
        c = self.coordinators[i]
        c.note_upload_complete(blob.blob_id, notes,
                               publish_now=not self.exactly_once)
        if c.try_finish_commit(now):
            self._t_done = max(self._t_done, now)
        self._pump_uploads(i)

    # -- notification fan-out + prefetching fetch lane --------------------
    def _publish(self, note: Notification) -> None:
        self.published.append(note)
        self.loop.after(self.ecfg.notification_latency_s, self._notify,
                        note)

    def _notify(self, note: Notification) -> None:
        az = note.target_az
        if not self.debatchers[az].begin(note):
            return  # duplicate claimed/dropped before any fetch is issued
        self._fetch_q[az].append(_Fetch(note, self.loop.now))
        self._pump_fetches(az)

    def _pump_fetches(self, az: int) -> None:
        cap = max(1, self.ecfg.fetch_parallelism)
        while self._fetch_inflight[az] < cap and self._fetch_q[az]:
            f = self._fetch_q[az].popleft()
            self._fetch_inflight[az] += 1
            self._issue_fetch(az, f)

    def _issue_fetch(self, az: int, f: _Fetch) -> None:
        blob_id = f.note.blob_id
        d = self.debatchers[az]
        cache = self.caches[az]
        if d.local is not None:
            hit = d.local.probe(blob_id)
            if hit is not None:
                self.loop.after(self.ecfg.local_latency_s,
                                self._fetch_done, az, f, hit, "local")
                return
        hit = cache.probe(blob_id)
        if hit is not None:
            self.loop.after(self.ecfg.rpc_latency_s,
                            self._fetch_done, az, f, hit, "cache")
            return
        key = (az, blob_id)
        leader_done = self._get_inflight.get(key)
        if leader_done is not None:
            # single-flight: ride the in-flight download, complete just
            # after the leader does
            cache.note_miss(coalesced=True)
            delay = max(0.0, leader_done - self.loop.now) \
                + self.ecfg.rpc_latency_s
            self.loop.after(delay, self._coalesced_done, az, f)
            return
        cache.note_miss(coalesced=False)
        cache.store_gets += 1
        _, lat = self.store.begin_get(blob_id)
        self.metrics.get_latencies.append(lat)
        self._get_inflight[key] = self.loop.now + lat
        self.loop.after(lat, self._store_get_done, az, f)

    def _store_get_done(self, az: int, f: _Fetch) -> None:
        blob_id = f.note.blob_id
        payload = self.store.payload(blob_id)
        self.caches[az].fill(blob_id, payload)
        self._get_inflight.pop((az, blob_id), None)
        self._fetch_done(az, f, payload, "store")

    def _coalesced_done(self, az: int, f: _Fetch) -> None:
        self._fetch_done(az, f, self.store.payload(f.note.blob_id),
                         "coalesced")

    def _fetch_done(self, az: int, f: _Fetch, payload: bytes,
                    src: str) -> None:
        now = self.loop.now
        d = self.debatchers[az]
        if d.local is not None and src != "local":
            d.local.fill(f.note.blob_id, payload)
        recs = d.complete(f.note, payload, 0.0, src, now)
        self.out[f.note.partition].extend(recs)
        self.metrics.records_delivered += len(recs)
        self.metrics.bytes_delivered += f.note.byte_range.length
        arrivals = self._blob_arrivals.pop(
            (f.note.blob_id, f.note.partition), None)
        if arrivals is None:
            self.metrics.duplicates_delivered += len(recs)
        else:
            for t0 in arrivals:
                self.metrics.record_latencies.append(now - t0)
        self._t_done = max(self._t_done, now)
        self._fetch_inflight[az] -= 1
        self._pump_fetches(az)

    # -- commits + failure injection --------------------------------------
    def commit_at(self, t: float) -> None:
        self.loop.at(t, self._commit_all)

    def _commit_all(self) -> None:
        now = self.loop.now
        for c in self.coordinators:
            if (c.batcher.buffered_bytes() == 0 and not c.outstanding
                    and not c.unpublished and not c.uncommitted
                    and c._commit_started is None):
                continue    # nothing to commit: don't extend the makespan
            c.begin_commit(now)
            if c.try_finish_commit(now):
                self._t_done = max(self._t_done, now)

    def _commit_tick(self, interval: float) -> None:
        self._commit_all()
        if (self._pending_ingests > 0
                or any(b.buffered_bytes() for b in self.batchers)):
            self.loop.after(interval, self._commit_tick, interval)

    def fail_at(self, t: float, inst: int) -> None:
        """Inject a crash of ``inst`` at time ``t``: queued/in-flight
        uploads and buffers are lost, uncommitted records replay."""
        self.loop.at(t, self._fail, inst)

    def _fail(self, i: int) -> None:
        now = self.loop.now
        self._epoch[i] += 1
        self._upload_q[i].clear()
        self._uploads_inflight[i] = 0
        replay = self.coordinators[i].fail_and_restart(now)
        for key in [k for k in self._arrivals if k[0] == i]:
            self._arrivals[key].clear()   # buffered records were lost
        self.metrics.records_replayed += len(replay)
        for k, rec in enumerate(replay):
            self.submit(now + (k + 1) * 1e-6, rec)

    # -- driver ------------------------------------------------------------
    def run(self, until: Optional[float] = None) -> ShuffleMetrics:
        """Run the event loop to completion (all submitted records
        delivered, all commits finished) and return the metrics."""
        ci = self.ecfg.commit_interval_s
        if ci:
            self.loop.after(ci, self._commit_tick, ci)
        self.loop.run(until)
        self.metrics.makespan_s = self._t_done
        return self.metrics
