"""Commit protocol integration (paper §3.1/§3.2).

Mirrors Kafka Streams' periodic commits: state may only be committed once
(a) all blobs derived from processed records are durably stored,
(b) their notifications are published, and
(c) the Debatcher has fully processed all fetched batches.

Failures before commit roll back to the last committed offset: the source
records are REPLAYED (at-least-once); the Debatcher's (blob, partition)
dedup restores exactly-once at the output. Orphaned blobs (uploaded but
never referenced) stay unreachable and are collected by retention.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.batcher import Batcher
from repro.core.blob import Notification
from repro.core.debatcher import Debatcher
from repro.core.records import Record


@dataclasses.dataclass
class CommitStats:
    commits: int = 0
    commit_block_s: float = 0.0
    failures_injected: int = 0
    records_replayed: int = 0


class CommitCoordinator:
    """Drives a Batcher through commit intervals with failure injection."""

    def __init__(self, batcher: Batcher, debatchers: List[Debatcher],
                 publish: Callable[[Notification], None]):
        self.batcher = batcher
        self.debatchers = debatchers
        self.publish = publish
        self.uncommitted: List[Record] = []   # source records since commit
        self.unpublished: List[Notification] = []
        self.stats = CommitStats()

    def process(self, rec: Record, now: float) -> None:
        self.uncommitted.append(rec)
        for note in self.batcher.process(rec, now):
            self.unpublished.append(note)

    def commit(self, now: float) -> float:
        """Blocking commit. Returns the blocked duration (seconds)."""
        notes, block_w = self.batcher.on_commit(now)
        self.unpublished.extend(notes)
        for note in self.unpublished:
            self.publish(note)
        self.unpublished.clear()
        block_r = max((d.on_commit(now) for d in self.debatchers),
                      default=0.0)
        self.uncommitted.clear()
        self.stats.commits += 1
        blocked = max(block_w, block_r)
        self.stats.commit_block_s += blocked
        return blocked

    def fail_and_restart(self, now: float) -> List[Record]:
        """Crash before commit: uploads may be orphaned; notifications not
        yet published are lost; uncommitted source records replay."""
        self.stats.failures_injected += 1
        replay = list(self.uncommitted)
        self.stats.records_replayed += len(replay)
        # lost: pending uploads (orphans stay in the store — harmless),
        # unpublished notifications, and all in-memory buffers.
        self.batcher.pending.clear()
        self.batcher.ready.clear()
        self.batcher.buffers.clear()
        self.batcher.buffer_bytes.clear()
        self.unpublished.clear()
        self.uncommitted.clear()
        return replay
