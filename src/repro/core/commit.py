"""Commit protocol integration (paper §3.1/§3.2).

Mirrors Kafka Streams' periodic commits: state may only be committed once
(a) all blobs derived from processed records are durably stored,
(b) their notifications are published, and
(c) the Debatcher has fully processed all fetched batches.

Failures before commit roll back to the last committed offset: the source
records are REPLAYED (at-least-once); the Debatcher's (blob, partition)
dedup restores exactly-once at the output. Orphaned blobs (uploaded but
never referenced) stay unreachable and are collected by retention.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Set

from repro.core.batcher import Batcher
from repro.core.blob import Notification
from repro.core.debatcher import Debatcher
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record


@dataclasses.dataclass
class CommitStats:
    commits: int = 0
    commit_block_s: float = 0.0
    failures_injected: int = 0
    records_replayed: int = 0


class CommitCoordinator:
    """Drives a Batcher through commit intervals with failure injection."""

    def __init__(self, batcher: Batcher, debatchers: List[Debatcher],
                 publish: Callable[[Notification], None]):
        self.batcher = batcher
        self.debatchers = debatchers
        self.publish = publish
        # source records (or whole RecordBatches) since the last commit
        self.uncommitted: List = []
        self.unpublished: List[Notification] = []
        self.stats = CommitStats()
        # async-engine state: blobs whose PUT is still in flight, and the
        # start time of a commit waiting for them to drain (None = idle)
        self.outstanding: Set[str] = set()
        self._commit_started: Optional[float] = None
        # snapshot of the commit in progress: the uploads it waits for,
        # the notifications it will publish, and how many uncommitted
        # source units it covers — uploads/records arriving later belong
        # to the NEXT commit, so a commit finishes in bounded time even
        # under continuous load
        self._commit_wait: Set[str] = set()
        self._commit_notes: List[Notification] = []
        self._commit_n: int = 0
        self._commit_again: bool = False

    def process(self, rec: Record, now: float) -> None:
        self.uncommitted.append(rec)
        for note in self.batcher.process(rec, now):
            self.unpublished.append(note)

    def ingest(self, batch: RecordBatch, now: float) -> None:
        """Columnar bulk ingest: the whole batch is tracked as one
        uncommitted unit (flattened to records only on replay)."""
        self.uncommitted.append(batch)
        for note in self.batcher.ingest(batch, now):
            self.unpublished.append(note)

    def commit(self, now: float) -> float:
        """Blocking commit. Returns the blocked duration (seconds)."""
        notes, block_w = self.batcher.on_commit(now)
        self.unpublished.extend(notes)
        for note in self.unpublished:
            self.publish(note)
        self.unpublished.clear()
        block_r = max((d.on_commit(now) for d in self.debatchers),
                      default=0.0)
        self.uncommitted.clear()
        self.stats.commits += 1
        blocked = max(block_w, block_r)
        self.stats.commit_block_s += blocked
        return blocked

    # -- event-driven commit protocol (async engine path) -------------------
    # Notifications of in-flight uploads reach the coordinator only at the
    # upload's completion event; a commit therefore happens in two halves:
    # ``begin_commit`` flushes the buffers (enqueueing the tail uploads)
    # and SNAPSHOTS what this commit covers; ``try_finish_commit``
    # completes once the snapshot's uploads drain — publishing the
    # snapshot's notifications at once (read-committed visibility, which
    # preserves exactly-once under reordering and replay). Work arriving
    # after ``begin_commit`` belongs to the NEXT commit (chained
    # automatically), so commits finish in bounded time even while the
    # source keeps producing — Kafka Streams' commit covers records
    # processed up to the commit point, not future ones.
    def note_upload_started(self, blob_id: str) -> None:
        self.outstanding.add(blob_id)

    def note_upload_complete(self, blob_id: str,
                             notes: List[Notification],
                             publish_now: bool) -> None:
        """Record a durable upload. ``publish_now`` is the at-least-once
        mode: notifications fan out immediately (a crash after this point
        produces duplicates downstream); exactly-once defers them to the
        commit covering the upload."""
        self.outstanding.discard(blob_id)
        in_commit = blob_id in self._commit_wait
        self._commit_wait.discard(blob_id)
        if publish_now:
            for note in notes:
                self.publish(note)
        elif in_commit:
            self._commit_notes.extend(notes)
        else:
            self.unpublished.extend(notes)

    def note_upload_aborted(self, blob_id: str) -> None:
        """A PUT failed permanently: stop waiting for it (the loss shows
        up in the engine's ``uploads_aborted``, not as a hung commit)."""
        self.outstanding.discard(blob_id)
        self._commit_wait.discard(blob_id)

    def begin_commit(self, now: float) -> None:
        """First half of an async commit: flush buffers into the upload
        lane and snapshot the uploads/notifications/records this commit
        covers. If a commit is already in flight, remember to chain
        another one when it finishes."""
        self.batcher.flush_all(now)
        if self._commit_started is not None:
            self._commit_again = True
            return
        self._commit_started = now
        self._commit_wait = set(self.outstanding)
        self._commit_notes = list(self.unpublished)
        self.unpublished.clear()
        self._commit_n = len(self.uncommitted)

    def try_finish_commit(self, now: float) -> bool:
        """Second half: once every upload in the commit's snapshot is
        durable, publish its notifications and mark its offsets
        committed. Chains the next commit if more work accumulated."""
        if self._commit_started is None or self._commit_wait:
            return False
        for note in self._commit_notes:
            self.publish(note)
        self._commit_notes = []
        del self.uncommitted[:self._commit_n]
        self._commit_n = 0
        self.stats.commits += 1
        self.stats.commit_block_s += now - self._commit_started
        self._commit_started = None
        if self._commit_again or self.outstanding or self.unpublished:
            self._commit_again = False
            self.begin_commit(now)
            self.try_finish_commit(now)
        return True

    def fail_and_restart(self, now: float) -> List[Record]:
        """Crash before commit: uploads may be orphaned; notifications not
        yet published are lost; uncommitted source records replay."""
        self.stats.failures_injected += 1
        replay: List[Record] = []
        for item in self.uncommitted:
            if isinstance(item, RecordBatch):
                replay.extend(item.iter_records())
            else:
                replay.append(item)
        self.stats.records_replayed += len(replay)
        # lost: pending uploads (orphans stay in the store — harmless),
        # unpublished notifications, and all in-memory buffers.
        self.batcher.pending.clear()
        self.batcher.ready.clear()
        self.batcher.buffers.clear()
        self.batcher.buffer_bytes.clear()
        self.unpublished.clear()
        self.uncommitted.clear()
        self.outstanding.clear()
        self._commit_started = None
        self._commit_wait.clear()
        self._commit_notes.clear()
        self._commit_n = 0
        self._commit_again = False
        return replay
