"""Commit protocol integration (paper §3.1/§3.2).

Mirrors Kafka Streams' periodic commits: state may only be committed once
(a) all blobs derived from processed records are durably stored,
(b) their notifications are published, and
(c) the Debatcher has fully processed all fetched batches.

Failures before commit roll back to the last committed offset: the source
records are REPLAYED (at-least-once); the Debatcher's (blob, partition)
dedup restores exactly-once at the output. Orphaned blobs (uploaded but
never referenced) stay unreachable and are collected by retention.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Set

from repro.core.batcher import Batcher
from repro.core.blob import Notification
from repro.core.debatcher import Debatcher
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record


@dataclasses.dataclass
class CommitStats:
    commits: int = 0
    commit_block_s: float = 0.0
    failures_injected: int = 0
    records_replayed: int = 0


class CommitCoordinator:
    """Drives a Batcher through commit intervals with failure injection."""

    def __init__(self, batcher: Batcher, debatchers: List[Debatcher],
                 publish: Callable[[Notification], None]):
        self.batcher = batcher
        self.debatchers = debatchers
        self.publish = publish
        # source records (or whole RecordBatches) since the last commit
        self.uncommitted: List = []
        self.unpublished: List[Notification] = []
        self.stats = CommitStats()
        # async-engine state: blobs whose PUT is still in flight, and the
        # start time of a commit waiting for them to drain (None = idle)
        self.outstanding: Set[str] = set()
        self._commit_started: Optional[float] = None

    def process(self, rec: Record, now: float) -> None:
        self.uncommitted.append(rec)
        for note in self.batcher.process(rec, now):
            self.unpublished.append(note)

    def ingest(self, batch: RecordBatch, now: float) -> None:
        """Columnar bulk ingest: the whole batch is tracked as one
        uncommitted unit (flattened to records only on replay)."""
        self.uncommitted.append(batch)
        for note in self.batcher.ingest(batch, now):
            self.unpublished.append(note)

    def commit(self, now: float) -> float:
        """Blocking commit. Returns the blocked duration (seconds)."""
        notes, block_w = self.batcher.on_commit(now)
        self.unpublished.extend(notes)
        for note in self.unpublished:
            self.publish(note)
        self.unpublished.clear()
        block_r = max((d.on_commit(now) for d in self.debatchers),
                      default=0.0)
        self.uncommitted.clear()
        self.stats.commits += 1
        blocked = max(block_w, block_r)
        self.stats.commit_block_s += blocked
        return blocked

    # -- event-driven commit protocol (async engine path) -------------------
    # Notifications of in-flight uploads reach ``unpublished`` only at the
    # upload's completion event; a commit therefore happens in two halves:
    # ``begin_commit`` flushes the buffers (enqueueing the tail uploads)
    # and ``try_finish_commit`` completes once ``outstanding`` drains —
    # publishing everything at once, which is the read-committed visibility
    # that preserves exactly-once under reordering and replay.
    def note_upload_started(self, blob_id: str) -> None:
        self.outstanding.add(blob_id)

    def note_upload_complete(self, blob_id: str,
                             notes: List[Notification],
                             publish_now: bool) -> None:
        """Record a durable upload. ``publish_now`` is the at-least-once
        mode: notifications fan out immediately (a crash after this point
        produces duplicates downstream); exactly-once defers them to the
        next commit."""
        self.outstanding.discard(blob_id)
        if publish_now:
            for note in notes:
                self.publish(note)
        else:
            self.unpublished.extend(notes)

    def begin_commit(self, now: float) -> None:
        """First half of an async commit: flush buffers into the upload
        lane. If a commit is already waiting, the new one merges with it
        (its notifications ride along when ``outstanding`` drains)."""
        self.batcher.flush_all(now)
        if self._commit_started is None:
            self._commit_started = now

    def try_finish_commit(self, now: float) -> bool:
        """Second half: once every outstanding upload is durable, publish
        the batch of notifications and mark the offsets committed."""
        if self._commit_started is None or self.outstanding:
            return False
        for note in self.unpublished:
            self.publish(note)
        self.unpublished.clear()
        self.uncommitted.clear()
        self.stats.commits += 1
        self.stats.commit_block_s += now - self._commit_started
        self._commit_started = None
        return True

    def fail_and_restart(self, now: float) -> List[Record]:
        """Crash before commit: uploads may be orphaned; notifications not
        yet published are lost; uncommitted source records replay."""
        self.stats.failures_injected += 1
        replay: List[Record] = []
        for item in self.uncommitted:
            if isinstance(item, RecordBatch):
                replay.extend(item.iter_records())
            else:
                replay.append(item)
        self.stats.records_replayed += len(replay)
        # lost: pending uploads (orphans stay in the store — harmless),
        # unpublished notifications, and all in-memory buffers.
        self.batcher.pending.clear()
        self.batcher.ready.clear()
        self.batcher.buffers.clear()
        self.batcher.buffer_bytes.clear()
        self.unpublished.clear()
        self.uncommitted.clear()
        self.outstanding.clear()
        self._commit_started = None
        return replay
