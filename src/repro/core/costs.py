"""Cloud-cost model: BlobShuffle (S3 + EC2) vs native Kafka shuffling.

All prices are AWS us-east-1 list prices as used in the paper (§5.1.4,
§5.3). Anchors reproduced by `benchmarks/paper_fig6_batch_size.py`:
  * S3 cost @1 GiB/s, 1 h retention: 20.63 USD/h (1 MiB) → 0.29 (128 MiB)
  * native Kafka shuffle: 192 USD/h  (≈ (2/3 + 2)·$0.02/GB · 3600 GB/h)
  * 16 MiB total (S3 + EC2): 4.46 USD/h vs 192 → > 40×.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.analytical import ModelParams, get_rate, put_rate
from repro.core.stores.base import StoreCosts

GiB = 1024 ** 3


@dataclasses.dataclass(frozen=True)
class TierPrices:
    """Per-tier object-storage pricing for the tier sweep.

    ``standard`` matches the paper's S3 us-east-1 list prices; the
    premium tiers are illustrative but directionally correct: lower
    latency is bought with higher request and storage prices, and zonal
    tiers additionally bill cross-AZ routing per GB.
    """
    name: str
    put_per_1k: float
    get_per_1k: float
    storage_gb_month: float
    cross_az_per_gb: float = 0.0
    hours_per_month: float = 730.0

    def store_costs(self) -> StoreCosts:
        """The ``StoreCosts`` a ``BlobStore`` backend bills with."""
        return StoreCosts(put_per_req=self.put_per_1k / 1000.0,
                          get_per_req=self.get_per_1k / 1000.0,
                          storage_per_gb_month=self.storage_gb_month,
                          hours_per_month=self.hours_per_month,
                          cross_az_per_gb=self.cross_az_per_gb)


STANDARD = TierPrices("standard", put_per_1k=5.0e-3, get_per_1k=0.4e-3,
                      storage_gb_month=0.023)
EXPRESS_ONE_ZONE = TierPrices("express-one-zone", put_per_1k=1.0e-2,
                              get_per_1k=0.8e-3, storage_gb_month=0.16,
                              cross_az_per_gb=0.01)
PREMIUM = TierPrices("premium-low-latency", put_per_1k=2.5e-2,
                     get_per_1k=2.0e-3, storage_gb_month=0.30,
                     cross_az_per_gb=0.01)

TIERS: Dict[str, TierPrices] = {t.name: t
                                for t in (STANDARD, EXPRESS_ONE_ZONE,
                                          PREMIUM)}


def dollars_per_gib(cost_usd: float, nbytes: int) -> float:
    """Normalize a dollar figure by the bytes it moved (0 bytes -> 0)."""
    return cost_usd / (nbytes / GiB) if nbytes else 0.0


def shuffle_cost_per_logical_gib(prices: TierPrices, *,
                                 compressed_ratio: float = 1.0,
                                 batch_bytes: int = 16 * 1024 ** 2,
                                 gets_per_blob: float = 9.0,
                                 retention_s: float = 3600.0) -> float:
    """Dollars to shuffle one *logical* (pre-compression) GiB.

    The Batcher triggers on logical buffered bytes, so a wire format that
    compresses blocks at finalize leaves the blob/notification *counts*
    unchanged and shrinks only the shipped bytes: request charges are
    fixed, while storage and cross-AZ routing scale with
    ``compressed_ratio`` (shipped/logical). This is why compression is
    ~free on S3 Standard but pays directly on the per-GB-billed premium
    tiers — the same asymmetry the paper exploits in the other direction
    by batching requests.
    """
    n_blobs = GiB / batch_bytes
    shipped_gb = compressed_ratio * GiB / 1e9
    months = retention_s / 3600.0 / prices.hours_per_month
    return (n_blobs / 1000.0 * prices.put_per_1k
            + n_blobs * gets_per_blob / 1000.0 * prices.get_per_1k
            + shipped_gb * months * prices.storage_gb_month
            + shipped_gb * prices.cross_az_per_gb)


@dataclasses.dataclass(frozen=True)
class AwsPrices:
    s3_put_per_1k: float = 5.0e-3
    s3_get_per_1k: float = 0.4e-3
    s3_storage_gb_month: float = 0.023
    hours_per_month: float = 730.0
    cross_az_per_gb: float = 0.02        # $0.01 egress + $0.01 ingress
    ec2_r6in_xlarge_hour: float = 0.3741  # app nodes (2 instances/node)
    kafka_replication_factor: int = 3


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    s3_put: float
    s3_get: float
    s3_storage: float
    ec2: float

    @property
    def s3_total(self) -> float:
        return self.s3_put + self.s3_get + self.s3_storage

    @property
    def total(self) -> float:
        return self.s3_total + self.ec2


def blobshuffle_cost_per_hour(p: ModelParams, *, retention_s: float = 3600.0,
                              prices: AwsPrices = AwsPrices(),
                              nodes: int = 0,
                              actual_batch_frac: float = 1.0
                              ) -> CostBreakdown:
    """Hourly cost at the model's throughput.

    ``actual_batch_frac``: mean actual/target batch size (Fig. 6g: ~0.97
    up to 32 MiB, ~0.90 at 128 MiB) — commits finalize batches early,
    increasing the request rates by 1/frac.
    """
    scale = 1.0 / max(actual_batch_frac, 1e-6)
    puts_h = put_rate(p) * scale * 3600.0
    gets_h = get_rate(p) * scale * 3600.0
    stored_gb = p.rate * p.s_rec * retention_s / 1e9
    return CostBreakdown(
        s3_put=puts_h / 1000.0 * prices.s3_put_per_1k,
        s3_get=gets_h / 1000.0 * prices.s3_get_per_1k,
        s3_storage=stored_gb * prices.s3_storage_gb_month
        / prices.hours_per_month,
        ec2=nodes * prices.ec2_r6in_xlarge_hour,
    )


def kafka_shuffle_cost_per_hour(p: ModelParams,
                                prices: AwsPrices = AwsPrices()) -> float:
    """Native Kafka repartitioning cross-AZ cost (paper §5.3).

    Per shuffled GB: producer→leader crosses AZs with prob (N_az−1)/N_az;
    replication sends to (rf−1) followers in other AZs; consumers use
    AZ-aware follower fetching (0 cross-AZ). Each crossing is billed
    $0.01/GB on both sides.
    """
    crossings = (p.n_az - 1) / p.n_az + (prices.kafka_replication_factor - 1)
    gb_per_hour = p.rate * p.s_rec * 3600.0 / 1e9
    return crossings * prices.cross_az_per_gb * gb_per_hour


def actual_batch_frac(s_batch: float) -> float:
    """Fig. 6g interpolation: ≈97–98% of target ≤32 MiB, ~90% at 128 MiB."""
    mib = s_batch / (1024.0 ** 2)
    if mib <= 32:
        return 0.975
    if mib >= 128:
        return 0.90
    # log-linear between 32 and 128 MiB
    import math
    t = (math.log2(mib) - 5.0) / 2.0
    return 0.975 + (0.90 - 0.975) * t
