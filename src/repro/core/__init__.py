"""Paper-faithful BlobShuffle: records → Batcher → object store (+caches)
→ notifications → Debatcher, with the §4 analytical model, calibrated
capacity/latency models, and the §5 discrete-event simulator."""

from repro.core.records import (Record, serialize, deserialize,
                                deserialize_all, default_partitioner)
from repro.core.recordbatch import (RecordBatch, fnv1a_batch,
                                    default_partitioner_batch)
from repro.core.blob import (Blob, BlobIndex, ByteRange, Notification,
                             build_blob, build_blob_from_buffers,
                             extract, extract_batch)
from repro.core.formats import (WIRE_MAGIC, BlobFormat, BlobFormatError,
                                ColumnarV2, CorruptBlobError, RawV1,
                                UnknownFormatError, detect_format,
                                get_format, register_format,
                                registered_formats)
from repro.core.stores import (BlobStore, SimulatedS3, LatencyModel,
                               StoreCosts, StoreStats, StoreError,
                               SlowDownError, TransientStoreError,
                               StoreTimeoutError, ExpressOneZoneStore,
                               FaultyStore, FaultStats)
from repro.core.cache import (LRUCache, SingleFlight, DistributedCache,
                              LocalCache)
from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.debatcher import Debatcher
from repro.core.commit import CommitCoordinator
from repro.core.events import EventLoop
from repro.core.engine import (AsyncShuffleEngine, EngineConfig,
                               ShuffleMetrics)
from repro.core.strategy import (COMBINERS, STRATEGIES, CombiningStrategy,
                                 DefaultStrategy, LastWinsCombiner,
                                 PushStrategy, ShuffleStrategy,
                                 StrategyStats, SumU64Combiner,
                                 TwoRoundMergeStrategy, make_strategy)
from repro.core.workload import (WorkloadConfig, drive, generate,
                                 generate_batch)
from repro.core.pipeline import BlobShufflePipeline
from repro.core.analytical import ModelParams
from repro.core.capacity import CapacityModel
from repro.core.costs import (AwsPrices, TierPrices, TIERS,
                              blobshuffle_cost_per_hour, dollars_per_gib,
                              kafka_shuffle_cost_per_hour,
                              shuffle_cost_per_logical_gib)
from repro.core.simulator import (SimConfig, SimResult, simulate,
                                  simulate_async, simulate_elastic)
