"""ShuffleBench-style open-workload driver for the async engine.

Generates a timestamped record stream with a configurable arrival process
(Poisson or deterministic), key skew (Zipf over a bounded key universe,
exponent 0 = uniform), and record size — the knobs ShuffleBench (Henning
et al., 2024) identifies as dominating shuffle behavior. Feeding it to
``AsyncShuffleEngine.submit`` yields per-stage latency percentiles and
$/GiB under open-loop load, which is what the paper's Figs. 5–7 sweep.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from repro.core.recordbatch import RecordBatch
from repro.core.records import Record, serialized_size


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    arrival_rate: float = 10_000.0   # records/s offered across all sources
    duration_s: float = 5.0
    record_bytes: int = 1024         # serialized record size target
    key_skew: float = 0.0            # Zipf exponent; 0 = uniform keys
    num_keys: int = 10_000
    poisson: bool = True             # False: deterministic inter-arrivals
    seed: int = 0

    @property
    def n_records(self) -> int:
        return max(1, int(self.arrival_rate * self.duration_s))


def _key_probs(cfg: WorkloadConfig) -> np.ndarray:
    ranks = np.arange(1, cfg.num_keys + 1, dtype=np.float64)
    w = ranks ** -cfg.key_skew
    return w / w.sum()


def _arrivals_and_keys(cfg: WorkloadConfig) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_records
    if cfg.poisson:
        gaps = rng.exponential(1.0 / cfg.arrival_rate, size=n)
        arrivals = np.cumsum(gaps)
    else:
        arrivals = (np.arange(n) + 1.0) / cfg.arrival_rate
    if cfg.key_skew > 0:
        keys = rng.choice(cfg.num_keys, size=n, p=_key_probs(cfg))
    else:
        keys = rng.integers(0, cfg.num_keys, size=n)
    return arrivals, keys


def _value_size(cfg: WorkloadConfig) -> int:
    # value padded so the serialized record lands on record_bytes
    probe = Record(int(0).to_bytes(8, "little"), b"")
    return max(1, cfg.record_bytes - serialized_size(probe))


def generate(cfg: WorkloadConfig) -> List[Tuple[float, Record]]:
    """Materialize the stream as [(arrival_time_s, record), ...]."""
    arrivals, keys = _arrivals_and_keys(cfg)
    vsize = _value_size(cfg)
    out: List[Tuple[float, Record]] = []
    for t, k in zip(arrivals, keys):
        rec = Record(int(k).to_bytes(8, "little"),
                     bytes(vsize), timestamp_us=int(t * 1e6))
        out.append((float(t), rec))
    return out


def generate_batch(cfg: WorkloadConfig) -> Tuple[np.ndarray, RecordBatch]:
    """Columnar twin of ``generate``: the whole stream as one
    ``RecordBatch`` (records identical to ``generate``'s, bit for bit)
    plus the arrival-time array — built fully vectorized, no per-record
    Python objects."""
    arrivals, keys = _arrivals_and_keys(cfg)
    batch = RecordBatch.from_fixed(
        keys.astype(np.uint64), _value_size(cfg),
        (arrivals * 1e6).astype(np.uint64))
    return arrivals, batch


def drive(engine, cfg: WorkloadConfig,
          batch_records: Optional[int] = None) -> None:
    """Submit the whole workload to an ``AsyncShuffleEngine`` (round-robin
    over instances, like a load-balanced source topic).

    ``batch_records``: when set, records are handed over in columnar
    micro-batches of that many consecutive arrivals (zero-copy row
    slices), delivered at each micro-batch's last arrival time — the
    engine's vectorized ingest lane. Per-record arrival times still feed
    the end-to-end latency accounting."""
    if batch_records is None:
        for t, rec in generate(cfg):
            engine.submit(t, rec)
        return
    arrivals, batch = generate_batch(cfg)
    n = len(batch)
    for s in range(0, n, batch_records):
        e = min(s + batch_records, n)
        engine.submit_batch(float(arrivals[e - 1]),
                            batch.slice_rows(s, e),
                            times=arrivals[s:e])
