"""Object storage layer: interface + simulated S3 (latency, cost, retention).

The latency model is calibrated to the paper's Fig. 5 (16 MiB objects,
us-east-1): long-tailed lognormal with size-dependent medians, PUT ≈ 7–9×
slower than GET, p95 ≈ 2.2× median. The cost model uses AWS list prices.
The store is append-only and garbage-tolerant: orphaned blobs are removed
by retention, never by readers (paper §3.1/§3.2).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.blob import Blob, ByteRange

MiB = 1024 ** 2


@dataclasses.dataclass
class StoreCosts:
    """AWS S3 us-east-1 list prices (paper §5.1.4)."""
    put_per_req: float = 0.005 / 1000
    get_per_req: float = 0.0004 / 1000
    storage_per_gb_month: float = 0.023
    hours_per_month: float = 730.0

    def storage_cost_per_gb_hour(self) -> float:
        return self.storage_per_gb_month / self.hours_per_month


@dataclasses.dataclass
class LatencyModel:
    """T = lognormal(median = t0 + size/bw, sigma). Long-tail per Fig. 5."""
    put_t0_s: float = 0.200
    put_bw: float = 40 * MiB      # bytes/s transfer component of PUT
    get_t0_s: float = 0.030
    get_bw: float = 350 * MiB
    sigma: float = 0.42           # p95 ≈ 2.0× median, p99 ≈ 2.7× median

    def put_median(self, size: int) -> float:
        return self.put_t0_s + size / self.put_bw

    def get_median(self, size: int) -> float:
        return self.get_t0_s + size / self.get_bw

    def sample_put(self, size: int, rng: np.random.Generator) -> float:
        return float(self.put_median(size) *
                     np.exp(self.sigma * rng.standard_normal()))

    def sample_get(self, size: int, rng: np.random.Generator) -> float:
        return float(self.get_median(size) *
                     np.exp(self.sigma * rng.standard_normal()))


@dataclasses.dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    put_bytes: int = 0
    get_bytes: int = 0
    byte_seconds: float = 0.0     # integral of stored bytes over time

    def cost_usd(self, costs: StoreCosts, retention_s: float = 0.0,
                 explicit_storage: bool = False) -> float:
        """Request costs + storage (byte·s integral, or puts×retention)."""
        c = self.puts * costs.put_per_req + self.gets * costs.get_per_req
        if explicit_storage:
            gb_h = self.byte_seconds / 1e9 / 3600.0
        else:
            gb_h = self.put_bytes * retention_s / 1e9 / 3600.0
        return c + gb_h * costs.storage_per_gb_month / costs.hours_per_month


class SimulatedS3:
    """In-memory object store with simulated latency + cost accounting.

    Used both by the functional (unit-test) path — where operations are
    synchronous and latency is just *reported* — and by the discrete-event
    simulator, which schedules completions at ``now + sampled latency``.
    """

    def __init__(self, latency: Optional[LatencyModel] = None,
                 costs: Optional[StoreCosts] = None, seed: int = 0,
                 retention_s: float = 3600.0):
        self.latency = latency or LatencyModel()
        self.costs = costs or StoreCosts()
        self.rng = np.random.default_rng(seed)
        self.retention_s = retention_s
        self.objects: Dict[str, Tuple[bytes, float]] = {}  # id -> (data, t)
        self.stats = StoreStats()

    # -- synchronous API (functional path) --------------------------------
    def put(self, blob_id: str, data: bytes, now: float = 0.0) -> float:
        """Store object; returns sampled completion latency (seconds)."""
        self.objects[blob_id] = (data, now)
        self.stats.puts += 1
        self.stats.put_bytes += len(data)
        return self.latency.sample_put(len(data), self.rng)

    def get(self, blob_id: str, byte_range: Optional[ByteRange] = None,
            now: float = 0.0) -> Tuple[bytes, float]:
        """Fetch object (or ranged sub-object); returns (data, latency)."""
        if blob_id not in self.objects:
            raise KeyError(f"no such object {blob_id} (expired or orphan?)")
        data, _ = self.objects[blob_id]
        if byte_range is not None:
            data = data[byte_range.offset:byte_range.end]
        self.stats.gets += 1
        self.stats.get_bytes += len(data)
        return data, self.latency.sample_get(len(data), self.rng)

    # -- event-driven API (async engine path) ------------------------------
    # The engine splits each operation into begin (sample latency, account
    # the request) and finish (apply the state change at the completion
    # event), so many PUTs/GETs can be in flight on the virtual clock.
    def begin_put(self, size: int) -> float:
        """Start an async PUT of ``size`` bytes; returns sampled latency.
        The object becomes durable only at ``finish_put`` (the completion
        event) — readers racing the upload must not observe it earlier."""
        return self.latency.sample_put(size, self.rng)

    def finish_put(self, blob_id: str, data: bytes, now: float) -> None:
        """Apply a completed PUT: object is durable as of ``now``."""
        self.objects[blob_id] = (data, now)
        self.stats.puts += 1
        self.stats.put_bytes += len(data)

    def begin_get(self, blob_id: str) -> Tuple[int, float]:
        """Start an async GET; returns (object size, sampled latency).
        Request accounting happens at issue time, like the real S3 bill."""
        if blob_id not in self.objects:
            raise KeyError(f"no such object {blob_id} (expired or orphan?)")
        size = len(self.objects[blob_id][0])
        self.stats.gets += 1
        self.stats.get_bytes += size
        return size, self.latency.sample_get(size, self.rng)

    def payload(self, blob_id: str) -> bytes:
        """Raw object bytes (engine reads these at GET completion)."""
        return self.objects[blob_id][0]

    def run_retention(self, now: float) -> int:
        """Delete objects older than the retention period (paper §3.2)."""
        dead = [k for k, (_, t) in self.objects.items()
                if now - t > self.retention_s]
        for k in dead:
            data, t = self.objects.pop(k)
            self.stats.byte_seconds += len(data) * (now - t)
        return len(dead)

    def contains(self, blob_id: str) -> bool:
        return blob_id in self.objects
