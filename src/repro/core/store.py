"""Back-compat shim: the storage layer now lives in ``repro.core.stores``.

Kept so historical imports (``from repro.core.store import SimulatedS3``)
keep working; new code should import from ``repro.core.stores``. Importing
this module emits a ``DeprecationWarning`` (once, at first import).
"""

from __future__ import annotations

import warnings

from repro.core.stores import (BlobStore, LatencyModel, SimulatedS3,
                               SlowDownError, StoreCosts, StoreError,
                               StoreStats, StoreTimeoutError,
                               TransientStoreError)

__all__ = [
    "BlobStore", "LatencyModel", "SimulatedS3", "SlowDownError",
    "StoreCosts", "StoreError", "StoreStats", "StoreTimeoutError",
    "TransientStoreError",
]

warnings.warn(
    "repro.core.store is deprecated; import from repro.core.stores instead",
    DeprecationWarning, stacklevel=2)
