"""Record model + serialization (key, value, timestamp, headers).

Matches the paper's Batcher contract: records are buffered in serialized
form; a blob is the concatenation of per-partition byte buffers.
"""

from __future__ import annotations

import dataclasses
import functools
import struct
from typing import List, Tuple

_HDR = struct.Struct("<IIQH")  # key_len, value_len, timestamp_us, n_headers


@dataclasses.dataclass(frozen=True)
class Record:
    key: bytes
    value: bytes
    timestamp_us: int = 0
    headers: Tuple[Tuple[bytes, bytes], ...] = ()

    @functools.cached_property
    def size(self) -> int:
        # cached: records are frozen, and the hot path reads size per
        # buffered record (cached_property writes around the frozen guard)
        return serialized_size(self)


def serialized_size(rec: Record) -> int:
    n = _HDR.size + len(rec.key) + len(rec.value)
    for k, v in rec.headers:
        n += 8 + len(k) + len(v)
    return n


def serialize(rec: Record) -> bytes:
    out = [_HDR.pack(len(rec.key), len(rec.value), rec.timestamp_us,
                     len(rec.headers)), rec.key, rec.value]
    for k, v in rec.headers:
        out.append(struct.pack("<II", len(k), len(v)))
        out.append(k)
        out.append(v)
    return b"".join(out)


def deserialize(buf, offset: int = 0) -> Tuple[Record, int]:
    """Parse one record from any bytes-like object. Slicing goes through a
    ``memoryview`` so each field is copied exactly once — callers can pass
    a view over a blob payload without materializing the range first."""
    mv = buf if isinstance(buf, memoryview) else memoryview(buf)
    klen, vlen, ts, nh = _HDR.unpack_from(mv, offset)
    p = offset + _HDR.size
    key = bytes(mv[p:p + klen]); p += klen
    value = bytes(mv[p:p + vlen]); p += vlen
    headers = []
    for _ in range(nh):
        hk, hv = struct.unpack_from("<II", mv, p); p += 8
        headers.append((bytes(mv[p:p + hk]), bytes(mv[p + hk:p + hk + hv])))
        p += hk + hv
    return Record(key, value, ts, tuple(headers)), p


def deserialize_all(buf) -> List[Record]:
    mv = buf if isinstance(buf, memoryview) else memoryview(buf)
    out, p = [], 0
    end = len(mv)
    while p < end:
        rec, p = deserialize(mv, p)
        out.append(rec)
    return out


def default_partitioner(key: bytes, num_partitions: int) -> int:
    """Deterministic key -> partition (murmur-ish via FNV-1a, like Kafka's
    default semantics: stable across instances)."""
    h = 0xCBF29CE484222325
    for b in key:
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h % num_partitions
