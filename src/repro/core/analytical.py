"""Analytical cost & latency model (paper §4), verbatim equations.

Parameters: N_inst instances, N_az AZs, λ records/s (aggregate), s_rec
bytes/record, S_batch target bytes, T_put/T_get object-storage latencies.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelParams:
    n_inst: int
    n_az: int
    rate: float          # λ, records/s aggregate
    s_rec: float         # bytes
    s_batch: float       # bytes
    t_put: float = 0.6   # seconds
    t_get: float = 0.075


def rate_per_instance(p: ModelParams) -> float:
    """λ_inst = λ / N_inst [records/s]."""
    return p.rate / p.n_inst


def bytes_per_instance(p: ModelParams) -> float:
    """b_inst = λ·s_rec / N_inst [bytes/s]."""
    return p.rate * p.s_rec / p.n_inst


def t_batch(p: ModelParams) -> float:
    """T_batch = S_batch·N_az·N_inst / (λ·s_rec) [s]."""
    return p.s_batch * p.n_az * p.n_inst / (p.rate * p.s_rec)


def batches_per_second_per_instance(p: ModelParams) -> float:
    """μ_batch,inst = λ·s_rec / (S_batch·N_inst)."""
    return p.rate * p.s_rec / (p.s_batch * p.n_inst)


def batches_per_second(p: ModelParams) -> float:
    """μ_batch = λ·s_rec / S_batch."""
    return p.rate * p.s_rec / p.s_batch


def put_rate(p: ModelParams) -> float:
    """μ_put = μ_batch (one PUT per batch)."""
    return batches_per_second(p)


def get_rate(p: ModelParams) -> float:
    """μ_get = μ_batch · (N_az − 1)/N_az (same-AZ reads hit the cache)."""
    return batches_per_second(p) * (p.n_az - 1) / p.n_az


def get_put_ratio(p: ModelParams) -> float:
    """GET:PUT = (N_az−1)/N_az — ≈ 2:3 for N_az=3 (paper Fig. 6f)."""
    return (p.n_az - 1) / p.n_az


def shuffle_latency_max(p: ModelParams) -> float:
    """T_shuffle^max = T_batch + T_put + T_get (upper bound)."""
    return t_batch(p) + p.t_put + p.t_get


def shuffle_latency_mean(p: ModelParams) -> float:
    """Expected latency: uniform arrival within the fill window, GET only
    for the (N_az−1)/N_az cross-AZ fraction."""
    return (t_batch(p) / 2.0 + p.t_put
            + p.t_get * (p.n_az - 1) / p.n_az)
