from repro.training.optimizer import adamw_init, adamw_update, OptConfig
from repro.training.train_step import TrainConfig, make_train_step, make_loss_fn
