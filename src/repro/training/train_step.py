"""Train-step builder: loss, microbatch grad accumulation, remat policy,
grad-sync modes (auto GSPMD vs blob-hierarchical cross-pod).

grad_sync modes:
  * ``auto``      — XLA/GSPMD inserts all reductions (incl. cross-pod) —
                    the "native" baseline analogue.
  * ``blob``      — the whole step runs inside a shard_map that is *manual*
                    over the "pod" axis (auto over data/model); the cross-pod
                    gradient reduction is the blob-bucketed hierarchical
                    all-reduce from ``repro.shuffle.grad_sync``.
  * ``blob_int8`` — same, with int8 compression on the DCN leg.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat
from repro.models import lm
from repro.models.common import ModelConfig
from repro.shuffle.api import ShuffleConfig
from repro.shuffle import grad_sync as GS
from repro.training.optimizer import OptConfig, adamw_update

IGNORE = -100  # label value ignored by the loss (e.g. image-patch positions)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    remat: str = "full"              # none | dots | full
    shuffle: ShuffleConfig = ShuffleConfig(mode="dense")
    grad_sync: str = "auto"          # auto | blob | blob_int8
    grad_sync_blob_bytes: int = 16 * 1024 * 1024
    z_loss: float = 0.0


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean CE over labels != IGNORE. logits (B,S,V) any dtype; fp32 math."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    idx = jnp.clip(labels, 0, logits.shape[-1] - 1)
    picked = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
    ce = lse - picked
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    mask = (labels != IGNORE).astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def cast_compute_params(cfg: ModelConfig, params):
    """Mixed precision: cast master (param_dtype) weights to compute dtype
    at the top of the step, so FSDP all-gathers move bf16, not fp32.
    Leaves declared f32 in the defs (norm scales, A_log, dt_bias) stay f32.
    """
    defs = lm.param_defs(cfg)
    pd = jnp.dtype(cfg.param_dtype)
    cd = jnp.dtype(cfg.compute_dtype)
    if pd == cd or not jnp.issubdtype(pd, jnp.floating):
        return params

    from repro.models.common import is_spec

    def cast(spec, x):
        if jnp.dtype(spec.dtype) == pd:
            return x.astype(cd)
        return x
    return jax.tree.map(cast, defs, params, is_leaf=is_spec)


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                 hints=None) -> Callable:
    from repro.models.flash import NO_HINTS
    hints = hints or NO_HINTS

    def loss_fn(params, batch):
        params = cast_compute_params(cfg, params)
        logits, aux = lm.forward(cfg, params, batch, mesh=mesh,
                                 shuffle=tcfg.shuffle, remat=tcfg.remat,
                                 hints=hints)
        ce = cross_entropy(logits, batch["labels"], tcfg.z_loss)
        return ce + aux, {"loss": ce, "aux_loss": aux}
    return loss_fn


def _split_micro(batch: Dict[str, jax.Array], k: int):
    def r(x):
        b = x.shape[0]
        return x.reshape((k, b // k) + x.shape[1:])
    return {key: (r(v) if v.ndim >= 1 and v.shape[0] % k == 0 else v)
            for key, v in batch.items()}


def _grads(loss_fn, params, batch, microbatches: int):
    """(mean) gradients, with optional scan-based microbatch accumulation."""
    if microbatches <= 1:
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, metrics
    micro = _split_micro(batch, microbatches)

    def body(carry, mb):
        g_acc, m_acc = carry
        (_, metrics), g = jax.value_and_grad(
            loss_fn, has_aux=True)(params, mb)
        g_acc = jax.tree.map(jnp.add, g_acc, g)
        m_acc = jax.tree.map(jnp.add, m_acc, metrics)
        return (g_acc, m_acc), None

    g0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    m0 = {"loss": jnp.zeros((), jnp.float32),
          "aux_loss": jnp.zeros((), jnp.float32)}
    (grads, metrics), _ = jax.lax.scan(body, (g0, m0), micro)
    inv = 1.0 / microbatches
    return (jax.tree.map(lambda x: x * inv, grads),
            jax.tree.map(lambda x: x * inv, metrics))


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh=None,
                    hints=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_sync != auto and a multi-pod mesh, the step is wrapped in a
    shard_map manual over "pod": the loss is the pod-local mean and the
    cross-pod reduction is the explicit blob-hierarchical all-reduce.
    """
    loss_fn = make_loss_fn(cfg, tcfg, mesh=mesh, hints=hints)

    def plain_step(params, opt_state, batch):
        grads, metrics = _grads(loss_fn, params, batch, tcfg.microbatches)
        params, opt_state, om = adamw_update(tcfg.opt, grads, opt_state,
                                             params)
        metrics.update(om)
        return params, opt_state, metrics

    # partial-auto shard_map (manual over "pod", auto over data/model)
    # needs the current jax.shard_map; on 0.4.x the SPMD partitioner
    # check-fails on the manual-subgroup mix, so degrade to GSPMD auto
    # grad sync there rather than crash.
    use_blob = (tcfg.grad_sync in ("blob", "blob_int8") and mesh is not None
                and "pod" in mesh.axis_names and mesh.shape["pod"] > 1
                and jaxcompat.NEW_SHARD_MAP)
    if not use_blob:
        return plain_step

    compress = tcfg.grad_sync == "blob_int8"
    # inside the pod-manual region the EP domain is intra-pod (experts are
    # part of the pod-DP replica) and shard_maps use the context mesh
    tcfg_pod = dataclasses.replace(tcfg, shuffle=tcfg.shuffle.pod_local())
    pod_loss_fn = make_loss_fn(cfg, tcfg_pod, mesh=None, hints=hints)

    def pod_local_step(params, opt_state, batch):
        grads, metrics = _grads(pod_loss_fn, params, batch,
                                tcfg.microbatches)
        grads, _ = GS.blob_allreduce_grads(
            grads, pod_axis="pod", blob_bytes=tcfg.grad_sync_blob_bytes,
            compress=compress, average=True)
        metrics = jax.tree.map(
            lambda x: jax.lax.pmean(x, "pod"), metrics)
        params, opt_state, om = adamw_update(tcfg.opt, grads, opt_state,
                                             params)
        metrics.update(om)
        return params, opt_state, metrics

    # manual over "pod" only; data/model stay automatic (GSPMD).
    def spec_tree(tree, batch_dim0=False):
        return jax.tree.map(
            lambda _: P("pod") if batch_dim0 else P(), tree)

    def step(params, opt_state, batch):
        return jaxcompat.shard_map(
            pod_local_step, mesh=mesh,
            in_specs=(spec_tree(params), spec_tree(opt_state),
                      spec_tree(batch, batch_dim0=True)),
            out_specs=(spec_tree(params), spec_tree(opt_state),
                       jax.tree.map(lambda _: P(), {"loss": 0, "aux_loss": 0,
                                                    "grad_norm": 0,
                                                    "lr": 0})),
            check_vma=False,
            axis_names={"pod"},
        )(params, opt_state, batch)

    return step
