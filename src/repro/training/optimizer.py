"""AdamW with sharded states (m/v mirror the parameter shardings)."""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.learning_rate * warm * frac


def adamw_init(params: PyTree) -> dict:
    def zeros(p):
        return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptConfig, grads: PyTree, opt_state: dict,
                 params: PyTree) -> Tuple[PyTree, dict, dict]:
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = schedule(cfg, count)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics
