"""Lag- and throughput-driven autoscaling against the capacity model.

The policy (Shukla & Simmhan-style: elasticity decisions co-designed
with the migration mechanism they trigger) watches two signals each
tick:

  * **notification-log lag** — end offset minus committed offset, summed
    over partitions, normalized per alive worker. Sustained high lag
    (``breach_ticks`` consecutive ticks) means the consumers cannot keep
    up: scale OUT. Sustained near-zero lag with more workers than the
    capacity model says the observed throughput needs: scale IN.
  * **delivered throughput vs. the calibrated capacity curve** —
    ``CapacityModel.max_throughput`` gives the cluster's processing
    ceiling per worker count, so the target size is the smallest count
    whose ceiling clears the observed rate with ``headroom``; lag alone
    can overshoot (a transient spike) or undershoot (a slow leak).

Every decision is recorded with its $ consequence (workers ×
``worker_cost_per_hour``), so scenarios can report the cost delta
against a statically peak-provisioned cluster. Scale-out adds workers
through the cluster (join → cooperative rebalance); scale-in retires the
newest least-loaded worker gracefully (leave → handoff), draining surge
capacity in LIFO order.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.capacity import CapacityModel
from repro.core.costs import AwsPrices

MiB = 1024.0 ** 2


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    interval_s: float = 0.5
    high_lag_per_worker: float = 24.0    # log entries per alive worker
    low_lag_per_worker: float = 2.0
    # producer-side backpressure: blobs queued behind the upload lanes
    # (a load spike shows up here commits before it reaches the log)
    high_queue_per_worker: float = 3.0
    low_queue_per_worker: float = 0.5
    breach_ticks: int = 2                # sustained ticks before acting
    cooldown_s: float = 1.5              # min gap between scale actions
    min_workers: int = 2
    max_workers: int = 16
    headroom: float = 1.2                # capacity margin over observed rate
    idle_stop_ticks: int = 3             # quiesce ticks before stopping
    worker_cost_per_hour: float = AwsPrices().ec2_r6in_xlarge_hour


@dataclasses.dataclass
class ScaleDecision:
    t: float
    action: str                          # "scale_out" | "scale_in"
    reason: str
    lag: int
    workers_before: int
    workers_after: int
    cost_per_hour_delta: float


class Autoscaler:
    def __init__(self, cluster, policy: Optional[AutoscalePolicy] = None,
                 capacity: Optional[CapacityModel] = None):
        self.cluster = cluster
        self.policy = policy or AutoscalePolicy()
        self.capacity = capacity or CapacityModel()
        self.decisions: List[ScaleDecision] = []
        self._hi = 0
        self._lo = 0
        self._idle = 0
        self._last_action_t = float("-inf")
        self._last_bytes = 0
        self._last_lag = -1

    def start(self) -> None:
        self.cluster.loop.after(self.policy.interval_s, self._tick)

    def workers_for_throughput(self, bytes_s: float) -> int:
        """Smallest worker count whose capacity ceiling clears
        ``bytes_s × headroom`` (the cost-curve side of the decision)."""
        cfg = self.cluster.engine.cfg
        batch_mib = cfg.batch_bytes / MiB
        need = bytes_s * self.policy.headroom
        for n in range(self.policy.min_workers,
                       self.policy.max_workers + 1):
            if self.capacity.max_throughput(batch_mib, cfg.num_partitions,
                                            n, cfg.num_az) >= need:
                return n
        return self.policy.max_workers

    def _tick(self) -> None:
        cluster, pol = self.cluster, self.policy
        eng = cluster.engine
        now = cluster.loop.now
        alive = cluster.membership.alive()
        lag = cluster.undelivered_lag()
        delivered = eng.metrics.bytes_delivered
        rate = (delivered - self._last_bytes) / pol.interval_s
        self._last_bytes = delivered
        need = self.workers_for_throughput(rate)
        lag_pw = lag / max(len(alive), 1)
        queue_pw = sum(len(q) for q in eng._upload_q) / max(len(alive), 1)
        if (lag_pw >= pol.high_lag_per_worker
                or queue_pw >= pol.high_queue_per_worker):
            self._hi, self._lo = self._hi + 1, 0
        elif (lag_pw <= pol.low_lag_per_worker
              and queue_pw <= pol.low_queue_per_worker):
            self._hi, self._lo = 0, self._lo + 1
        else:
            self._hi = self._lo = 0
        cooled = now - self._last_action_t >= pol.cooldown_s
        if (self._hi >= pol.breach_ticks and cooled
                and len(alive) < pol.max_workers):
            target = min(pol.max_workers, max(len(alive) + 1, need))
            for _ in range(target - len(alive)):
                cluster.add_worker()
            self.decisions.append(ScaleDecision(
                now, "scale_out",
                f"lag/worker={lag_pw:.0f} queue/worker={queue_pw:.1f}",
                lag, len(alive), target,
                (target - len(alive)) * pol.worker_cost_per_hour))
            self._last_action_t = now
            self._hi = 0
        elif (self._lo >= pol.breach_ticks and cooled
              and len(alive) > max(pol.min_workers, need)):
            victim = min(
                alive,
                key=lambda w: (cluster.partitions_of(w.worker_id),
                               -w.joined_at, w.worker_id))
            cluster.remove_worker(victim.worker_id)
            self.decisions.append(ScaleDecision(
                now, "scale_in",
                f"lag/worker={lag_pw:.0f} queue/worker={queue_pw:.1f}",
                lag, len(alive), len(alive) - 1,
                -pol.worker_cost_per_hour))
            self._last_action_t = now
            self._lo = 0
        # keep ticking while the system is busy; stop after a few idle
        # ticks so the virtual-clock run can drain (undelivered lag, not
        # committed lag: committed offsets only advance on commits, which
        # stop with the producers). A lag that is positive but STUCK with
        # no engine work in flight is a permanent loss (e.g. an aborted
        # fetch of an expired blob), not business — ticking on it forever
        # would keep the loop alive and run() would never return.
        progressing = lag > 0 and lag != self._last_lag
        self._last_lag = lag
        busy = (eng._work_pending() or progressing
                or cluster.membership.pending_detections())
        self._idle = 0 if busy else self._idle + 1
        if busy or self._idle < pol.idle_stop_ticks:
            cluster.loop.after(pol.interval_s, self._tick)
