"""Sticky, AZ-aware partition assignment.

Kafka's sticky assignor plus rack awareness, adapted to the BlobShuffle
topology where every partition has a *home AZ* (``partition % num_az`` —
the AZ its blobs are batched toward and whose cache cluster holds the
write-through copies). Priorities, strictly in order:

  1. **balance** — no worker exceeds ``ceil(P / W)`` partitions;
  2. **stickiness** — a partition stays with its current owner when that
     owner is alive, AZ-compatible, and under the balance cap (minimal
     movement: a join moves at most the new worker's fair share, a crash
     moves only the dead worker's partitions);
  3. **AZ alignment** — otherwise the least-loaded alive worker in the
     partition's home AZ (same-AZ cache hits, no cross-AZ GET penalty);
  4. **cross-AZ fallback** — no alive worker in the home AZ (AZ outage):
     the least-loaded worker anywhere. Consuming cross-AZ costs latency
     and routing charges, but beats not consuming at all.

The output is deterministic for a given (partitions, workers, previous)
input — ties break on worker id — so virtual-clock runs reproduce.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Iterable, List, Optional

from repro.cluster.membership import UP, WorkerInfo


@dataclasses.dataclass(frozen=True)
class PartitionMeta:
    partition: int
    home_az: int


@dataclasses.dataclass
class AssignorStats:
    assignments: int = 0
    moved: int = 0           # partitions whose owner changed
    cross_az: int = 0        # partitions assigned outside their home AZ


class StickyAzAssignor:
    def __init__(self):
        self.stats = AssignorStats()

    def assign(self, parts: Iterable[PartitionMeta],
               workers: Iterable[WorkerInfo],
               previous: Optional[Dict[int, str]] = None) -> Dict[int, str]:
        """partition -> worker_id over the alive workers."""
        previous = previous or {}
        alive = sorted((w for w in workers if w.state == UP),
                       key=lambda w: w.worker_id)
        ordered = sorted(parts, key=lambda p: p.partition)
        if not alive:
            return {}
        by_id = {w.worker_id: w for w in alive}
        by_az: Dict[int, List[WorkerInfo]] = defaultdict(list)
        for w in alive:
            by_az[w.az].append(w)
        cap = -(-len(ordered) // len(alive))       # ceil(P / W)
        load = {w.worker_id: 0 for w in alive}
        out: Dict[int, str] = {}
        # pass 1 — sticky: keep the previous owner wherever allowed
        for p in ordered:
            prev = previous.get(p.partition)
            w = by_id.get(prev)
            if w is None or load[prev] >= cap:
                continue
            if w.az == p.home_az or not by_az.get(p.home_az):
                out[p.partition] = prev
                load[prev] += 1
        # pass 2 — place the rest: home AZ first, then anywhere
        for p in ordered:
            if p.partition in out:
                continue
            cands = by_az.get(p.home_az) or alive
            under = [w for w in cands if load[w.worker_id] < cap]
            pool = (under
                    or [w for w in alive if load[w.worker_id] < cap]
                    or alive)
            w = min(pool, key=lambda w: (load[w.worker_id], w.worker_id))
            out[p.partition] = w.worker_id
            load[w.worker_id] += 1
        self.stats.assignments += 1
        self.stats.moved += sum(1 for p, w in out.items()
                                if previous.get(p) not in (None, w))
        self.stats.cross_az += sum(
            1 for p in ordered if by_id[out[p.partition]].az != p.home_az)
        return out

    @staticmethod
    def moved(previous: Dict[int, str], new: Dict[int, str]) -> List[int]:
        """Partitions whose owner changes going from ``previous`` to
        ``new`` (newly-assigned partitions count as moved)."""
        return sorted(p for p, w in new.items() if previous.get(p) != w)
