"""ElasticCluster: the glue between the subsystem and the engine.

Owns the notification log, the offset store, membership, the rebalance
coordinator, and (optionally) the autoscaler, and plugs into
``AsyncShuffleEngine`` via three hooks:

  * ``engine._publish`` → ``publish``: a finalized notification becomes
    a durable log entry and is delivered (after the messaging delay,
    plus the cross-AZ extra when producer and owner AZs differ) to the
    partition's current OWNER — not to a fixed per-AZ debatcher;
  * ``engine._fetch_done`` → ``on_delivery``: the exactly-once gate —
    stale owners and replayed duplicates are dropped by log offset and
    (blob, partition), the paper's Debatcher dedup made partition-scoped
    state that migrates with ownership;
  * ``engine._commit_all`` → ``commit_offsets``: consumer offsets
    advance to each partition's contiguous delivered frontier on the
    engine's commit cadence — the token a new owner resumes from.

Cache alignment: after every completed rebalance the per-AZ
``DistributedCache`` clusters are resized to the alive worker count in
their AZ via consistent re-routing (``resize``) — ownership moves with
the assignment, entries are NOT flushed.
"""

from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Set

from repro.cluster.assignor import PartitionMeta, StickyAzAssignor
from repro.cluster.autoscaler import Autoscaler, AutoscalePolicy
from repro.cluster.membership import UP, Membership, WorkerInfo
from repro.cluster.notification_log import NotificationLog, OffsetStore
from repro.cluster.rebalance import RebalanceCoordinator, RebalanceEvent
from repro.core.blob import Notification
from repro.core.costs import AwsPrices


class _PartitionState:
    """Partition-scoped consumption state. It belongs to the PARTITION,
    not the worker — like a Kafka Streams state store, it survives its
    owner and migrates on reassignment, which is what lets the dedup
    hold across crash handoffs."""
    __slots__ = ("partition", "home_az", "owner", "delivered", "seen_blobs")

    def __init__(self, partition: int, home_az: int):
        self.partition = partition
        self.home_az = home_az
        self.owner: Optional[str] = None
        self.delivered: Set[int] = set()    # offsets >= committed
        self.seen_blobs: Set[str] = set()   # (blob, partition) dedup

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"_PartitionState(p={self.partition}, az={self.home_az}, "
                f"owner={self.owner})")


@dataclasses.dataclass
class ClusterStats:
    published: int = 0
    delivered: int = 0
    undeliverable: int = 0       # appended with no live owner (replay later)
    replayed_entries: int = 0    # scheduled again for a new owner
    handoff_duplicates_dropped: int = 0
    stale_drops: int = 0         # deliveries to (silently) dead workers
    cross_az_deliveries: int = 0  # owner consumed outside the home AZ
    offset_commits: int = 0
    cache_reroutes: int = 0      # cache entries moved (never flushed)
    worker_seconds: float = 0.0  # integral of alive workers over time


class ElasticCluster:
    GROUP = "debatch"

    def __init__(self, engine, *, mode: str = "cooperative",
                 assignor: Optional[StickyAzAssignor] = None,
                 heartbeat_timeout_s: float = 2.0,
                 autoscale: Optional[AutoscalePolicy] = None,
                 sync_barrier_s: float = 0.25,
                 migration_batch: int = 0,
                 migration_interval_s: float = 0.05):
        self.engine = engine
        self.loop = engine.loop
        self.log = NotificationLog()
        self.offsets = OffsetStore()
        self.stats = ClusterStats()
        self.membership = Membership(engine.loop, heartbeat_timeout_s,
                                     self._on_membership)
        self.rebalancer = RebalanceCoordinator(
            self, assignor or StickyAzAssignor(), mode,
            sync_barrier_s=sync_barrier_s, migration_batch=migration_batch,
            migration_interval_s=migration_interval_s)
        self.parts: Dict[int, _PartitionState] = {
            p: _PartitionState(p, engine.partition_to_az(p))
            for p in range(engine.cfg.num_partitions)}
        self._ws_t = self.loop.now
        engine.attach_cluster(self)
        # bootstrap: one worker per already-active engine instance, and a
        # single silent initial assignment (not a counted rebalance)
        self._bootstrapping = True
        for i in range(engine.n_instances):
            if engine.active[i]:
                self.membership.join(f"w{i}", engine._inst_az[i], i)
        self._bootstrapping = False
        initial = self.rebalancer.assignor.assign(
            self.partition_meta(), self.membership.alive(), {})
        for p, w in initial.items():
            self.parts[p].owner = w
        engine.on_assignment_changed()
        self._align_caches()
        self.autoscaler: Optional[Autoscaler] = None
        if autoscale is not None:
            self.autoscaler = Autoscaler(self, autoscale)
            self.autoscaler.start()

    # -- topology views ----------------------------------------------------
    def partition_meta(self) -> List[PartitionMeta]:
        return [PartitionMeta(st.partition, st.home_az)
                for st in self.parts.values()]

    def assignment(self) -> Dict[int, str]:
        return {p: st.owner for p, st in self.parts.items()
                if st.owner is not None}

    def partitions_of(self, worker_id: str) -> int:
        return sum(1 for st in self.parts.values()
                   if st.owner == worker_id)

    def total_lag(self) -> int:
        """Uncommitted notification-log entries (Kafka consumer lag)."""
        return sum(self.log.end_offset(p)
                   - self.offsets.committed(self.GROUP, p)
                   for p in self.parts)

    def undelivered_lag(self) -> int:
        """Entries not yet delivered downstream — the backpressure signal
        (committed lag additionally counts the delivered-but-uncommitted
        window, which only drains on the commit cadence)."""
        return sum(self.log.end_offset(p)
                   - self.offsets.committed(self.GROUP, p)
                   - len(st.delivered)
                   for p, st in self.parts.items())

    # -- worker operations -------------------------------------------------
    def add_worker(self, az: Optional[int] = None) -> str:
        """Scale-out: provision an engine instance + join the group
        (join triggers a rebalance in the configured mode)."""
        inst = self.engine.add_instance(az)
        wid = f"w{inst}"
        self.membership.join(wid, self.engine._inst_az[inst], inst)
        return wid

    def remove_worker(self, worker_id: str) -> None:
        """Graceful scale-in: drain the instance, then leave (the
        rebalance hands its partitions off from committed offsets)."""
        w = self.membership.workers[worker_id]
        self.engine.remove_instance(w.inst)
        self.membership.leave(worker_id)

    def crash_worker(self, worker_id: str) -> None:
        """Fail-stop now: the engine instance dies immediately (uploads
        and buffers lost, uncommitted records replay); the GROUP only
        reacts one heartbeat timeout later. No-op if the worker already
        left or crashed (e.g. the autoscaler retired it first)."""
        w = self.membership.workers[worker_id]
        if w.state != UP or w.silent_since is not None:
            return
        self.engine._fail(w.inst, permanent=True)
        self.membership.crash(worker_id)

    def crash_worker_at(self, t: float, worker_id: str) -> None:
        self.loop.at(t, self.crash_worker, worker_id)

    def az_outage(self, az: int) -> None:
        """Every worker in ``az`` fail-stops at once; their partitions
        fall back to cross-AZ owners at detection."""
        for w in list(self.membership.alive()):
            if w.az == az and w.silent_since is None:
                self.crash_worker(w.worker_id)

    def az_outage_at(self, t: float, az: int) -> None:
        self.loop.at(t, self.az_outage, az)

    def _on_membership(self, kind: str, w: WorkerInfo) -> None:
        self._accrue(self.loop.now)
        if self._bootstrapping:
            return
        obs = self.engine.obs
        if obs is not None:
            obs.mark(f"rebalance_trigger:{kind}", self.loop.now)
        self.rebalancer.trigger(kind, self.loop.now)

    # -- data plane --------------------------------------------------------
    def publish(self, note: Notification, src_az: Optional[int] = None
                ) -> int:
        """Engine hook: append to the log and deliver to the partition's
        owner; entries published while ownership is in flux (revoked,
        owner silently dead) wait in the log for the next resume."""
        off = self.log.append(note)
        self.stats.published += 1
        st = self.parts[note.partition]
        w = (self.membership.workers.get(st.owner)
             if st.owner is not None else None)
        if w is None or not self.membership.is_alive_now(w.worker_id):
            self.stats.undeliverable += 1
            return off
        self._schedule_delivery(st, off, note, w, src_az)
        return off

    def _schedule_delivery(self, st: _PartitionState, off: int,
                           note: Notification, w: WorkerInfo,
                           src_az: Optional[int]) -> None:
        e = self.engine.ecfg
        delay = e.notification_latency_s
        if src_az is not None and src_az != w.az:
            delay += e.cross_az_notification_extra_s
        if w.az != note.target_az:
            self.stats.cross_az_deliveries += 1
        self.loop.after(delay, self.engine.cluster_deliver, w.az, note,
                        off, w.worker_id)

    def on_delivery(self, note: Notification, offset: int,
                    worker_id: str) -> bool:
        """Engine hook, called at fetch completion — the exactly-once
        gate. False drops the delivery (the engine releases the lane)."""
        st = self.parts[note.partition]
        if not self.membership.is_alive_now(worker_id):
            self.stats.stale_drops += 1
            return False
        committed = self.offsets.committed(self.GROUP, note.partition)
        if (offset < committed or offset in st.delivered
                or note.blob_id in st.seen_blobs):
            self.stats.handoff_duplicates_dropped += 1
            return False
        st.delivered.add(offset)
        st.seen_blobs.add(note.blob_id)
        self.stats.delivered += 1
        return True

    def commit_offsets(self, now: float) -> int:
        """Advance every partition's committed offset to its contiguous
        delivered frontier (engine commit hook). Returns partitions
        whose committed offset moved."""
        return sum(self._commit_partition(p) for p in self.parts)

    def _commit_partition(self, p: int) -> bool:
        st = self.parts[p]
        c = self.offsets.committed(self.GROUP, p)
        while c in st.delivered:
            st.delivered.discard(c)
            c += 1
        if self.offsets.commit(self.GROUP, p, c):
            self.stats.offset_commits += 1
            return True
        return False

    # -- rebalance plumbing (called by RebalanceCoordinator) ---------------
    def revoke(self, partition: int) -> None:
        self.parts[partition].owner = None

    def assign_partition(self, partition: int, worker_id: str) -> int:
        """Hand one partition to ``worker_id``: commit its offsets (the
        handoff token), switch ownership, and replay the log from the
        committed offset. Returns the number of entries re-scheduled."""
        st = self.parts[partition]
        if st.owner == worker_id:
            return 0
        self._commit_partition(partition)
        st.owner = worker_id
        return self._resume(st)

    def _resume(self, st: _PartitionState) -> int:
        w = self.membership.workers.get(st.owner)
        if w is None or w.state != UP:
            return 0
        start = self.offsets.committed(self.GROUP, st.partition)
        n = 0
        for off, note in self.log.replay(st.partition, start):
            if off in st.delivered or note.blob_id in st.seen_blobs:
                continue    # already downstream: nothing to redo
            self._schedule_delivery(st, off, note, w, None)
            n += 1
        self.stats.replayed_entries += n
        return n

    def on_rebalance_complete(self, ev: RebalanceEvent) -> None:
        # the assignment snapshot moved: strategies routing blob
        # placement by owner AZ (push-based shuffle) re-snapshot, and
        # the batchers drop their cached partition→AZ tables
        obs = self.engine.obs
        if obs is not None:
            obs.mark("rebalance_complete", self.loop.now)
        self.engine.on_assignment_changed()
        self._align_caches()

    def _align_caches(self) -> None:
        """Re-route (never flush) each AZ's cache cluster to its alive
        worker count — cache ownership follows the assignment."""
        per_az = Counter(w.az for w in self.membership.alive())
        for az, cache in enumerate(self.engine.caches):
            self.stats.cache_reroutes += cache.resize(
                max(1, per_az.get(az, 0)))

    # -- accounting --------------------------------------------------------
    def _accrue(self, now: float) -> None:
        self.stats.worker_seconds += \
            len(self.membership.alive()) * (now - self._ws_t)
        self._ws_t = now

    def infra_cost_usd(self, cost_per_hour: Optional[float] = None
                       ) -> float:
        """Worker-time cost of the run so far (elastic $ vs static $)."""
        if cost_per_hour is None:
            cost_per_hour = AwsPrices().ec2_r6in_xlarge_hour
        return self.stats.worker_seconds / 3600.0 * cost_per_hour

    def finalize(self, now: float) -> None:
        """End-of-run bookkeeping (engine ``run()`` hook): close the
        worker-seconds integral and commit the final frontiers."""
        self._accrue(now)
        self.commit_offsets(now)
