"""Virtual-clock worker lifecycle: join / leave / crash / timeout.

Layered on the deterministic ``EventLoop``: a crash is *silent* — the
worker stops heartbeating at the crash instant, but the group only
learns of it ``heartbeat_timeout_s`` later (the detection event is
scheduled on the loop, so failover latency is part of the simulation,
exactly like a missed ``session.timeout.ms`` in a Kafka consumer group).
Graceful ``leave`` is announced and takes effect immediately. Periodic
heartbeat *events* are elided — on a virtual clock they would be no-ops
between state changes — but the ``heartbeat``/``last_heartbeat`` API is
kept so liveness can be probed and a flapping worker can cancel its own
pending detection by beating in time.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.core.events import EventLoop

UP = "up"
LEFT = "left"
CRASHED = "crashed"


@dataclasses.dataclass
class WorkerInfo:
    worker_id: str
    az: int
    inst: int                 # engine instance index backing this worker
    joined_at: float
    state: str = UP
    last_heartbeat: float = 0.0
    # crash instant, while the group has not yet detected it (ground
    # truth the simulator knows; the group's view is ``state``)
    silent_since: Optional[float] = None


class Membership:
    """Consumer-group membership view with timeout-based crash detection."""

    def __init__(self, loop: EventLoop, heartbeat_timeout_s: float = 2.0,
                 on_change: Optional[Callable[[str, WorkerInfo], None]]
                 = None):
        self.loop = loop
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.on_change = on_change
        self.workers: Dict[str, WorkerInfo] = {}
        self.generation = 0        # bumps on every membership change

    # -- lifecycle ---------------------------------------------------------
    def join(self, worker_id: str, az: int, inst: int) -> WorkerInfo:
        now = self.loop.now
        w = WorkerInfo(worker_id, az, inst, joined_at=now,
                       last_heartbeat=now)
        self.workers[worker_id] = w
        self._changed("join", w)
        return w

    def leave(self, worker_id: str) -> None:
        """Graceful departure: announced, takes effect immediately."""
        w = self.workers[worker_id]
        if w.state != UP:
            return
        w.state = LEFT
        self._changed("leave", w)

    def crash(self, worker_id: str) -> None:
        """Fail-stop NOW; the group detects it one heartbeat timeout
        later (the scheduled ``_detect`` event bumps the generation)."""
        w = self.workers[worker_id]
        if w.state != UP or w.silent_since is not None:
            return
        w.silent_since = self.loop.now
        self.loop.after(self.heartbeat_timeout_s, self._detect, worker_id)

    def _detect(self, worker_id: str) -> None:
        w = self.workers.get(worker_id)
        if w is None or w.state != UP or w.silent_since is None:
            return      # left meanwhile, or a heartbeat got through
        w.state = CRASHED
        self._changed("crash", w)

    def heartbeat(self, worker_id: str) -> None:
        w = self.workers[worker_id]
        if w.state == UP:
            w.last_heartbeat = self.loop.now
            w.silent_since = None    # cancels any pending detection

    # -- views -------------------------------------------------------------
    def alive(self) -> List[WorkerInfo]:
        """The GROUP's view: members it believes are up — including
        crashed-but-undetected workers (messages routed to them are lost
        until the timeout fires, which is the point)."""
        return sorted((w for w in self.workers.values() if w.state == UP),
                      key=lambda w: w.worker_id)

    def is_alive_now(self, worker_id: str) -> bool:
        """Ground truth: up AND actually running (not silently dead)."""
        w = self.workers.get(worker_id)
        return (w is not None and w.state == UP
                and w.silent_since is None)

    def pending_detections(self) -> bool:
        return any(w.state == UP and w.silent_since is not None
                   for w in self.workers.values())

    def _changed(self, kind: str, w: WorkerInfo) -> None:
        self.generation += 1
        if self.on_change is not None:
            self.on_change(kind, w)
