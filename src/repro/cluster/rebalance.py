"""Rebalance coordination: eager (stop-the-world) vs cooperative.

Two protocols over the same sticky assignment, mirroring Kafka's
``eager`` vs ``cooperative-sticky`` rebalance modes:

  * **eager** — every partition is revoked for a synchronization barrier
    (``sync_barrier_s``: the time for all members to rejoin the group);
    while revoked, nothing is consumed and newly published notifications
    pile up in the log. All partitions then resume from their committed
    offsets at once. Simple, and visibly expensive: the pause shows up
    directly in the p95-during-rebalance metric.

  * **cooperative** — only partitions whose owner actually changes hand
    off; unchanged partitions keep flowing throughout. The moved set can
    additionally migrate in Megaphone-style incremental *waves*
    (``migration_batch`` partitions every ``migration_interval_s``),
    bounding the instantaneous state-movement so latency stays flat.

Exactly-once handoff, in both modes: a partition's offsets are committed
at its handoff point, the new owner replays the notification log from
the committed offset, and the cluster's delivery-time dedup (by log
offset and (blob, partition)) drops anything the old owner had already
delivered — including completions of fetches that were still in flight
when ownership moved.

A new trigger supersedes in-flight migration waves: each trigger bumps a
round counter, and stale waves abandon themselves (the newest
assignment already covers every partition).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.cluster.assignor import StickyAzAssignor


@dataclasses.dataclass
class RebalanceEvent:
    reason: str              # "join" | "leave" | "crash" | manual
    mode: str                # "eager" | "cooperative"
    started_at: float
    ended_at: float
    moved: List[int]         # partitions whose owner changed
    replayed: int = 0        # log entries re-scheduled for the new owners
    generation: int = 0
    superseded: bool = False


class RebalanceCoordinator:
    def __init__(self, cluster, assignor: StickyAzAssignor,
                 mode: str = "cooperative", *,
                 sync_barrier_s: float = 0.25,
                 migration_batch: int = 0,
                 migration_interval_s: float = 0.05):
        if mode not in ("eager", "cooperative"):
            raise ValueError(f"unknown rebalance mode: {mode!r}")
        self.cluster = cluster
        self.assignor = assignor
        self.mode = mode
        self.sync_barrier_s = sync_barrier_s
        self.migration_batch = migration_batch
        self.migration_interval_s = migration_interval_s
        self.events: List[RebalanceEvent] = []
        self._round = 0

    @property
    def partitions_moved(self) -> int:
        return sum(len(e.moved) for e in self.events if not e.superseded)

    def trigger(self, reason: str, now: float) -> RebalanceEvent:
        cluster = self.cluster
        self._round += 1
        rnd = self._round
        new = self.assignor.assign(
            cluster.partition_meta(),
            list(cluster.membership.workers.values()),
            cluster.assignment())
        moved = sorted(p for p, w in new.items()
                       if cluster.parts[p].owner != w)
        ev = RebalanceEvent(reason, self.mode, now, now, moved,
                            generation=cluster.membership.generation)
        self.events.append(ev)
        loop = cluster.loop
        if self.mode == "eager":
            for st in cluster.parts.values():
                cluster.revoke(st.partition)
            loop.after(self.sync_barrier_s, self._eager_resume, new, ev,
                       rnd)
        else:
            if not moved:
                # nothing to migrate, but the membership still changed:
                # cache clusters must realign to the new worker set
                cluster.on_rebalance_complete(ev)
                return ev
            step = max(1, self.migration_batch) if self.migration_batch \
                else len(moved)
            waves = [moved[i:i + step] for i in range(0, len(moved), step)]
            for k, wave in enumerate(waves):
                loop.after(k * self.migration_interval_s, self._wave,
                           wave, new, ev, k == len(waves) - 1, rnd)
        return ev

    def _stale(self, ev: RebalanceEvent, rnd: int) -> bool:
        if rnd != self._round:
            ev.superseded = True
            return True
        return False

    def _eager_resume(self, new: Dict[int, str], ev: RebalanceEvent,
                      rnd: int) -> None:
        if self._stale(ev, rnd):
            return
        for p, w in sorted(new.items()):
            ev.replayed += self.cluster.assign_partition(p, w)
        ev.ended_at = self.cluster.loop.now
        self.cluster.on_rebalance_complete(ev)

    def _wave(self, wave: List[int], new: Dict[int, str],
              ev: RebalanceEvent, last: bool, rnd: int) -> None:
        if self._stale(ev, rnd):
            return
        for p in wave:
            ev.replayed += self.cluster.assign_partition(p, new[p])
        if last:
            ev.ended_at = self.cluster.loop.now
            self.cluster.on_rebalance_complete(ev)
