"""Durable, offset-addressed notification log + consumer-offset store.

The paper's "compact notifications" flow through a messaging layer the
engine previously modeled as fixed-delay point-to-point delivery. That is
not enough for elasticity: when partition ownership moves (scale-out,
crash, AZ outage), the new owner must be able to REPLAY every
notification the old owner had not durably consumed. This module makes
the messaging layer a per-partition, append-only, offset-addressed log —
the simulated twin of a Kafka notification topic — plus the
consumer-group offset store whose committed offsets are the exactly-once
handoff token: a new owner resumes from ``committed(group, partition)``
and the delivery-time dedup drops anything the old owner already got
downstream.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.blob import Notification


@dataclasses.dataclass
class LogStats:
    appends: int = 0
    bytes_appended: int = 0
    replayed: int = 0        # entries re-read during handoff/recovery


class NotificationLog:
    """Per-partition append-only log of notifications with dense offsets."""

    def __init__(self):
        self._parts: Dict[int, List[Notification]] = defaultdict(list)
        self.stats = LogStats()

    def append(self, note: Notification) -> int:
        """Append one notification to its partition's log; returns the
        entry's offset (dense, 0-based, per partition)."""
        log = self._parts[note.partition]
        log.append(note)
        self.stats.appends += 1
        self.stats.bytes_appended += note.size
        return len(log) - 1

    def end_offset(self, partition: int) -> int:
        return len(self._parts.get(partition, ()))

    def read(self, partition: int, start: int = 0,
             end: Optional[int] = None) -> List[Tuple[int, Notification]]:
        """Entries of ``partition`` in ``[start, end)`` as
        ``(offset, notification)`` pairs."""
        log = self._parts.get(partition, [])
        end = len(log) if end is None else min(end, len(log))
        return [(off, log[off]) for off in range(max(0, start), end)]

    def replay(self, partition: int, start: int
               ) -> List[Tuple[int, Notification]]:
        """``read`` that also counts the entries as replayed (handoff or
        crash recovery re-consumption)."""
        out = self.read(partition, start)
        self.stats.replayed += len(out)
        return out

    def partitions(self) -> List[int]:
        return sorted(self._parts)


class OffsetStore:
    """Committed consumer offsets per (group, partition).

    The durable handoff token: commits are monotonic (a stale coordinator
    can never move a group backwards), and a partition's new owner starts
    consuming from ``committed(group, partition)``.
    """

    def __init__(self):
        self._committed: Dict[Tuple[str, int], int] = {}
        self.commits = 0

    def commit(self, group: str, partition: int, offset: int) -> bool:
        """Advance the committed offset; returns True if it moved."""
        key = (group, partition)
        cur = self._committed.get(key, 0)
        if offset <= cur:
            return False
        self._committed[key] = offset
        self.commits += 1
        return True

    def committed(self, group: str, partition: int) -> int:
        return self._committed.get((group, partition), 0)
