"""Elastic cluster subsystem: durable notification log, virtual-clock
membership, sticky AZ-aware assignment, eager/cooperative rebalancing
with exactly-once handoff, and lag-driven autoscaling — the paper's
"Kafka Streams consistency and rebalance protocol preserved" claim made
executable on the async engine's virtual clock."""

from repro.cluster.assignor import (AssignorStats, PartitionMeta,
                                    StickyAzAssignor)
from repro.cluster.autoscaler import (Autoscaler, AutoscalePolicy,
                                      ScaleDecision)
from repro.cluster.manager import ClusterStats, ElasticCluster
from repro.cluster.membership import (CRASHED, LEFT, UP, Membership,
                                      WorkerInfo)
from repro.cluster.notification_log import (LogStats, NotificationLog,
                                            OffsetStore)
from repro.cluster.rebalance import RebalanceCoordinator, RebalanceEvent

__all__ = [
    "AssignorStats", "PartitionMeta", "StickyAzAssignor",
    "Autoscaler", "AutoscalePolicy", "ScaleDecision",
    "ClusterStats", "ElasticCluster",
    "CRASHED", "LEFT", "UP", "Membership", "WorkerInfo",
    "LogStats", "NotificationLog", "OffsetStore",
    "RebalanceCoordinator", "RebalanceEvent",
]
