from repro.distributed.sharding import (ShardingRules, partition_spec,
                                        named_shardings, DEFAULT_RULES)
