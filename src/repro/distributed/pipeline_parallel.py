"""GPipe-style pipeline parallelism over the "pod" axis (optional config).

Alternative use of the expensive inter-pod link: instead of a pod-DP
all-reduce domain, map pipeline STAGES onto pods — the DCN then carries
only microbatch boundary activations, point-to-point (collective_permute),
which is the cheapest possible inter-pod pattern (paper analogy: ship one
blob per hop instead of an all-to-all).

``gpipe_apply`` runs the classic fill/drain schedule inside a shard_map
that is manual over the stage axis:

    step t: stage s computes microbatch (t - s) if 0 <= t-s < n_micro,
            then passes its activation to stage s+1.

Equivalence to the sequential stack is tested on 8 host devices
(tests/test_pipeline_parallel.py).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jaxcompat


def gpipe_apply(stage_fn: Callable, params, x, *, mesh, n_micro: int,
                stage_axis: str = "pod"):
    """Run a pipelined stack of ``n_stages = mesh.shape[stage_axis]``.

    stage_fn(stage_params, x_mb) -> y_mb  (same shape as x_mb)
    params: pytree with a leading stage dim on every leaf.
    x: (batch, ...) global input; batch % n_micro == 0.

    Returns y with the same shape as x, equal to applying the stages
    sequentially (stage 0 first).
    """
    n_stages = mesh.shape[stage_axis]
    B = x.shape[0]
    assert B % n_micro == 0, "batch must divide into microbatches"
    mb = B // n_micro
    x_micro = x.reshape((n_micro, mb) + x.shape[1:])

    def local(params_s, xm):
        # params_s: this stage's params (leading stage dim stripped to 1)
        params_s = jax.tree.map(lambda a: a[0], params_s)
        x_micro = xm.reshape((n_micro, mb) + xm.shape[1:])
        sidx = jax.lax.axis_index(stage_axis)
        n_steps = n_micro + n_stages - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def step(carry, t):
            buf, out = carry  # buf: (mb, ...) activation entering this stage
            my_mb = t - sidx  # microbatch index this stage works on now
            active = (my_mb >= 0) & (my_mb < n_micro)
            # stage 0 ingests fresh microbatches; others use the received buf
            xin = jnp.where(sidx == 0,
                            x_micro[jnp.clip(t, 0, n_micro - 1)], buf)
            y = stage_fn(params_s, xin)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            out = jax.lax.cond(
                active & (sidx == n_stages - 1),
                lambda o: o.at[jnp.clip(my_mb, 0, n_micro - 1)].set(y),
                lambda o: o, out)
            # ship activations one hop downstream (wraps around harmlessly)
            buf_next = jax.lax.ppermute(y, stage_axis, fwd_perm)
            return (buf_next, out), None

        buf0 = jnp.zeros_like(x_micro[0])
        out0 = jnp.zeros_like(x_micro)
        (_, out), _ = jax.lax.scan(step, (buf0, out0),
                                   jnp.arange(n_steps))
        # result lives on the last stage; share it with every stage
        out = jax.lax.psum(
            jnp.where(sidx == n_stages - 1, out, jnp.zeros_like(out)),
            stage_axis)
        return out

    spec_p = jax.tree.map(lambda _: P(stage_axis), params)
    out = jaxcompat.shard_map(
        local, mesh=mesh,
        in_specs=(spec_p, P()),
        out_specs=P(),
        check_vma=False,
        axis_names={stage_axis},
    )(params, x)
    return out.reshape(x.shape)
