"""Logical-axis → mesh-axis sharding rules with divisibility fallback.

Every array in the framework carries *logical* axis names on its ArraySpec
(see ``repro.models.common``). A ``ShardingRules`` table maps those names to
mesh axes; ``partition_spec`` applies the table with two safety rails:

  * a mesh axis is used at most once per tensor (PartitionSpec constraint),
  * an axis is only applied if the dimension is divisible by the mesh-axis
    product so far (e.g. 8 kv-heads on a 16-way model axis ⇒ replicated).

This is what lets one config express qwen2-72b (FSDP+TP), gemma-2b (MQA),
deepseek (EP) and the decode cells (batch=1) without per-arch sharding code.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArraySpec, is_spec


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Map logical axis name -> tuple of mesh axes (in order of preference)."""
    rules: Dict[str, Tuple[str, ...]]

    def get(self, name) -> Tuple[str, ...]:
        if name is None:
            return ()
        r = self.rules.get(name, ())
        return (r,) if isinstance(r, str) else tuple(r)

    def override(self, **kw) -> "ShardingRules":
        new = dict(self.rules)
        for k, v in kw.items():
            new[k] = v
        return ShardingRules(new)


# Default parameter/activation rules for the (pod, data, model) mesh family.
#   - FSDP (ZeRO-3): weight d_model ("embed") dims sharded over "data";
#     XLA all-gathers per layer inside the scan and overlaps with compute.
#   - TP: heads / mlp hidden / vocab over "model".
#   - EP: experts over ("pod", "model") — the BlobShuffle domain.
#   - batch over ("pod", "data"); kv_seq over "model" is enabled per-cell in
#     the perf pass (flash-decode style sequence sharding).
DEFAULT_RULES = ShardingRules({
    "vocab": ("model",),
    "embed": ("data",),
    "kv_embed": ("data",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("pod", "model"),
    "expert_mlp": (),
    "layers": (),
    "stack": (),
    "batch": ("pod", "data"),
    "seq": (),
    "kv_seq": (),
})


def partition_spec(spec: ArraySpec, rules: ShardingRules, mesh: Mesh) -> P:
    used = set()
    parts = []
    axes = spec.axes or (None,) * len(spec.shape)
    for dim, name in zip(spec.shape, axes):
        chosen = []
        prod = 1
        for mesh_ax in rules.get(name):
            if mesh_ax in used or mesh_ax not in mesh.shape:
                continue
            size = mesh.shape[mesh_ax]
            if size > 1 and dim % (prod * size) == 0:
                chosen.append(mesh_ax)
                used.add(mesh_ax)
                prod *= size
        parts.append(tuple(chosen) if len(chosen) > 1
                     else (chosen[0] if chosen else None))
    return P(*parts)


def named_shardings(defs, rules: ShardingRules, mesh: Mesh):
    """ArraySpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, partition_spec(s, rules, mesh)),
        defs, is_leaf=is_spec)


def constrain(x, spec: ArraySpec, rules: ShardingRules, mesh: Mesh):
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, partition_spec(spec, rules, mesh)))


def batch_specs(shapes: Dict[str, ArraySpec], rules: ShardingRules,
                mesh: Mesh):
    return {k: NamedSharding(mesh, partition_spec(s, rules, mesh))
            for k, s in shapes.items()}
