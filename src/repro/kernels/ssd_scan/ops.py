"""Public op: full chunked SSD built on the per-chunk kernel + host scan."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ref import ssd_chunk_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas"))
def ssd_scan_op(x, dt, A, B, C, *, chunk: int = 256, use_pallas=None):
    """Chunked SSD: kernel for per-chunk terms + tiny inter-chunk scan.

    Same contract as repro.models.ssm.ssd_chunked (y, final_state).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    b, S, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    xq = x.reshape(b, nc, chunk, H, P)
    dtq = dt.reshape(b, nc, chunk, H)
    Bq = jnp.repeat(B.reshape(b, nc, chunk, G, N), rep, axis=3)
    Cq = jnp.repeat(C.reshape(b, nc, chunk, G, N), rep, axis=3)

    if use_pallas:
        y_intra, states, a_total, y_decay = ssd_chunk_pallas(
            xq, dtq, A.astype(jnp.float32), Bq, Cq,
            interpret=not _on_tpu())
    else:
        y_intra, states, a_total, y_decay = ssd_chunk_ref(
            xq, dtq, A.astype(jnp.float32), Bq, Cq)

    def chunk_step(state, inp):
        st_k, atot_k = inp
        prev = state
        return state * jnp.exp(atot_k)[..., None, None] + st_k, prev

    state0 = jnp.zeros((b, H, P, N), jnp.float32)
    final_state, prev = jax.lax.scan(
        chunk_step, state0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_total, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                     # (b, nc, H, P, N)
    y_inter = jnp.einsum("bcih,bcihn,bchpn->bcihp", y_decay,
                         Cq.astype(jnp.float32), prev)
    y = (y_intra + y_inter).reshape(b, Sp, H, P)[:, :S]
    return y.astype(x.dtype), final_state
