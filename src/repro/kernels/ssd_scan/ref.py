"""Oracle for the ssd_scan kernel: per-chunk SSD terms in pure jnp.

The kernel computes, per (batch, chunk, head):
  y_intra       — within-chunk quadratic contribution,
  chunk_state   — end-of-chunk state contribution (pre-recurrence),
  a_total       — per-head total decay of the chunk,
  y_decay       — exp(cum_a) factors so the host can add the inter-chunk
                  term  y_inter[i] = y_decay[i] · C[i] · S_prev.
The tiny inter-chunk recurrence runs outside (jnp scan over states).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(xq, dtq, A, Bq, Cq):
    """xq (b,nc,Q,H,P); dtq (b,nc,Q,H); A (H,); Bq/Cq (b,nc,Q,H,N).

    Returns (y_intra (b,nc,Q,H,P), states (b,nc,H,P,N),
             a_total (b,nc,H), y_decay (b,nc,Q,H)).
    """
    xq = xq.astype(jnp.float32)
    dtq = dtq.astype(jnp.float32)
    Bq = Bq.astype(jnp.float32)
    Cq = Cq.astype(jnp.float32)
    Q = xq.shape[2]
    a = dtq * A[None, None, None, :]
    cum_a = jnp.cumsum(a, axis=2)
    a_total = cum_a[:, :, -1]
    diff = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]
    ii = jnp.arange(Q)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", Cq, Bq) * decay \
        * dtq[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xq)
    w = jnp.exp(a_total[:, :, None, :] - cum_a) * dtq
    states = jnp.einsum("bcjh,bcjhp,bcjhn->bchpn", w, xq, Bq)
    return y_intra, states, a_total, jnp.exp(cum_a)
