"""Pallas TPU kernel for the SSD per-chunk computation (Mamba2).

Grid: (b, nc, H). Per instance the full Q×Q decay/score tile for one head
lives in VMEM (Q ≤ 256 → ≤ 256 KiB fp32) and the two contractions
(scores·x and the state outer product) hit the MXU. This is the tiling
that replaces the 8-tensor quadratic materialization of the jnp path
(observed 8.8 GB/layer on mamba2-130m train — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(A_ref, x_ref, dt_ref, B_ref, C_ref, y_ref, st_ref, at_ref,
            yd_ref):
    h = pl.program_id(2)
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)        # (Q,)
    Bm = B_ref[0, 0, :, 0, :].astype(jnp.float32)      # (Q, N)
    Cm = C_ref[0, 0, :, 0, :].astype(jnp.float32)      # (Q, N)
    A = A_ref[h]
    Q = x.shape[0]

    a = dt * A
    cum_a = jnp.cumsum(a)
    a_total = cum_a[-1]
    diff = cum_a[:, None] - cum_a[None, :]
    ii = jax.lax.iota(jnp.int32, Q)
    causal = ii[:, None] >= ii[None, :]
    decay = jnp.where(causal, jnp.exp(diff), 0.0)       # (Q, Q)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ()))) * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())))
    w = jnp.exp(a_total - cum_a) * dt                   # (Q,)
    state = jax.lax.dot_general(
        x * w[:, None], Bm, (((0,), (0,)), ((), ())))   # (P, N)

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = state.astype(st_ref.dtype)
    at_ref[0, 0, 0] = a_total
    yd_ref[0, 0, :, 0] = jnp.exp(cum_a)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(xq, dtq, A, Bq, Cq, *, interpret: bool = True):
    """Same contract as ssd_chunk_ref, with B/C pre-expanded to H heads."""
    b, nc, Q, H, P = xq.shape
    N = Bq.shape[-1]
    grid = (b, nc, H)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(A.shape, lambda i, c, h: (0,)),
            pl.BlockSpec((1, 1, Q, 1, P), lambda i, c, h: (i, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, c, h: (i, c, 0, h)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda i, c, h: (i, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda i, c, h: (i, c, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda i, c, h: (i, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda i, c, h: (i, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda i, c, h: (i, c, h)),
            pl.BlockSpec((1, 1, Q, 1), lambda i, c, h: (i, c, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, H), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, Q, H), jnp.float32),
        ],
        interpret=interpret,
    )(A, xq, dtq, Bq, Cq)
