"""Public op: flash attention — Pallas on TPU, custom-VJP jnp elsewhere."""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.models.flash import flash_attention as flash_jnp


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def flash_attention_op(q, k, v, *, causal: bool = True,
                       use_pallas: bool = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return flash_attention_pallas(q, k, v, causal=causal,
                                      interpret=not _on_tpu())
    return flash_jnp(q, k, v, causal=causal)
