"""Pallas TPU flash-attention forward kernel.

Grid: (B, H, nq). Per instance: the q block (Qt × D) lives in VMEM; the
kv stream for the matching GQA kv-head is scanned in KV_TILE chunks with
running (m, l, acc) — the MXU sees (Qt×D)·(D×KVt) and (Qt×KVt)·(KVt×D)
matmuls; tiles are multiples of 128 on the contracted dims for
hardware alignment. O(Qt·KVt) VMEM, never O(S²).

Oracle: repro.kernels.flash_attention.ref (dense attention); also matched
against the custom-VJP jnp flash in repro.models.flash by the tests.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _make_kernel(Sq: int, Skv: int, q_tile: int, kv_tile: int,
                 causal: bool, scale: float):
    nkv = -(-Skv // kv_tile)

    def kernel(q_ref, k_ref, v_ref, o_ref):
        t = pl.program_id(2)
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (Qt, D)
        qpos = t * q_tile + jax.lax.iota(jnp.int32, q_tile)

        def step(ki, carry):
            acc, m, l = carry
            k = k_ref[0, 0, pl.dslice(ki * kv_tile, kv_tile), :].astype(
                jnp.float32)                              # (KVt, D)
            v = v_ref[0, 0, pl.dslice(ki * kv_tile, kv_tile), :].astype(
                jnp.float32)
            s = jax.lax.dot_general(
                q, k, (((1,), (1,)), ((), ())))           # (Qt, KVt)
            kpos = ki * kv_tile + jax.lax.iota(jnp.int32, kv_tile)
            mask = kpos[None, :] < Skv
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[:, None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[:, None] + jax.lax.dot_general(
                p, v, (((1,), (0,)), ((), ())))
            return acc_new, m_new, l_new

        D = q_ref.shape[-1]
        acc0 = jnp.zeros((q_tile, D), jnp.float32)
        m0 = jnp.full((q_tile,), NEG_INF, jnp.float32)
        l0 = jnp.zeros((q_tile,), jnp.float32)
        acc, m, l = jax.lax.fori_loop(0, nkv, step, (acc0, m0, l0))
        o_ref[0, 0] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(
            o_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("causal", "q_tile", "kv_tile",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           q_tile: int = 128, kv_tile: int = 128,
                           interpret: bool = True):
    """q (B,Sq,H,D); k,v (B,Skv,KVH,D) with H % KVH == 0."""
    B, Sq, H, D = q.shape
    Skv, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = 1.0 / math.sqrt(D)
    q_tile = min(q_tile, Sq)
    kv_tile = min(kv_tile, Skv)
    # pad sequences to tile multiples (dynamic slices must stay in bounds;
    # the kernel masks kpos >= Skv so padded kv rows contribute nothing)
    qpad = (-Sq) % q_tile
    kpad = (-Skv) % kv_tile
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    # (B, H, S, D) layout for head-major blocking
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    grid = (B, H, Sq_p // q_tile)
    out = pl.pallas_call(
        _make_kernel(Sq_p, Skv, q_tile, kv_tile, causal, scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_tile, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, Skv_p, D), lambda b, h, t: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, Skv_p, D), lambda b, h, t: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_tile, D),
                               lambda b, h, t: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)[:, :Sq]
