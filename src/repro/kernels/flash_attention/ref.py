"""Oracle for the Pallas flash kernel: the dense reference attention."""

from repro.models.attention import dense_attention


def flash_ref(q, k, v, *, causal: bool = True):
    """q (B,Sq,H,D); k,v (B,Skv,KVH,D) -> (B,Sq,H,D)."""
    return dense_attention(q, k, v, causal=causal)
