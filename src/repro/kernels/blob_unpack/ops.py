"""Public op: blob_unpack — jitted wrapper (Pallas on TPU, oracle on CPU).

``unpack_from_keys`` is the fused Debatcher path matching
``blob_pack.blob_pack_fused``: slot/valid derivation (``bin_pack``'s
rank) and the tiled-vector-gather kernel run in one jitted pass.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.blob_unpack.kernel import (blob_unpack_fused_pallas,
                                              blob_unpack_pallas)
from repro.kernels.blob_unpack.ref import blob_unpack_ref
from repro.shuffle.binning import bin_pack


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def blob_unpack(buf, slot, valid, *, use_pallas: bool = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return blob_unpack_pallas(buf, slot, valid,
                                  interpret=not _on_tpu())
    return blob_unpack_ref(buf, slot, valid)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def blob_unpack_fused(buf, slot, valid, *, use_pallas: bool = None):
    """Fused tile kernel over a precomputed packing description."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return blob_unpack_fused_pallas(buf, slot, valid,
                                        interpret=not _on_tpu())
    return blob_unpack_ref(buf, slot, valid)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def unpack_from_keys(buf, keys, *, num_bins: int, capacity: int,
                     use_pallas: bool = None):
    """Fused Debatcher extract: derive slot/valid from destination keys
    (``bin_pack``'s rank half) and gather unit rows in the same jitted
    pass — (bins, capacity, d) + keys -> (U, d)."""
    pack = bin_pack(keys, num_bins, capacity)
    return blob_unpack_fused(buf, pack.slot, pack.valid,
                             use_pallas=use_pallas)
