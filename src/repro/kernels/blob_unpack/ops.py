"""Public op: blob_unpack — jitted wrapper (Pallas on TPU, oracle on CPU)."""

from __future__ import annotations

import functools

import jax

from repro.kernels.blob_unpack.kernel import blob_unpack_pallas
from repro.kernels.blob_unpack.ref import blob_unpack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def blob_unpack(buf, slot, valid, *, use_pallas: bool = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return blob_unpack_pallas(buf, slot, valid,
                                  interpret=not _on_tpu())
    return blob_unpack_ref(buf, slot, valid)
