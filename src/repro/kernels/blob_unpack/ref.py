"""Pure-jnp oracle for blob_unpack (Debatcher): bin layout -> unit rows."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def blob_unpack_ref(buf: jax.Array, slot: jax.Array, valid: jax.Array
                    ) -> jax.Array:
    """buf (bins, cap, d); slot (U,) flat slot ids; valid (U,) mask.

    Returns (U, d): unit u reads buf.reshape(-1, d)[slot[u]], zero if
    invalid (capacity-dropped units).
    """
    flat = buf.reshape(-1, buf.shape[-1])
    rows = flat[jnp.clip(slot, 0, flat.shape[0] - 1)]
    return jnp.where(valid[:, None], rows, 0).astype(buf.dtype)
