"""Pallas TPU kernels for blob_unpack (Debatcher extract).

``blob_unpack_pallas`` — reference kernel: grid (ceil(U / ROW_TILE),),
each instance gathers ROW_TILE unit rows one at a time via ``fori_loop``.

``blob_unpack_fused_pallas`` — fused tile kernel matching the fused pack:
the whole tile's slot indices load at once and all FUSED_ROW_TILE rows
come out of a single vectorized ``jnp.take`` gather, masked and stored
with no per-row loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
FUSED_ROW_TILE = 128


def _make_kernel(U: int, row_tile: int):
    def kernel(slot_ref, valid_ref, buf_ref, out_ref):
        t = pl.program_id(0)
        R = buf_ref.shape[0]

        def body(i, _):
            u = t * row_tile + i
            uc = jnp.minimum(u, U - 1)
            s = jnp.clip(slot_ref[uc], 0, R - 1)
            row = buf_ref[s, :]
            keep = (u < U) & valid_ref[uc]
            out_ref[i, :] = jnp.where(keep, row, jnp.zeros_like(row))
            return 0

        jax.lax.fori_loop(0, row_tile, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def blob_unpack_pallas(buf, slot, valid, *, interpret: bool = True):
    bins, cap, d = buf.shape
    U = slot.shape[0]
    flat = buf.reshape(bins * cap, d)
    row_tile = min(ROW_TILE, U)
    grid = (-(-U // row_tile),)
    return pl.pallas_call(
        _make_kernel(U, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(slot.shape, lambda t: (0,)),
            pl.BlockSpec(valid.shape, lambda t: (0,)),
            pl.BlockSpec(flat.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((U, d), buf.dtype),
        interpret=interpret,
    )(slot, valid, flat)


def _make_fused_kernel(U: int, row_tile: int):
    def kernel(slot_ref, valid_ref, buf_ref, out_ref):
        t = pl.program_id(0)
        flat = buf_ref[...]
        R = flat.shape[0]
        u = (t * row_tile + jax.lax.broadcasted_iota(
            jnp.int32, (row_tile, 1), 0)[:, 0])
        uc = jnp.minimum(u, U - 1)
        s = jnp.clip(jnp.take(slot_ref[...], uc, axis=0), 0, R - 1)
        rows = jnp.take(flat, s, axis=0)            # tiled vector gather
        keep = ((u < U) & jnp.take(valid_ref[...], uc, axis=0))[:, None]
        out_ref[:, :] = jnp.where(keep, rows, jnp.zeros_like(rows))
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def blob_unpack_fused_pallas(buf, slot, valid, *, interpret: bool = True):
    """Tiled-vector-gather unpack (bit-exact with ``blob_unpack_ref``)."""
    bins, cap, d = buf.shape
    U = slot.shape[0]
    flat = buf.reshape(bins * cap, d)
    row_tile = min(FUSED_ROW_TILE, U)
    grid = (-(-U // row_tile),)
    return pl.pallas_call(
        _make_fused_kernel(U, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(slot.shape, lambda t: (0,)),
            pl.BlockSpec(valid.shape, lambda t: (0,)),
            pl.BlockSpec(flat.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((U, d), buf.dtype),
        interpret=interpret,
    )(slot, valid, flat)
