"""Pallas TPU kernel for blob_unpack (Debatcher extract).

Grid: (ceil(U / ROW_TILE),): each instance gathers ROW_TILE unit rows from
the flattened blob buffer by dynamic slot index, zeroing dropped units.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8


def _make_kernel(U: int, row_tile: int):
    def kernel(slot_ref, valid_ref, buf_ref, out_ref):
        t = pl.program_id(0)
        R = buf_ref.shape[0]

        def body(i, _):
            u = t * row_tile + i
            uc = jnp.minimum(u, U - 1)
            s = jnp.clip(slot_ref[uc], 0, R - 1)
            row = buf_ref[s, :]
            keep = (u < U) & valid_ref[uc]
            out_ref[i, :] = jnp.where(keep, row, jnp.zeros_like(row))
            return 0

        jax.lax.fori_loop(0, row_tile, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def blob_unpack_pallas(buf, slot, valid, *, interpret: bool = True):
    bins, cap, d = buf.shape
    U = slot.shape[0]
    flat = buf.reshape(bins * cap, d)
    row_tile = min(ROW_TILE, U)
    grid = (-(-U // row_tile),)
    return pl.pallas_call(
        _make_kernel(U, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(slot.shape, lambda t: (0,)),
            pl.BlockSpec(valid.shape, lambda t: (0,)),
            pl.BlockSpec(flat.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((U, d), buf.dtype),
        interpret=interpret,
    )(slot, valid, flat)
