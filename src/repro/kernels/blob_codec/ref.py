"""Pure-jnp oracle for the fused blob compress+pack codec.

The wire-format PR makes compression part of the blob hot path (columnar
v2's int8 value codec); on TPU the analogue is fusing the quantizer into
the pack/unpack gathers so the blob layout is produced already-compressed
in one pass:

  compress_pack_ref    = blob_pack_ref  ∘ int8_quantize   (per blob row)
  unpack_decompress_ref = int8_dequantize ∘ blob_unpack_ref

Quantization is the symmetric per-row absmax/127 scheme from
``repro.shuffle.compression`` — the same semantics the host-side
``formats.codecs.quantize_value_arena`` applies per record. The scale is
written as ``absmax * (1/127)`` rather than ``absmax / 127``: XLA
rewrites a divide-by-constant to a reciprocal multiply in some lowering
contexts (observed inside interpret-mode Pallas bodies) but never the
reverse, so spelling the multiply explicitly is what makes ref and
kernel bit-exact. Padding rows are all-zero and quantize to
(q=0, scale=1.0).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.blob_pack.ref import blob_pack_ref
from repro.kernels.blob_unpack.ref import blob_unpack_ref
from repro.shuffle.compression import int8_dequantize

_INV_127 = 1.0 / 127.0


def quantize_rows(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization over the last axis, shared by
    the oracle and the fused kernel body (any leading shape)."""
    x32 = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.where(absmax > 0, absmax * jnp.float32(_INV_127), 1.0)
    q = jnp.clip(jnp.round(x32 / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compress_pack_ref(x: jax.Array, order: jax.Array, starts: jax.Array,
                      counts: jax.Array, *, capacity: int
                      ) -> Tuple[jax.Array, jax.Array]:
    """(T, d) tokens + sorted-order description -> compressed blob layout
    (q int8 (bins, capacity, d), scales float32 (bins, capacity))."""
    packed = blob_pack_ref(x, order, starts, counts, capacity=capacity)
    return quantize_rows(packed)


def unpack_decompress_ref(q: jax.Array, scales: jax.Array, slot: jax.Array,
                          valid: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Compressed blob layout + slot/valid description -> (U, d) unit rows
    in ``dtype`` (dequantized; capacity-dropped units are zero)."""
    x = int8_dequantize(q, scales, dtype)
    return blob_unpack_ref(x, slot, valid)
