from repro.kernels.blob_codec.ops import (compress_pack,
                                          compress_pack_fused,
                                          unpack_decompress,
                                          unpack_decompress_fused)

__all__ = ["compress_pack", "compress_pack_fused", "unpack_decompress",
           "unpack_decompress_fused"]
