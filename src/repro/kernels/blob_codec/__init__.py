from repro.kernels.blob_codec.host import compress_pack_fused_host
from repro.kernels.blob_codec.ops import (compress_pack,
                                          compress_pack_fused,
                                          unpack_decompress,
                                          unpack_decompress_fused)

__all__ = ["compress_pack", "compress_pack_fused",
           "compress_pack_fused_host", "unpack_decompress",
           "unpack_decompress_fused"]
