"""Host fast path for the fused compress+pack codec.

The compressed pack used to pay the padded gather in bf16/f32 *and then*
a quantize traversal over the padded (bins, capacity, d) layout. The
host path restructures it around one observation: the per-row int8
quantizer is independent of destination order, so it can run **before**
the pack — once over the T live rows instead of over bins × capacity
padded ones — and the gather then moves int8 codes (half/quarter the
bytes of the raw rows):

  1. quantize all T rows in one fused XLA pass (``quantize_rows`` — the
     *same function* the Pallas kernel and jnp oracle use, so outputs
     cannot drift);
  2. numpy sorted-order front half (shared with ``blob_pack.host``);
  3. per-bin contiguous block copies of int8 codes + f32 scales into the
     padded layout; padding rows are (q=0, scale=1.0), exactly what the
     oracle's quantize-of-zeros produces.

Bit-exact with ``compress_pack_ref`` (parity-tested). ``out=`` takes a
``(q, scales)`` arena pair for steady-state reuse, same rationale as
``blob_pack_fused_host``.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from repro.kernels.blob_codec.ref import quantize_rows
from repro.kernels.blob_pack.host import sorted_order_np

_quantize_jit = jax.jit(quantize_rows)


def compress_pack_fused_host(x, keys, *, num_bins: int, capacity: int,
                             out: Optional[Tuple[np.ndarray,
                                                 np.ndarray]] = None):
    """(T, d) host rows + destination keys -> ((q int8 (bins, capacity,
    d), scales f32 (bins, capacity)), sorted-order description)."""
    q_all, s_all = _quantize_jit(x)
    qn = np.asarray(q_all)
    sn = np.asarray(s_all)
    d = qn.shape[-1]
    order, starts, counts = sorted_order_np(keys, num_bins)
    reuse = (out is not None
             and out[0].shape == (num_bins, capacity, d)
             and out[0].dtype == np.int8
             and out[1].shape == (num_bins, capacity)
             and out[1].dtype == np.float32
             and out[0].flags.c_contiguous)
    if reuse:
        q_out, s_out = out
    else:
        q_out = np.zeros((num_bins, capacity, d), np.int8)
        s_out = np.ones((num_bins, capacity), np.float32)
    qs = qn[order]
    ss = sn[order]
    take = np.minimum(counts, capacity)
    for b in range(num_bins):
        s = starts[b]
        c = take[b]
        q_out[b, :c] = qs[s:s + c]
        s_out[b, :c] = ss[s:s + c]
        if reuse and c < capacity:
            q_out[b, c:] = 0
            s_out[b, c:] = 1.0
    return (q_out, s_out), (order, starts, counts)
