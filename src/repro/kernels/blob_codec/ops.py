"""Public ops: fused compress+pack / unpack+decompress blob codec.

Mirrors ``blob_pack.blob_pack_fused`` / ``blob_unpack.unpack_from_keys``:
the sort/rank front half (``repro.shuffle.binning``) and the fused Pallas
codec kernel run in one jitted pass, Pallas on TPU and the composed jnp
oracle elsewhere.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.blob_codec.kernel import (compress_pack_fused_pallas,
                                             unpack_decompress_fused_pallas)
from repro.kernels.blob_codec.ref import (compress_pack_ref,
                                          unpack_decompress_ref)
from repro.shuffle.binning import bin_pack, sorted_order


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def compress_pack(x, order, starts, counts, *, capacity: int,
                  use_pallas: bool = None):
    """(T, d) tokens + sorted-order description -> compressed blob layout
    (q int8 (bins, capacity, d), scales f32 (bins, capacity))."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return compress_pack_fused_pallas(x, order, starts, counts,
                                          capacity=capacity,
                                          interpret=not _on_tpu())
    return compress_pack_ref(x, order, starts, counts, capacity=capacity)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def compress_pack_fused(x, keys, *, num_bins: int, capacity: int,
                        use_pallas: bool = None):
    """Fused Batcher path: sort/rank front half + gather+quantize kernel
    in one jitted pass. (tokens, destination keys) -> ((q, scales),
    sorted-order description). Bit-exact with ``compress_pack_ref`` over
    ``sorted_order``."""
    order, starts, counts = sorted_order(keys, num_bins)
    out = compress_pack(x, order, starts, counts, capacity=capacity,
                        use_pallas=use_pallas)
    return out, (order, starts, counts)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def unpack_decompress(q, scales, slot, valid, *, use_pallas: bool = None):
    """Compressed blob layout + slot/valid -> (U, d) f32 unit rows."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return unpack_decompress_fused_pallas(q, scales, slot, valid,
                                              interpret=not _on_tpu())
    return unpack_decompress_ref(q, scales, slot, valid)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def unpack_decompress_fused(q, scales, keys, *, num_bins: int,
                            capacity: int, use_pallas: bool = None):
    """Fused Debatcher path: derive slot/valid from destination keys
    (``bin_pack``'s rank half) and gather+dequantize in the same jitted
    pass — compressed (bins, capacity, d) + keys -> (U, d) f32."""
    pack = bin_pack(keys, num_bins, capacity)
    return unpack_decompress(q, scales, pack.slot, pack.valid,
                             use_pallas=use_pallas)
