"""Pallas TPU kernels for the fused blob compress+pack codec.

``compress_pack_fused_pallas`` extends ``blob_pack_fused_pallas``'s tiled
vector gather with an in-register quantize: each program instance gathers
FUSED_ROW_TILE destination rows with one ``jnp.take``, masks them, then
computes the per-row absmax scale and int8 codes before anything is
stored — the uncompressed f32 blob layout never materializes in HBM. Two
outputs per tile: the int8 codes block and the f32 scales block.

``unpack_decompress_fused_pallas`` is the inverse on the Debatcher side:
one gather pulls the tile's int8 rows *and* their scales, and the
dequantized f32 rows are produced in the same pass.

Both are bit-exact (in interpret mode) with the composed oracles in
``ref.py``: the quantizer is the *same function* (``ref.quantize_rows``)
applied to the gathered tile, so kernel and oracle cannot drift.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.blob_codec.ref import quantize_rows

FUSED_ROW_TILE = 128


def _make_compress_pack_kernel(capacity: int, row_tile: int):
    def kernel(order_ref, starts_ref, counts_ref, x_ref, q_ref, scale_ref):
        b = pl.program_id(0)
        t = pl.program_id(1)
        start = starts_ref[b]
        count = jnp.minimum(counts_ref[b], capacity)
        order = order_ref[...]
        U = order.shape[0]
        r = (t * row_tile + jax.lax.broadcasted_iota(
            jnp.int32, (row_tile, 1), 0)[:, 0])
        pos = jnp.clip(start + r, 0, U - 1)
        toks = jnp.take(order, pos, axis=0)
        rows = jnp.take(x_ref[...], toks, axis=0)   # tiled vector gather
        keep = (r < count)[:, None]
        rows = jnp.where(keep, rows, jnp.zeros_like(rows))
        # in-register symmetric per-row int8 quantize; padding -> (0, 1.0)
        q, scale = quantize_rows(rows)
        q_ref[0, :, :] = q
        scale_ref[0, :] = scale
    return kernel


@functools.partial(jax.jit,
                   static_argnames=("capacity", "interpret", "row_tile"))
def compress_pack_fused_pallas(x, order, starts, counts, *, capacity: int,
                               interpret: bool = True,
                               row_tile: Optional[int] = None):
    """Single-pass gather+quantize pack (bit-exact with
    ``compress_pack_ref``): (T, d) tokens -> (q int8 (bins, capacity, d),
    scales f32 (bins, capacity)). ``row_tile`` overrides the tile depth
    (the device benchmark lane sweeps it)."""
    bins = starts.shape[0]
    d = x.shape[-1]
    row_tile = min(row_tile or FUSED_ROW_TILE, capacity)
    grid = (bins, -(-capacity // row_tile))
    return pl.pallas_call(
        _make_compress_pack_kernel(capacity, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(order.shape, lambda b, t: (0,)),      # full order
            pl.BlockSpec(starts.shape, lambda b, t: (0,)),
            pl.BlockSpec(counts.shape, lambda b, t: (0,)),
            pl.BlockSpec(x.shape, lambda b, t: (0, 0)),        # tokens
        ],
        out_specs=[
            pl.BlockSpec((1, row_tile, d), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, row_tile), lambda b, t: (b, t)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bins, capacity, d), jnp.int8),
            jax.ShapeDtypeStruct((bins, capacity), jnp.float32),
        ],
        interpret=interpret,
    )(order, starts, counts, x)


def _make_unpack_decompress_kernel(U: int, row_tile: int):
    def kernel(slot_ref, valid_ref, q_ref, scale_ref, out_ref):
        t = pl.program_id(0)
        flat_q = q_ref[...]
        R = flat_q.shape[0]
        u = (t * row_tile + jax.lax.broadcasted_iota(
            jnp.int32, (row_tile, 1), 0)[:, 0])
        uc = jnp.minimum(u, U - 1)
        s = jnp.clip(jnp.take(slot_ref[...], uc, axis=0), 0, R - 1)
        q = jnp.take(flat_q, s, axis=0)             # tiled vector gather
        scale = jnp.take(scale_ref[...], s, axis=0)
        rows = q.astype(jnp.float32) * scale[:, None]   # dequantize
        keep = ((u < U) & jnp.take(valid_ref[...], uc, axis=0))[:, None]
        out_ref[:, :] = jnp.where(keep, rows, jnp.zeros_like(rows))
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret", "row_tile"))
def unpack_decompress_fused_pallas(q, scales, slot, valid, *,
                                   interpret: bool = True,
                                   row_tile: Optional[int] = None):
    """Single-pass gather+dequantize unpack (bit-exact with
    ``unpack_decompress_ref``): compressed blob layout -> (U, d) f32."""
    bins, cap, d = q.shape
    U = slot.shape[0]
    flat_q = q.reshape(bins * cap, d)
    flat_s = scales.reshape(bins * cap)
    row_tile = min(row_tile or FUSED_ROW_TILE, U)
    grid = (-(-U // row_tile),)
    return pl.pallas_call(
        _make_unpack_decompress_kernel(U, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(slot.shape, lambda t: (0,)),
            pl.BlockSpec(valid.shape, lambda t: (0,)),
            pl.BlockSpec(flat_q.shape, lambda t: (0, 0)),
            pl.BlockSpec(flat_s.shape, lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((row_tile, d), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((U, d), jnp.float32),
        interpret=interpret,
    )(slot, valid, flat_q, flat_s)
