"""Pallas TPU kernels for blob_pack (Batcher gather into blob layout).

Two generations:

* ``blob_pack_pallas`` — the original reference kernel. Grid:
  (bins, ceil(capacity / ROW_TILE)); each program instance materializes
  ROW_TILE destination rows with a ``fori_loop`` that gathers **one row
  per iteration** (serialized row-at-a-time body).
* ``blob_pack_fused_pallas`` — the fused single-pass kernel. Same grid,
  but the body is one **tiled vector gather**: the whole tile's token
  indices are computed at once (iota → clip → order lookup) and all
  FUSED_ROW_TILE rows are gathered in a single vectorized ``jnp.take``,
  masked, and stored — no per-row loop. Combined with the jit-fused
  sort/rank front half in ``ops.blob_pack_fused`` this replaces the old
  two-pass (bin_pack rank/scatter, then gather) structure.

The feature dim is kept whole per row (d ≤ a few K → tile × d blocks sit
comfortably in VMEM and are lane-aligned for the VPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8
FUSED_ROW_TILE = 128


def _make_kernel(capacity: int, row_tile: int):
    def kernel(order_ref, starts_ref, counts_ref, x_ref, out_ref):
        b = pl.program_id(0)
        t = pl.program_id(1)
        start = starts_ref[b]
        count = jnp.minimum(counts_ref[b], capacity)
        U = order_ref.shape[0]

        def body(i, _):
            r = t * row_tile + i                    # row within the bin
            pos = jnp.clip(start + r, 0, U - 1)
            tok = order_ref[pos]
            row = x_ref[tok, :]
            row = jnp.where(r < count, row, jnp.zeros_like(row))
            out_ref[0, i, :] = row
            return 0

        jax.lax.fori_loop(0, row_tile, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def blob_pack_pallas(x, order, starts, counts, *, capacity: int,
                     interpret: bool = True):
    bins = starts.shape[0]
    d = x.shape[-1]
    row_tile = min(ROW_TILE, capacity)
    grid = (bins, -(-capacity // row_tile))
    return pl.pallas_call(
        _make_kernel(capacity, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(order.shape, lambda b, t: (0,)),      # full order
            pl.BlockSpec(starts.shape, lambda b, t: (0,)),
            pl.BlockSpec(counts.shape, lambda b, t: (0,)),
            pl.BlockSpec(x.shape, lambda b, t: (0, 0)),        # tokens
        ],
        out_specs=pl.BlockSpec((1, row_tile, d), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bins, capacity, d), x.dtype),
        interpret=interpret,
    )(order, starts, counts, x)


def _make_fused_kernel(capacity: int, row_tile: int):
    def kernel(order_ref, starts_ref, counts_ref, x_ref, out_ref):
        b = pl.program_id(0)
        t = pl.program_id(1)
        start = starts_ref[b]
        count = jnp.minimum(counts_ref[b], capacity)
        order = order_ref[...]
        U = order.shape[0]
        # whole tile of destination rows at once (no fori_loop):
        r = (t * row_tile + jax.lax.broadcasted_iota(
            jnp.int32, (row_tile, 1), 0)[:, 0])
        pos = jnp.clip(start + r, 0, U - 1)
        toks = jnp.take(order, pos, axis=0)
        rows = jnp.take(x_ref[...], toks, axis=0)   # tiled vector gather
        keep = (r < count)[:, None]
        out_ref[0, :, :] = jnp.where(keep, rows, jnp.zeros_like(rows))
    return kernel


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def blob_pack_fused_pallas(x, order, starts, counts, *, capacity: int,
                           interpret: bool = True):
    """Single-pass tiled-vector-gather pack (same contract and bit-exact
    output as ``blob_pack_pallas`` / ``blob_pack_ref``)."""
    bins = starts.shape[0]
    d = x.shape[-1]
    row_tile = min(FUSED_ROW_TILE, capacity)
    grid = (bins, -(-capacity // row_tile))
    return pl.pallas_call(
        _make_fused_kernel(capacity, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(order.shape, lambda b, t: (0,)),      # full order
            pl.BlockSpec(starts.shape, lambda b, t: (0,)),
            pl.BlockSpec(counts.shape, lambda b, t: (0,)),
            pl.BlockSpec(x.shape, lambda b, t: (0, 0)),        # tokens
        ],
        out_specs=pl.BlockSpec((1, row_tile, d), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bins, capacity, d), x.dtype),
        interpret=interpret,
    )(order, starts, counts, x)
