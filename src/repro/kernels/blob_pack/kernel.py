"""Pallas TPU kernel for blob_pack (Batcher gather into blob layout).

Grid: (bins, ceil(capacity / ROW_TILE)). Each program instance materializes
ROW_TILE destination rows of one bin in VMEM by dynamically gathering
token rows from the token array, masking rows past the bin's demand. The
feature dim is kept whole per row (d ≤ a few K → ROW_TILE × d tiles sit
comfortably in VMEM and are lane-aligned for the VPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 8


def _make_kernel(capacity: int, row_tile: int):
    def kernel(order_ref, starts_ref, counts_ref, x_ref, out_ref):
        b = pl.program_id(0)
        t = pl.program_id(1)
        start = starts_ref[b]
        count = jnp.minimum(counts_ref[b], capacity)
        U = order_ref.shape[0]

        def body(i, _):
            r = t * row_tile + i                    # row within the bin
            pos = jnp.clip(start + r, 0, U - 1)
            tok = order_ref[pos]
            row = x_ref[tok, :]
            row = jnp.where(r < count, row, jnp.zeros_like(row))
            out_ref[0, i, :] = row
            return 0

        jax.lax.fori_loop(0, row_tile, body, 0)
    return kernel


@functools.partial(jax.jit, static_argnames=("capacity", "interpret"))
def blob_pack_pallas(x, order, starts, counts, *, capacity: int,
                     interpret: bool = True):
    bins = starts.shape[0]
    d = x.shape[-1]
    row_tile = min(ROW_TILE, capacity)
    grid = (bins, -(-capacity // row_tile))
    return pl.pallas_call(
        _make_kernel(capacity, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(order.shape, lambda b, t: (0,)),      # full order
            pl.BlockSpec(starts.shape, lambda b, t: (0,)),
            pl.BlockSpec(counts.shape, lambda b, t: (0,)),
            pl.BlockSpec(x.shape, lambda b, t: (0, 0)),        # tokens
        ],
        out_specs=pl.BlockSpec((1, row_tile, d), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bins, capacity, d), x.dtype),
        interpret=interpret,
    )(order, starts, counts, x)
