"""Pallas TPU kernels for blob_pack (Batcher gather into blob layout).

Both kernels now share one whole-tile body: each program instance
computes the tile's destination rows at once (iota → clip → order
lookup) and gathers all ``row_tile`` rows with a single vectorized
``jnp.take`` — the original per-row ``fori_loop`` body (which serialized
one gather per destination row) is gone.

Tile geometry is retuned for the VPU: ``ROW_TILE`` was 8 — far below
the (sublane × lane) shapes the vector unit wants — and is now 128, so
a tile is a (128, d) block: lane-aligned along the whole feature dim and
deep enough in the sublane dim to amortize the gather's index math. Both
wrappers take a ``row_tile`` override so the device-mode benchmark lane
(``benchmarks/micro.py``) can sweep row-tile configurations the way
MaxText tunes its combine thresholds, without editing kernel source.

The feature dim is kept whole per row (d ≤ a few K → tile × d blocks sit
comfortably in VMEM and are lane-aligned for the VPU).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 128
FUSED_ROW_TILE = 128

#: row-tile candidates the device benchmark lane sweeps (clamped to
#: capacity at call time); 8 is kept as the degenerate legacy point so
#: the sweep shows what the retune bought
SWEEP_ROW_TILES = (8, 32, 64, 128, 256)


def _make_tile_kernel(capacity: int, row_tile: int):
    """Whole-tile gather body shared by the plain and fused pack kernels:
    one vectorized ``jnp.take`` per (bin, tile) program instance."""
    def kernel(order_ref, starts_ref, counts_ref, x_ref, out_ref):
        b = pl.program_id(0)
        t = pl.program_id(1)
        start = starts_ref[b]
        count = jnp.minimum(counts_ref[b], capacity)
        order = order_ref[...]
        U = order.shape[0]
        # whole tile of destination rows at once (no fori_loop):
        r = (t * row_tile + jax.lax.broadcasted_iota(
            jnp.int32, (row_tile, 1), 0)[:, 0])
        pos = jnp.clip(start + r, 0, U - 1)
        toks = jnp.take(order, pos, axis=0)
        rows = jnp.take(x_ref[...], toks, axis=0)   # tiled vector gather
        keep = (r < count)[:, None]
        out_ref[0, :, :] = jnp.where(keep, rows, jnp.zeros_like(rows))
    return kernel


def _pack_call(x, order, starts, counts, *, capacity: int, row_tile: int,
               interpret: bool):
    bins = starts.shape[0]
    d = x.shape[-1]
    row_tile = min(row_tile, capacity)
    grid = (bins, -(-capacity // row_tile))
    return pl.pallas_call(
        _make_tile_kernel(capacity, row_tile),
        grid=grid,
        in_specs=[
            pl.BlockSpec(order.shape, lambda b, t: (0,)),      # full order
            pl.BlockSpec(starts.shape, lambda b, t: (0,)),
            pl.BlockSpec(counts.shape, lambda b, t: (0,)),
            pl.BlockSpec(x.shape, lambda b, t: (0, 0)),        # tokens
        ],
        out_specs=pl.BlockSpec((1, row_tile, d), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((bins, capacity, d), x.dtype),
        interpret=interpret,
    )(order, starts, counts, x)


@functools.partial(jax.jit,
                   static_argnames=("capacity", "interpret", "row_tile"))
def blob_pack_pallas(x, order, starts, counts, *, capacity: int,
                     interpret: bool = True,
                     row_tile: Optional[int] = None):
    """Two-pass-compatible pack kernel (same contract as ``blob_pack_ref``),
    now running the whole-tile gather body — the ``fori_loop`` generation
    is retired."""
    return _pack_call(x, order, starts, counts, capacity=capacity,
                      row_tile=row_tile or ROW_TILE, interpret=interpret)


@functools.partial(jax.jit,
                   static_argnames=("capacity", "interpret", "row_tile"))
def blob_pack_fused_pallas(x, order, starts, counts, *, capacity: int,
                           interpret: bool = True,
                           row_tile: Optional[int] = None):
    """Single-pass tiled-vector-gather pack (same contract and bit-exact
    output as ``blob_pack_pallas`` / ``blob_pack_ref``)."""
    return _pack_call(x, order, starts, counts, capacity=capacity,
                      row_tile=row_tile or FUSED_ROW_TILE,
                      interpret=interpret)
