"""Public op: blob_pack — jitted wrapper choosing Pallas (TPU) vs oracle.

Also provides ``pack_from_keys`` which computes the sorted-order inputs
(argsort by destination) the way the shuffle layer does, and
``blob_pack_fused`` — the single-pass path that fuses the sort/rank front
half of ``bin_pack`` with the tiled-vector-gather kernel, replacing the
two-pass rank/scatter + row-loop gather structure.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.blob_pack.host import (blob_pack_fused_host,
                                          sorted_order_np)
from repro.kernels.blob_pack.kernel import (blob_pack_fused_pallas,
                                            blob_pack_pallas)
from repro.kernels.blob_pack.ref import blob_pack_ref
from repro.shuffle.binning import sorted_order

__all__ = ["blob_pack", "pack_from_keys", "blob_pack_fused",
           "blob_pack_fused_host", "sorted_order_np"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def blob_pack(x, order, starts, counts, *, capacity: int,
              use_pallas: bool = None):
    """(T,d) tokens + sorted-order description -> (bins, capacity, d)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return blob_pack_pallas(x, order, starts, counts,
                                capacity=capacity,
                                interpret=not _on_tpu())
    return blob_pack_ref(x, order, starts, counts, capacity=capacity)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def pack_from_keys(x, keys, *, num_bins: int, capacity: int,
                   use_pallas: bool = None):
    """Convenience: bin tokens by destination key and pack into blobs."""
    order, starts, counts = sorted_order(keys, num_bins)
    return blob_pack(x, order, starts, counts, capacity=capacity,
                     use_pallas=use_pallas), (order, starts, counts)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def blob_pack_fused(x, keys, *, num_bins: int, capacity: int,
                    use_pallas: bool = None):
    """Fused single-pass pack: ``bin_pack``'s sort/rank and the gather run
    in one jitted pass, and the Pallas kernel gathers whole tiles with
    vectorized ``jnp.take`` instead of a row-at-a-time ``fori_loop``.

    (tokens, destination keys) -> ((bins, capacity, d), sorted-order
    description). Bit-exact with ``pack_from_keys``."""
    order, starts, counts = sorted_order(keys, num_bins)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        out = blob_pack_fused_pallas(x, order, starts, counts,
                                     capacity=capacity,
                                     interpret=not _on_tpu())
    else:
        out = blob_pack_ref(x, order, starts, counts, capacity=capacity)
    return out, (order, starts, counts)
