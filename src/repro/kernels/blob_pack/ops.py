"""Public op: blob_pack — jitted wrapper choosing Pallas (TPU) vs oracle.

Also provides ``pack_from_keys`` which computes the sorted-order inputs
(argsort by destination) the way the shuffle layer does, so callers can go
straight from (tokens, destination keys) to the blob layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.blob_pack.kernel import blob_pack_pallas
from repro.kernels.blob_pack.ref import blob_pack_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def blob_pack(x, order, starts, counts, *, capacity: int,
              use_pallas: bool = None):
    """(T,d) tokens + sorted-order description -> (bins, capacity, d)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return blob_pack_pallas(x, order, starts, counts,
                                capacity=capacity,
                                interpret=not _on_tpu())
    return blob_pack_ref(x, order, starts, counts, capacity=capacity)


@functools.partial(jax.jit, static_argnames=("num_bins", "capacity",
                                             "use_pallas"))
def pack_from_keys(x, keys, *, num_bins: int, capacity: int,
                   use_pallas: bool = None):
    """Convenience: bin tokens by destination key and pack into blobs."""
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    counts = jnp.bincount(keys, length=num_bins).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    return blob_pack(x, order, starts, counts, capacity=capacity,
                     use_pallas=use_pallas), (order, starts, counts)
