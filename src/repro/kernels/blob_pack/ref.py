"""Pure-jnp oracle for blob_pack: gather sorted tokens into bin layout.

blob_pack turns per-token rows into the contiguous per-destination blob
layout used by the shuffle (the Batcher hot path). Inputs are the
*sorted-order* description produced by repro.shuffle.binning:

  x       (T, d)     token rows
  order   (U,)       unit index -> token index, sorted by destination bin
  starts  (bins,)    first position of each bin within `order`
  counts  (bins,)    true demand per bin (may exceed capacity)

Output: (bins, capacity, d); rows beyond a bin's count are zero.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def blob_pack_ref(x: jax.Array, order: jax.Array, starts: jax.Array,
                  counts: jax.Array, *, capacity: int) -> jax.Array:
    r = jnp.arange(capacity)
    # unit position in sorted order for (bin b, row r): starts[b] + r
    pos = starts[:, None] + r[None, :]                      # (bins, cap)
    valid = r[None, :] < jnp.minimum(counts, capacity)[:, None]
    tok = order[jnp.clip(pos, 0, order.shape[0] - 1)]       # (bins, cap)
    rows = x[tok]                                           # (bins, cap, d)
    return jnp.where(valid[..., None], rows, 0).astype(x.dtype)
