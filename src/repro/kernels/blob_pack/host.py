"""Host (numpy) fast path for the fused blob pack.

Off-accelerator, the jitted jnp oracle bottoms out in XLA:CPU's gather,
which tops out well under the machine's copy bandwidth for this access
pattern (many ~1 KiB row copies). The host path reaches the hardware
limit with three moves numpy does at memcpy-class speed:

  1. one stable argsort + bincount/cumsum (the ``sorted_order`` front
     half, numpy twins of ``repro.shuffle.binning.sorted_order``);
  2. one row gather ``x[order]`` into destination order, done on the
     widest integer view of the row bytes;
  3. per-bin **contiguous block copies** into the padded (bins,
     capacity, d) layout — sequential memcpys, not per-row gathers.

Outputs are bit-exact with ``blob_pack_ref`` (pure byte movement; the
parity tests in ``tests/test_kernels.py`` assert it).

Callers on a steady-state hot path should pass ``out=`` (and reuse the
returned array): a fresh 10s-of-MiB allocation per call pays a page
-fault storm that costs more than the copies themselves. With a reused
arena the pack runs ~2x faster; padding rows are re-zeroed per call so
reuse is semantically invisible.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def sorted_order_np(keys, num_bins: int
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Numpy twin of ``repro.shuffle.binning.sorted_order`` — identical
    (order, starts, counts) arrays (stable argsort ties resolve the same
    way), so host- and device-packed blobs line up slot for slot."""
    keys = np.asarray(keys)
    order = np.argsort(keys, kind="stable").astype(np.int32)
    counts = np.bincount(keys, minlength=num_bins).astype(np.int32)
    starts = np.zeros(num_bins, np.int32)
    np.cumsum(counts[:-1], out=starts[1:])
    return order, starts, counts


def _widest_view(a: np.ndarray) -> np.ndarray:
    """View (n, d)-shaped row bytes as the widest integer dtype dividing
    the row size — fancy indexing copies per *item*, so wider items move
    the same bytes with fewer copies."""
    row_bytes = a.shape[-1] * a.dtype.itemsize
    for width, dt in ((8, np.uint64), (4, np.uint32), (2, np.uint16)):
        if row_bytes % width == 0 and a.dtype.itemsize != width:
            try:
                return a.view(dt)
            except ValueError:       # non-contiguous last axis
                return a
        if a.dtype.itemsize == width:
            return a
    return a


def blob_pack_fused_host(x, keys, *, num_bins: int, capacity: int,
                         out: Optional[np.ndarray] = None
                         ) -> Tuple[np.ndarray, Tuple[np.ndarray,
                                                      np.ndarray,
                                                      np.ndarray]]:
    """(T, d) host rows + destination keys -> ((bins, capacity, d),
    sorted-order description), bit-exact with ``blob_pack_ref``.

    ``out``: optional preallocated (bins, capacity, d) array of ``x``'s
    dtype to write into (arena reuse; see module docstring)."""
    x = np.asarray(x)
    d = x.shape[-1]
    order, starts, counts = sorted_order_np(keys, num_bins)
    reuse = (out is not None and out.shape == (num_bins, capacity, d)
             and out.dtype == x.dtype and out.flags.c_contiguous)
    if not reuse:
        out = np.zeros((num_bins, capacity, d), x.dtype)
    xs = _widest_view(np.ascontiguousarray(x))[order]
    ov = _widest_view(out)
    take = np.minimum(counts, capacity)
    for b in range(num_bins):
        s = starts[b]
        c = take[b]
        ov[b, :c] = xs[s:s + c]
        if reuse and c < capacity:
            ov[b, c:] = 0
    return out, (order, starts, counts)
