from repro.data.generator import (LoadGenerator, lm_batch_stream,
                                  shufflebench_records)
