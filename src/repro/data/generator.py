"""Data pipeline: ShuffleBench-style load generator + LM token streams.

* ``shufflebench_records`` — the paper's benchmark workload: records with
  random byte values; the key is derived from the first 8 bytes of the
  value (paper §5.1.1 step ii); a timestamp is written into the tail of
  the value (step iii) for latency measurement.
* ``LoadGenerator`` — rate-capped generator (ad-hoc throughput method:
  offered load above the system's capacity).
* ``lm_batch_stream`` — deterministic, step-keyed synthetic token batches
  for the training examples (step-keyed ⇒ restarts replay identically —
  the property the fault-tolerance tests rely on).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.records import Record


def shufflebench_records(n: int, value_bytes: int = 1024, seed: int = 0,
                         t0_us: int = 0) -> List[Record]:
    rng = np.random.default_rng(seed)
    out = []
    vals = rng.bytes(n * value_bytes)
    for i in range(n):
        v = vals[i * value_bytes:(i + 1) * value_bytes]
        out.append(Record(key=v[:8], value=v, timestamp_us=t0_us + i))
    return out


@dataclasses.dataclass
class LoadGenerator:
    """Per-instance generator emitting up to ``rate`` records/s."""
    rate: float = 180_000.0
    value_bytes: int = 1024
    seed: int = 0

    def window(self, t_start: float, t_end: float) -> List[Record]:
        n = int((t_end - t_start) * self.rate)
        return shufflebench_records(n, self.value_bytes, seed=self.seed,
                                    t0_us=int(t_start * 1e6))


def lm_batch_stream(vocab_size: int, batch: int, seq: int,
                    *, multimodal=None, d_model: int = 0):
    """Returns batch_fn(step) -> training batch (tokens+labels or
    frames/patches for the stub-frontend archs)."""
    def batch_fn(step: int) -> Dict[str, jax.Array]:
        k = jax.random.key(step)
        ks = jax.random.split(k, 3)
        if multimodal is not None and multimodal.kind == "audio":
            return {
                "frames": jax.random.normal(ks[0], (batch, seq, d_model),
                                            jnp.bfloat16),
                "labels": jax.random.randint(ks[1], (batch, seq), 0,
                                             vocab_size),
            }
        if multimodal is not None and multimodal.kind == "vision":
            P = multimodal.num_patches
            labels = jax.random.randint(ks[2], (batch, seq), 0, vocab_size)
            labels = labels.at[:, :P].set(-100)  # no loss on patches
            return {
                "tokens": jax.random.randint(ks[0], (batch, seq - P), 0,
                                             vocab_size),
                "patches": jax.random.normal(ks[1], (batch, P, d_model),
                                             jnp.bfloat16),
                "labels": labels,
            }
        toks = jax.random.randint(ks[0], (batch, seq + 1), 0, vocab_size)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    return batch_fn
