from repro.serving.engine import (ServeConfig, make_prefill_step,
                                  make_decode_step, greedy_sample)
