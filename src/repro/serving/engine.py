"""Serving steps: prefill (full-sequence forward) and one-token decode.

``serve_step`` semantics per the assignment: decode shapes lower ONE new
token against a KV cache of ``seq_len`` (the cache is the dominant state).
The batch scheduler in ``repro.serving.scheduler`` drives these steps for
the runnable serving example.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.common import ModelConfig
from repro.shuffle.api import ShuffleConfig


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    shuffle: ShuffleConfig = ShuffleConfig(mode="dense")
    temperature: float = 0.0  # 0 = greedy


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def make_prefill_step(cfg: ModelConfig, scfg: ServeConfig, mesh=None,
                      hints=None):
    """prefill(params, batch) -> logits (B, S, V). Inference forward."""
    from repro.models.flash import NO_HINTS
    hints = hints or NO_HINTS

    def prefill(params, batch):
        logits, _ = lm.forward(cfg, params, batch, mesh=mesh,
                               shuffle=scfg.shuffle, remat="none",
                               hints=hints)
        return logits
    return prefill


def make_decode_step(cfg: ModelConfig, scfg: ServeConfig, mesh=None):
    """serve_step(params, cache, batch{tokens,pos}) -> (cache, next, logits)."""
    def serve_step(params, cache, batch):
        logits, new_cache = lm.decode_step(cfg, params, cache, batch,
                                           mesh=mesh, shuffle=scfg.shuffle)
        nxt = greedy_sample(logits)
        return new_cache, nxt, logits
    return serve_step
