"""Small shared helpers used across the framework."""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

KiB = 1024
MiB = 1024**2
GiB = 1024**3


def tree_size_bytes(tree: PyTree) -> int:
    """Total bytes of all leaves (works on ShapeDtypeStruct and arrays)."""
    return sum(
        math.prod(x.shape) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_num_params(tree: PyTree) -> int:
    return sum(math.prod(x.shape) for x in jax.tree.leaves(tree))


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}TiB"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def dataclass_replace(obj, **kw):
    return dataclasses.replace(obj, **kw)


def stable_hash64(data: bytes) -> int:
    """Deterministic 64-bit FNV-1a hash (no Python hash randomization)."""
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def percentile(values: Iterable[float], q: float) -> float:
    arr = np.asarray(sorted(values), dtype=np.float64)
    if arr.size == 0:
        return float("nan")
    return float(np.percentile(arr, q))
