"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints store whole (unsharded) arrays, so elasticity is a sharding
decision at restore time: build the new mesh, derive new NamedShardings
from the same logical-axis rules, and device_put. The data pipeline
rescales per-host batch = global_batch / new_dp. Used by
``BlobCheckpointer.restore(..., shardings=...)`` and tested end-to-end on
8→4→8 host devices.
"""

from __future__ import annotations

from typing import Any, Dict


from repro.distributed.sharding import ShardingRules, named_shardings


def elastic_restore_plan(defs, rules: ShardingRules, new_mesh
                         ) -> Dict[str, Any]:
    """Shardings + per-host batch scaling for the new topology."""
    shardings = named_shardings(defs, rules, new_mesh)
    dp = 1
    for ax in ("pod", "data"):
        if ax in new_mesh.shape:
            dp *= new_mesh.shape[ax]
    return {"shardings": shardings, "dp_degree": dp,
            "devices": new_mesh.devices.size}
