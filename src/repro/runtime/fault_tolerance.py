"""Fault-tolerant training driver: periodic blob checkpoints + restart.

Failures (injected or real exceptions) roll back to the latest *committed*
manifest; the restarted run continues bit-identically (tested), because
the checkpoint captures (params, opt_state, step) and the data pipeline
is step-keyed (deterministic record generation per step).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional


from repro.checkpoint import BlobCheckpointer, FileStore, latest_step


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultTolerantTrainer:
    """Drives train_step with checkpoint/restart.

    train_step: (params, opt, batch) -> (params, opt, metrics)
    batch_fn:   step -> batch  (deterministic — the data pipeline is
                step-keyed so replays after restart are identical)
    """
    store: FileStore
    train_step: Callable
    batch_fn: Callable
    ckpt_every: int = 10
    async_upload: bool = True

    def __post_init__(self):
        self.ckpt = BlobCheckpointer(self.store,
                                     async_upload=self.async_upload)

    def run(self, params, opt_state, *, steps: int,
            fail_at: Optional[Dict[int, int]] = None,
            max_restarts: int = 10):
        """Run ``steps`` steps; ``fail_at`` maps step->how many times to
        fail there. Returns (params, opt, history of losses)."""
        fail_at = dict(fail_at or {})
        state = {"params": params, "opt": opt_state}
        self.ckpt.save(0, state)
        self.ckpt.wait()
        history = {}
        step = 0
        restarts = 0
        while step < steps:
            try:
                if fail_at.get(step, 0) > 0:
                    fail_at[step] -= 1
                    raise InjectedFailure(f"node failure at step {step}")
                batch = self.batch_fn(step)
                p, o, metrics = self.train_step(state["params"],
                                                state["opt"], batch)
                state = {"params": p, "opt": o}
                history[step] = float(metrics["loss"])
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except InjectedFailure:
                restarts += 1
                if restarts > max_restarts:
                    raise
                self.ckpt.wait()
                last = latest_step(self.store)
                state = self.ckpt.restore(last, state)
                # drop uncommitted history (recomputed after restart)
                history = {s: l for s, l in history.items() if s < last}
                step = last
        self.ckpt.save(steps, state)
        self.ckpt.wait()
        losses = [history[s] for s in sorted(history)]
        return state["params"], state["opt"], losses
