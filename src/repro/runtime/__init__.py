from repro.runtime.fault_tolerance import FaultTolerantTrainer
from repro.runtime.stragglers import HedgedFetcher
from repro.runtime.elastic import elastic_restore_plan
