"""Straggler mitigation: hedged blob fetches.

Object-storage latency is long-tailed (paper Fig. 5); at thousands of
concurrent readers the per-step tail is the max over many samples. The
hedge: if the primary GET has not completed within ``hedge_quantile`` of
the latency distribution, fire a backup request and take the earlier
completion — bounding the per-request tail at the cost of a small extra
request rate. (Same single-flight cache keeps the per-AZ GET invariant:
the hedge re-requests through the cache owner, not around it.)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.stores import LatencyModel


@dataclasses.dataclass
class HedgeStats:
    requests: int = 0
    hedges: int = 0
    wins: int = 0          # backup finished first


class HedgedFetcher:
    """Models hedged GETs against the calibrated latency distribution."""

    def __init__(self, latency: Optional[LatencyModel] = None,
                 hedge_quantile: float = 0.95, seed: int = 0):
        self.latency = latency or LatencyModel()
        self.q = hedge_quantile
        self.rng = np.random.default_rng(seed)
        self.stats = HedgeStats()

    def hedge_threshold(self, size: int) -> float:
        med = self.latency.get_median(size)
        z = {0.90: 1.2816, 0.95: 1.6449, 0.99: 2.3263}.get(self.q, 1.6449)
        return med * float(np.exp(self.latency.sigma * z))

    def fetch(self, size: int) -> float:
        """Returns the effective completion latency with hedging."""
        self.stats.requests += 1
        t1 = self.latency.sample_get(size, self.rng)
        thresh = self.hedge_threshold(size)
        if t1 <= thresh:
            return t1
        self.stats.hedges += 1
        t2 = thresh + self.latency.sample_get(size, self.rng)
        if t2 < t1:
            self.stats.wins += 1
        return min(t1, t2)

    def tail_improvement(self, size: int, n: int = 20000,
                         pct: float = 99.0) -> Tuple[float, float]:
        """(p_tail without hedging, p_tail with hedging)."""
        base = np.array([self.latency.sample_get(size, self.rng)
                         for _ in range(n)])
        hedged = np.array([self.fetch(size) for _ in range(n)])
        return (float(np.percentile(base, pct)),
                float(np.percentile(hedged, pct)))
