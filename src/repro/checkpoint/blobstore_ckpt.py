"""Checkpointing through the BlobShuffle storage pattern.

The paper's commit protocol, reused for fault tolerance: every array leaf
is uploaded as a **blob**; the **manifest** (the "notification") is written
only after all blob uploads are durable. A crash mid-checkpoint leaves
orphaned blobs — harmless and unreachable, collected by retention —
never a corrupt checkpoint. Restore trusts manifests only.

* ``FileStore`` — filesystem-backed object store (same interface shape as
  the simulated S3; blobs are content-addressed under ``objects/``).
* ``BlobCheckpointer`` — save/restore of arbitrary pytrees with optional
  **async** upload (background thread — overlaps training compute) and
  **elastic restore**: arrays are stored whole, so restoring onto a
  different mesh/sharding (different DP/TP size) is a device_put with the
  new shardings.

The checkpointer is store-agnostic: any object implementing the
``CheckpointStore`` shape (``put``/``get``/``put_manifest``/
``get_manifest``/``manifests``/``run_retention``) works — ``FileStore``
here for real filesystems, ``repro.checkpoint.tiered.TieredCheckpointStore``
to checkpoint through the simulated multi-tier blob stores
(``SimulatedS3`` / ``ExpressOneZoneStore`` / ``FaultyStore``).

Manifests can carry an ``extra`` dict (e.g. the training input pipeline's
per-partition consumed offsets) so data-plane progress commits atomically
with the model state it belongs to.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, List, Optional

import jax
import numpy as np

PyTree = Any


class FileStore:
    """Append-only object store on the filesystem (durable blob tier)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(root, "manifests"), exist_ok=True)

    def put(self, blob_id: str, data: bytes) -> None:
        path = os.path.join(self.root, "objects", blob_id)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)  # atomic: a blob either exists fully or not

    def get(self, blob_id: str) -> bytes:
        with open(os.path.join(self.root, "objects", blob_id), "rb") as f:
            return f.read()

    def put_manifest(self, name: str, manifest: dict) -> None:
        path = os.path.join(self.root, "manifests", name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)

    def get_manifest(self, name: str) -> Optional[dict]:
        path = os.path.join(self.root, "manifests", name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return json.load(f)

    def manifests(self) -> List[str]:
        return sorted(os.listdir(os.path.join(self.root, "manifests")))

    def run_retention(self) -> int:
        """GC blobs unreachable from any manifest (orphans from crashes)."""
        live = set()
        for name in self.manifests():
            m = self.get_manifest(name)
            live.update(e["blob"] for e in m["leaves"])
        removed = 0
        objdir = os.path.join(self.root, "objects")
        for blob in os.listdir(objdir):
            if blob not in live and not blob.endswith(".tmp"):
                os.remove(os.path.join(objdir, blob))
                removed += 1
        return removed


def _encode(arr: np.ndarray) -> bytes:
    """Raw little-endian bytes (dtype/shape live in the manifest) — this
    covers ml_dtypes types (bfloat16, fp8) that np.save cannot roundtrip."""
    return arr.tobytes()


def _decode(data: bytes, shape, dtype_str: str) -> np.ndarray:
    import ml_dtypes  # registered extension dtypes (bfloat16, ...)
    try:
        dt = np.dtype(dtype_str)
    except TypeError:
        dt = np.dtype(getattr(ml_dtypes, dtype_str))
    return np.frombuffer(data, dtype=dt).reshape(shape)


class BlobCheckpointer:
    def __init__(self, store, *, async_upload: bool = True):
        self.store = store
        self.async_upload = async_upload
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- write path ------------------------------------------------------
    def save(self, step: int, tree: PyTree, *, extra: Optional[dict] = None,
             crash_before_manifest=False):
        """Upload all leaves as blobs, then commit the manifest.

        ``extra`` rides in the manifest (JSON-serializable metadata that
        must commit atomically with the checkpoint — e.g. input-pipeline
        offsets); read it back with :meth:`manifest`.

        ``crash_before_manifest`` (tests): simulate a failure after the
        blob uploads but before the manifest write — the checkpoint must
        NOT become visible.
        """
        self.wait()
        leaves, treedef = jax.tree.flatten(tree)
        host = [np.asarray(l) for l in leaves]  # device→host copy now

        def work():
            entries = []
            for i, arr in enumerate(host):
                blob_id = f"step{step:08d}_leaf{i:05d}.npy"
                self.store.put(blob_id, _encode(arr))
                entries.append({"blob": blob_id,
                                "shape": list(arr.shape),
                                "dtype": str(arr.dtype)})
            if crash_before_manifest:
                return  # blobs become orphans; manifest never written
            manifest = {"step": step, "treedef": str(treedef),
                        "leaves": entries, "time": time.time(),
                        "extra": extra or {}}
            self.store.put_manifest(f"step{step:08d}.json", manifest)

        if self.async_upload:
            def run():
                try:
                    work()
                except BaseException as e:  # surfaced on next wait()
                    self._error = e
            self._thread = threading.Thread(target=run, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        """Block until the in-flight checkpoint is durable (commit)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # -- read path ---------------------------------------------------------
    def manifest(self, step: int) -> Optional[dict]:
        """The committed manifest for ``step`` (None if not committed).
        ``manifest(step)["extra"]`` carries the metadata saved alongside."""
        m = self.store.get_manifest(f"step{step:08d}.json")
        if m is not None:
            m.setdefault("extra", {})  # manifests from older writers
        return m

    def restore(self, step: int, like: PyTree, *, shardings: PyTree = None
                ) -> PyTree:
        """Restore into the structure of ``like``; optionally device_put
        with (possibly different — elastic) shardings."""
        m = self.store.get_manifest(f"step{step:08d}.json")
        if m is None:
            raise FileNotFoundError(f"no committed checkpoint for {step}")
        leaves, treedef = jax.tree.flatten(like)
        assert len(leaves) == len(m["leaves"]), "tree structure changed"
        out = []
        for ref, entry in zip(leaves, m["leaves"]):
            assert list(ref.shape) == entry["shape"], \
                f"shape mismatch {ref.shape} vs {entry['shape']}"
            arr = _decode(self.store.get(entry["blob"]), entry["shape"],
                          entry["dtype"])
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree


def latest_step(store) -> Optional[int]:
    names = store.manifests()
    if not names:
        return None
    return max(int(n[4:12]) for n in names)
