"""Checkpointing through the simulated multi-tier blob stores.

``TieredCheckpointStore`` adapts any ``BlobStore`` tier — ``SimulatedS3``,
the zonal ``ExpressOneZoneStore``, or either wrapped in a ``FaultyStore``
fault injector — to the ``CheckpointStore`` shape that
``BlobCheckpointer`` drives (``put``/``get``/``put_manifest``/
``get_manifest``/``manifests``/``run_retention``). This is the paper's
commit pattern applied to model state: leaves are blobs, the manifest is
the notification, and a crash between the two leaves only unreachable
orphans for retention to collect.

Tier semantics handled here rather than in the checkpointer:

* **faults** — ``StoreError`` (503 SlowDown / transient / timeout) raised
  at issue time by a ``FaultyStore`` is retried up to ``max_attempts``
  with the attempt count surfaced in ``.retries`` (the checkpointer
  stays oblivious; a persistent fault still propagates);
* **zonal placement** — an ``az`` hint pins checkpoint objects to one
  zone of an ``ExpressOneZoneStore`` (cross-AZ restore then pays the
  tier's routing penalty, exactly like shuffle blobs);
* **virtual clock** — ``clock`` (e.g. ``lambda: engine.loop.now``) bills
  storage byte·seconds and retention age on the same clock as the
  shuffle traffic sharing the store;
* **namespacing** — keys live under ``<prefix>objects/`` and
  ``<prefix>manifests/`` so checkpoints and shuffle blobs can share one
  store without colliding;
* **retention** — ``run_retention`` is manifest-reachability GC: any
  checkpoint object not referenced by a committed manifest (a crash
  orphan) is deleted through the store's billed ``delete``.
"""

from __future__ import annotations

import json
from typing import Callable, List, Optional

from repro.core.stores import StoreError

_MANIFESTS = "manifests/"
_OBJECTS = "objects/"


def _base(store):
    """Unwrap decorator stores (``FaultyStore.inner`` chains) down to the
    object that owns the key namespace — listing must not consume fault
    budget or billing, it's a control-plane operation."""
    s = store
    while not hasattr(s, "objects") and hasattr(s, "inner"):
        s = s.inner
    return s


class TieredCheckpointStore:
    """``CheckpointStore`` over any simulated ``BlobStore`` tier."""

    def __init__(self, store, *, az: Optional[int] = None,
                 clock: Optional[Callable[[], float]] = None,
                 max_attempts: int = 8, prefix: str = "ckpt/"):
        self.store = store
        self.az = az
        self.prefix = prefix
        self.max_attempts = max_attempts
        self._clock = clock or (lambda: 0.0)
        self.retries = 0            # fault-injected attempts that re-ran

    # -- retry shim ---------------------------------------------------------
    def _attempt(self, fn):
        last: Optional[StoreError] = None
        for _ in range(self.max_attempts):
            try:
                return fn()
            except StoreError as e:
                self.retries += 1
                last = e
        raise last

    def _okey(self, blob_id: str) -> str:
        return self.prefix + _OBJECTS + blob_id

    def _mkey(self, name: str) -> str:
        return self.prefix + _MANIFESTS + name

    # -- CheckpointStore API ------------------------------------------------
    def put(self, blob_id: str, data: bytes) -> None:
        self._attempt(lambda: self.store.put(
            self._okey(blob_id), data, now=self._clock(), az=self.az))

    def get(self, blob_id: str) -> bytes:
        return self._attempt(lambda: self.store.get(
            self._okey(blob_id), None, self._clock(), self.az))[0]

    def put_manifest(self, name: str, manifest: dict) -> None:
        data = json.dumps(manifest, sort_keys=True).encode()
        self._attempt(lambda: self.store.put(
            self._mkey(name), data, now=self._clock(), az=self.az))

    def get_manifest(self, name: str) -> Optional[dict]:
        key = self._mkey(name)
        if not self.store.contains(key):
            return None
        data = self._attempt(
            lambda: self.store.get(key, None, self._clock(), self.az))[0]
        return json.loads(data)

    def manifests(self) -> List[str]:
        pre = self.prefix + _MANIFESTS
        return sorted(k[len(pre):] for k in _base(self.store).objects
                      if k.startswith(pre))

    def run_retention(self, now: Optional[float] = None) -> int:
        """GC checkpoint objects unreachable from any committed manifest
        (orphans from crashes mid-checkpoint). Only keys under this
        adapter's prefix are considered — co-located shuffle blobs are
        governed by the store's own age-based retention."""
        now = self._clock() if now is None else now
        live = set()
        for name in self.manifests():
            m = self.get_manifest(name)
            live.update(self._okey(e["blob"]) for e in m["leaves"])
        pre = self.prefix + _OBJECTS
        base = _base(self.store)
        dead = [k for k in base.objects
                if k.startswith(pre) and k not in live]
        for k in dead:
            base.delete(k, now)
        return len(dead)
