from repro.checkpoint.blobstore_ckpt import (BlobCheckpointer, FileStore,
                                             latest_step)
