from repro.checkpoint.blobstore_ckpt import (BlobCheckpointer, FileStore,
                                             latest_step)
from repro.checkpoint.tiered import TieredCheckpointStore

__all__ = ["BlobCheckpointer", "FileStore", "TieredCheckpointStore",
           "latest_step"]
