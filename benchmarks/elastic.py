"""Elasticity benchmarks: rebalance cost, exactly-once, and $ delta.

Three scripted measurements over the cluster subsystem:

  * **handoff** — the acceptance scenario: a worker joins mid-stream
    (cooperative rebalance), then an original worker crashes
    (reassignment). Verifies record-by-record bit-identical delivery
    against a static-cluster run of the same workload, counts partitions
    moved (sticky: join must move at most the new worker's fair share),
    and compares p95 shuffle latency inside the rebalance windows
    against steady state.
  * **eager-vs-coop** — the same join in eager (stop-the-world) mode,
    for the pause/replay contrast.
  * **autoscale** — a load spike through ``simulate_elastic`` with the
    lag/queue-driven autoscaler; reports the infra $ actually paid
    (worker-seconds) against a static cluster provisioned for the peak
    worker count the elastic run reached.

Writes ``BENCH_elastic.json`` so CI can gate on: p95 during a
cooperative rebalance <= 3x steady-state p95; zero lost and zero
duplicated records across scale-out + crash; payload bit-identity.
"""

from __future__ import annotations

import json
from collections import Counter
from typing import List, Tuple

import numpy as np

from repro.cluster import ElasticCluster
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,
                        EngineConfig, Record, SimConfig, simulate_elastic)

Row = Tuple[str, float, str]

CFG = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                        num_partitions=18, num_az=3)
N_RECORDS = 4000
RATE = 2500.0            # arrivals span N_RECORDS / RATE seconds
N_INSTANCES = 4
JOIN_T, CRASH_T = 0.4, 1.0
WINDOW_GRACE_S = 0.4     # rebalance window extends past ended_at


def _records(n=N_RECORDS, seed=11):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(300), timestamp_us=i)
            for i in range(n)]


def _engine():
    return AsyncShuffleEngine(
        CFG, EngineConfig(commit_interval_s=0.1),
        n_instances=N_INSTANCES, seed=7, exactly_once=True)


def _multiset(eng):
    return {p: sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                      for r in rs)
            for p, rs in eng.out.items() if rs}


def _run(mode=None):
    """mode None = static cluster; otherwise elastic join + crash."""
    eng = _engine()
    cluster = None
    if mode is not None:
        cluster = ElasticCluster(eng, mode=mode, heartbeat_timeout_s=0.15)
        eng.loop.at(JOIN_T, cluster.add_worker)
        cluster.crash_worker_at(CRASH_T, "w1")
    for i, rec in enumerate(_records()):
        eng.submit(i / RATE, rec)
    metrics = eng.run()
    return eng, cluster, metrics


def _windowed_p95(metrics, events):
    """(steady p95, rebalance-window p95) from timestamped latencies."""
    lat = np.asarray(metrics.record_latencies)
    times = np.asarray(metrics.record_latency_times)
    windows = [(e.started_at, e.ended_at + WINDOW_GRACE_S)
               for e in events if not e.superseded]
    in_win = np.zeros(len(lat), dtype=bool)
    for lo, hi in windows:
        in_win |= (times >= lo) & (times <= hi)
    steady = lat[~in_win]
    during = lat[in_win]
    p95_steady = float(np.percentile(steady, 95)) if steady.size \
        else float(np.percentile(lat, 95))
    p95_during = float(np.percentile(during, 95)) if during.size \
        else p95_steady
    return p95_steady, p95_during


def _diff_counts(static_ms, elastic_ms):
    """(lost, duplicated) record counts, elastic vs static multiset."""
    lost = dup = 0
    for p in set(static_ms) | set(elastic_ms):
        a = static_ms.get(p, [])
        b = elastic_ms.get(p, [])
        ca, cb = Counter(a), Counter(b)
        lost += sum((ca - cb).values())
        dup += sum((cb - ca).values())
    return lost, dup


#: written into the JSON under "_doc" (see docs/benchmarks.md)
FIELD_DOCS = {
    "records": "records submitted per run",
    "payload_bit_identical": "GATE: cooperative-rebalance delivery multiset "
                             "== static baseline's",
    "records_lost": "GATE(=0): records the elastic run failed to deliver",
    "records_duplicated": "GATE(=0): extra deliveries vs the static run",
    "duplicates_delivered": "GATE(=0): duplicates the engine itself saw",
    "records_replayed": "records replayed by commit-protocol recovery",
    "p95_steady_s": "p95 record latency outside rebalance windows",
    "p95_rebalance_s": "p95 record latency inside rebalance windows",
    "p95_ratio": "GATE(<=3x): rebalance p95 / steady p95",
    "partitions_moved_join": "GATE(<= fair share): partitions moved when "
                             "a worker joined (sticky assignment)",
    "join_fair_share": "ceil(partitions / workers) after the join",
    "partitions_moved_total": "partitions moved across all rebalances",
    "replayed_entries": "notification-log entries replayed on handoff",
    "handoff_duplicates_dropped": "deliveries suppressed by the handoff "
                                  "dedup fence",
    "cache_reroutes": "consumer cache reroutes after ownership moves",
    "eager_records_lost": "records lost under eager (non-cooperative) "
                          "rebalance — the contrast lane",
    "eager_records_duplicated": "extra deliveries under eager rebalance",
    "eager_undeliverable": "records eager rebalance orphaned entirely",
    "eager_replayed_entries": "log entries replayed under eager rebalance",
    "autoscale_decisions": "scale decisions: virtual time, action, worker "
                           "count, rule that fired",
    "autoscale_peak_workers": "max workers the autoscaler provisioned",
    "autoscale_lag_final": "consumer lag (records) at end of the spike run",
    "autoscale_duplicates": "duplicate deliveries during autoscale (=0)",
    "cost_usd_static_infra": "infra cost if peak workers ran the whole run",
    "cost_usd_elastic_infra": "infra cost actually billed by the autoscaler",
    "cost_delta_usd": "savings of elastic vs peak-static provisioning",
}


def run() -> List[Row]:
    rows: List[Row] = []
    result = {}

    # -- handoff: static baseline vs cooperative join + crash -------------
    static_eng, _, static_m = _run(None)
    coop_eng, coop, coop_m = _run("cooperative")
    static_ms, coop_ms = _multiset(static_eng), _multiset(coop_eng)
    lost, dup = _diff_counts(static_ms, coop_ms)
    events = [e for e in coop.rebalancer.events if not e.superseded]
    p95_steady, p95_rebalance = _windowed_p95(coop_m, events)
    join_moved = len(events[0].moved) if events else 0
    fair_share = -(-CFG.num_partitions // (N_INSTANCES + 1))
    result.update({
        "records": N_RECORDS,
        "payload_bit_identical": static_ms == coop_ms,
        "records_lost": lost,
        "records_duplicated": dup,
        "duplicates_delivered": coop_m.duplicates_delivered,
        "records_replayed": coop_m.records_replayed,
        "p95_steady_s": p95_steady,
        "p95_rebalance_s": p95_rebalance,
        "p95_ratio": p95_rebalance / p95_steady if p95_steady else 1.0,
        "partitions_moved_join": join_moved,
        "join_fair_share": fair_share,
        "partitions_moved_total": coop.rebalancer.partitions_moved,
        "replayed_entries": coop.stats.replayed_entries,
        "handoff_duplicates_dropped":
            coop.stats.handoff_duplicates_dropped,
        "cache_reroutes": coop.stats.cache_reroutes,
    })
    rows.append(("elastic.handoff", coop_m.makespan_s * 1e6,
                 f"bit_identical={result['payload_bit_identical']} "
                 f"lost={lost} dup={dup} "
                 f"p95_ratio={result['p95_ratio']:.2f} "
                 f"moved_join={join_moved}<= {fair_share} "
                 f"replayed={coop.stats.replayed_entries}"))

    # -- eager contrast ----------------------------------------------------
    eager_eng, eager, eager_m = _run("eager")
    e_lost, e_dup = _diff_counts(static_ms, _multiset(eager_eng))
    result.update({
        "eager_records_lost": e_lost,
        "eager_records_duplicated": e_dup,
        "eager_undeliverable": eager.stats.undeliverable,
        "eager_replayed_entries": eager.stats.replayed_entries,
    })
    rows.append(("elastic.eager", eager_m.makespan_s * 1e6,
                 f"lost={e_lost} dup={e_dup} "
                 f"undeliverable={eager.stats.undeliverable} "
                 f"replayed={eager.stats.replayed_entries}"))

    # -- autoscale: spike, $ vs static peak provisioning -------------------
    cfg = SimConfig(n_nodes=2, inst_per_node=2, partitions_factor=3,
                    duration_s=3.0, max_interval_s=0.25,
                    commit_interval_s=0.25, seed=3)
    eng, cluster, s = simulate_elastic(cfg, scale=0.001, spike_factor=3.0)
    peak = max([d.workers_after for d in cluster.autoscaler.decisions],
               default=len(cluster.membership.alive()))
    hourly = cluster.autoscaler.policy.worker_cost_per_hour
    static_infra = peak * eng.loop.now / 3600.0 * hourly
    elastic_infra = s["infra_cost_usd"]
    result.update({
        "autoscale_decisions": [
            {"t": d.t, "action": d.action, "workers": d.workers_after,
             "reason": d.reason}
            for d in cluster.autoscaler.decisions],
        "autoscale_peak_workers": peak,
        "autoscale_lag_final": s["lag_final"],
        "autoscale_duplicates": eng.metrics.duplicates_delivered,
        "cost_usd_static_infra": static_infra,
        "cost_usd_elastic_infra": elastic_infra,
        "cost_delta_usd": static_infra - elastic_infra,
    })
    rows.append(("elastic.autoscale", eng.loop.now * 1e6,
                 f"peak={peak} decisions="
                 f"{len(cluster.autoscaler.decisions)} "
                 f"$static={static_infra:.4f} $elastic={elastic_infra:.4f} "
                 f"saved={static_infra - elastic_infra:.4f}"))

    result["_doc"] = {k: FIELD_DOCS[k] for k in result if k in FIELD_DOCS}
    with open("BENCH_elastic.json", "w") as f:
        json.dump(result, f, indent=2)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
