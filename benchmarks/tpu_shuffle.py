"""TPU-adaptation benchmark: flat vs blob-hierarchical MoE dispatch.

Spawns an 8-device subprocess (2 pods × 2 data × 2 model) and reports,
per mode: wall time per step, inter-pod (DCN) payload bytes, and HLO
collective statistics from the compiled module — the roofline-level
evidence for the BlobShuffle adaptation (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from typing import List, Tuple

Row = Tuple[str, float, str]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_BODY = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json, time
import jax, jax.numpy as jnp
from repro.launch.mesh import make_test_mesh
from repro.launch import hlo_analysis as H
from repro.shuffle.api import ShuffleConfig, ep_moe_ffn

mesh = make_test_mesh(devices=8)
E, k, d, de, T = 16, 2, 64, 128, 4096
ks = jax.random.split(jax.random.key(0), 5)
x = jax.random.normal(ks[0], (T, d), jnp.bfloat16)
wr = jax.random.normal(ks[1], (d, E), jnp.float32) * 0.3
wg = jax.random.normal(ks[2], (E, d, de), jnp.bfloat16)
wu = jax.random.normal(ks[3], (E, d, de), jnp.bfloat16)
wd = jax.random.normal(ks[4], (E, de, d), jnp.bfloat16)
out = {}
for mode, compress in (('direct', False), ('blob', False), ('blob', True)):
    cfg = ShuffleConfig(mode=mode, token_axes=('pod','data','model'),
                        expert_axes=('pod','model'), capacity_factor=1.25,
                        compress_dcn=compress)
    f = jax.jit(lambda x: ep_moe_ffn(x, wr, wg, wu, wd, top_k=k, cfg=cfg,
                                     mesh=mesh)[0::2])
    comp = f.lower(x).compile()
    st = H.analyze(comp.as_text(), num_devices=8, devices_per_pod=4)
    y, diag = f(x)
    jax.block_until_ready(y)
    t0 = time.perf_counter()
    for _ in range(5):
        y, diag = f(x)
    jax.block_until_ready(y)
    us = (time.perf_counter() - t0) / 5 * 1e6
    key = mode + ('+int8' if compress else '')
    out[key] = {'us': us, 'dcn_bytes': float(diag.dcn_bytes),
                'dropped': int(diag.dropped),
                'hlo_collective_bytes': st.collective_bytes,
                'hlo_dcn_bytes': st.dcn_collective_bytes,
                'hlo_collective_count': st.collective_count}
print('RESULT ' + json.dumps(out))
"""


def run() -> List[Row]:
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_BODY)],
                       env=env, capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        return [("tpu_shuffle.error", 0, r.stderr.splitlines()[-1][:120])]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    data = json.loads(line[len("RESULT "):])
    rows: List[Row] = []
    base = data["direct"]
    for mode, d in data.items():
        rows.append((
            f"tpu_shuffle.{mode}", d["us"],
            f"dcn={d['dcn_bytes'] / 1e6:.2f}MB "
            f"hlo_coll={d['hlo_collective_bytes'] / 1e6:.2f}MB "
            f"hlo_dcn={d['hlo_dcn_bytes'] / 1e6:.2f}MB "
            f"n_coll={d['hlo_collective_count']} "
            f"dcn_vs_direct={d['dcn_bytes'] / max(base['dcn_bytes'], 1):.2f}x"
        ))
    return rows
