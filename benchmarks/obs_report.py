"""Latency-decomposition report: where does p95 come from, per strategy?

Runs the strategies suite's Zipf-skewed workload through the engine once
per strategy with observability enabled and decomposes end-to-end
latency into its five exact stages (batch_wait + upload + commit_wait +
notify + fetch — adjacent lifecycle timestamp differences, so per-record
stage sums equal the end-to-end sample by construction). This reproduces
the paper's latency-breakdown analysis: at small batch sizes the batch
wait dominates; as blobs grow the PUT and the commit-aligned
notification take over (§4/Fig. 6 of the BlobShuffle paper).

Every run doubles as the observability layer's own acceptance gate:

  * **bit-identity** — each observed run's delivery digest must equal
    the unobserved run's (hooks never schedule events or consume RNG);
  * **conservation** — the end-of-run checker must report zero violated
    laws for every strategy;
  * **reconciliation** — per-strategy stage mean sums must equal the
    end-to-end mean to float precision, with zero unattributed records;
  * **sketch accuracy** — the e2e p95 from the quantile sketch must be
    within 2% of ``np.percentile`` over the exact latency list;
  * **overhead** — best-of-N CPU time of an observed run over an
    unobserved one, timed in a fresh subprocess at an amortizing record
    density, must stay under 1.10 (the <10% CI gate);
  * **windowed query** — an elastic run answers "p95 during the
    rebalance" from recorded marks.

Writes ``BENCH_obs.json`` (every field documented under ``_doc``) and
the sampled Chrome-trace artifact ``TRACE_obs.json`` (load it in
``chrome://tracing`` or https://ui.perfetto.dev).
"""

from __future__ import annotations

import gc
import hashlib
import json
import subprocess
import sys
import time
from typing import Dict, List, Tuple

import numpy as np

import benchmarks.strategies as S
from repro.cluster import ElasticCluster
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig, EngineConfig,
                        ExpressOneZoneStore, WorkloadConfig, simulate_async)
from repro.core.workload import drive
from repro.obs import STAGES, ObsConfig

Row = Tuple[str, float, str]

STRATEGY_NAMES = ("default", "combining", "push", "merge")

#: best-of-N CPU-time pairs for the overhead gate (min over runs is
#: robust to noise; the virtual-clock work is deterministic)
OVERHEAD_RUNS = 9

#: record-rate scale for the overhead pairs — 2x the simulator default
#: (~19 records/blob, still far sparser than realistic blobs), so the
#: fixed per-delivery obs cost amortizes as it would in any deployment
OVERHEAD_SCALE = 0.02

#: written into the JSON itself under "_doc" so the CI gates (and the
#: reader) share one definition of every field
FIELD_DOCS = {
    "quick": "true when the run used the --quick smoke geometry",
    "stages": "the exact decomposition order: e2e = sum of these stages "
              "(adjacent blob-lifecycle timestamp differences)",
    "strategies":
        "per-strategy report: stage p50/p95/mean seconds from the "
        "windowed quantile sketches, the e2e quantiles, sum_check "
        "(stage-mean sum vs e2e mean, attributed record counts), "
        "records_delivered, the dominant p95 stage, conservation "
        "(laws checked / violations), digest_matches_unobserved, and "
        "sketch_p95_rel_err vs np.percentile over the full latency list",
    "bit_identical_all":
        "every strategy's observed run delivered the exact digest of "
        "its unobserved run (gate: must be true — obs hooks never "
        "schedule events or consume RNG)",
    "reconciliation_ok":
        "every strategy's stage mean sum equals its e2e mean to 1e-9 "
        "relative with zero unattributed records (gate: must be true)",
    "conservation_violations_total":
        "violated conservation laws summed over all strategy runs "
        "(gate: must be 0)",
    "sketch_p95_rel_err_max":
        "max over strategies of |sketch p95 - np.percentile p95| / "
        "exact p95 (gate: < 0.02, the sketch's acceptance bound)",
    "overhead_ratio":
        "best-of-N CPU seconds (process_time — immune to scheduler "
        "contention on shared CI runners; the simulation is "
        "single-threaded CPU work) of an observed default-strategy run "
        "/ unobserved (gate: < 1.10, the <10% overhead bound). Timed "
        "in a freshly spawned subprocess (pyperf-style isolation — the "
        "ratio must not depend on heap state left by earlier suites) "
        "at 2x the simulator's default record-rate scale "
        "(~19 records/blob — still far sparser than realistic blobs) "
        "because obs cost is fixed per delivery and record volume "
        "amortizes it; the shrunk CI-quick scale (~5 records/blob) "
        "would measure mostly per-delivery Python call overhead that "
        "no real deployment density exhibits",
    "overhead_scale": "record-rate scale factor used for the overhead "
                      "pair (2x simulate_async's default)",
    "obs_on_best_s": "best-of-N CPU seconds, observability enabled",
    "obs_off_best_s": "best-of-N CPU seconds, observability disabled",
    "rebalance":
        "windowed-query demo from a cooperative-rebalance run: e2e p95 "
        "inside the [trigger, complete+window] mark window vs the whole "
        "run (answered from per-window sketch merges, not bespoke code)",
    "trace_events": "events in the sampled Chrome-trace artifact "
                    "TRACE_obs.json (1-in-N blobs, crc32-deterministic)",
}


def _digest(eng) -> str:
    """Same digest as tests/test_strategies.py: delivery multiset,
    latency samples, store request counts, makespan."""
    h = hashlib.sha256()
    for p in sorted(eng.out):
        h.update(str(p).encode())
        for r in sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                        for r in eng.out[p]):
            h.update(r[0])
            h.update(r[1])
            h.update(str(r[2]).encode())
    h.update(repr([round(x, 12)
                   for x in eng.metrics.record_latencies[:50]]).encode())
    h.update(repr((eng.store.stats.puts, eng.store.stats.gets,
                   eng.store.stats.put_bytes)).encode())
    h.update(repr(round(eng.metrics.makespan_s, 9)).encode())
    return h.hexdigest()


def _run(name: str, cfg, scale: float, obs):
    store = ExpressOneZoneStore(seed=cfg.seed, num_az=cfg.n_az)
    eng, _ = simulate_async(cfg, scale=scale, exactly_once=True,
                            key_skew=S.KEY_SKEW, store=store,
                            ingest_batch_records=S.BATCH_RECORDS,
                            strategy=name, obs=obs)
    return eng


def _rebalance_window(quick: bool) -> dict:
    """Cooperative rebalance mid-stream; the windowed-query demo."""
    cfg = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                            num_partitions=18, num_az=3)
    wl = WorkloadConfig(arrival_rate=2000.0,
                        duration_s=1.0 if quick else 1.5,
                        record_bytes=300, key_skew=1.2, seed=11)
    eng = AsyncShuffleEngine(cfg, EngineConfig(commit_interval_s=0.1),
                             n_instances=4, seed=7, exactly_once=True,
                             obs=True)
    cluster = ElasticCluster(eng, mode="cooperative",
                             heartbeat_timeout_s=0.15)
    eng.loop.at(0.4, cluster.add_worker)
    drive(eng, wl, batch_records=64)
    eng.run()
    o = eng.obs
    t0 = o.registry.marks_named("rebalance_trigger:")[0][0]
    t1 = o.registry.marks_named("rebalance_complete")[-1][0]
    win = o.cfg.window_s
    p95_rebal = o.e2e_percentile(95, t0, t1 + win)
    return {"trigger_s": t0, "complete_s": t1,
            "p95_during_rebalance_s": p95_rebal,
            "p95_whole_run_s": o.e2e_percentile(95),
            "conservation_violations": len(o.report.violations)}


def _overhead_main() -> None:
    """Overhead timing pairs, run in a fresh subprocess so the heap is
    clean and the measurement is independent of whatever ran before.
    Interleaved on/off pairs so drift hits both sides equally; prints a
    JSON line the parent parses."""
    cfg, _ = S._sim_args(True)
    _run("default", cfg, OVERHEAD_SCALE, obs=None)      # warm
    offs, ons = [], []
    gc.collect()
    gc.disable()
    try:
        for _ in range(OVERHEAD_RUNS):
            gc.collect()
            t = time.process_time()
            _run("default", cfg, OVERHEAD_SCALE, obs=None)
            offs.append(time.process_time() - t)
            gc.collect()
            t = time.process_time()
            _run("default", cfg, OVERHEAD_SCALE, obs=True)
            ons.append(time.process_time() - t)
    finally:
        gc.enable()
    print(json.dumps({"offs": offs, "ons": ons}))


def run(quick: bool = False) -> List[Row]:
    cfg, scale = S._sim_args(quick)
    rows: List[Row] = []
    results: Dict[str, dict] = {}
    violations_total = 0
    rel_errs, identical, reconciled = [], [], []
    trace_eng = None

    for name in STRATEGY_NAMES:
        obs_cfg = ObsConfig(trace_sample_every=4)
        eng = _run(name, cfg, scale, obs=obs_cfg)
        eng_off = _run(name, cfg, scale, obs=None)
        d = eng.obs.stage_decomposition(qs=(50, 95))
        chk = d["sum_check"]
        rep = eng.obs.report
        exact_p95 = float(np.percentile(eng.metrics.record_latencies, 95))
        rel = abs(d["e2e"]["p95_s"] - exact_p95) / exact_p95
        same = _digest(eng) == _digest(eng_off)
        recon = (chk["unattributed_records"] == 0
                 and abs(chk["stage_mean_sum_s"] - chk["e2e_mean_s"])
                 <= 1e-9 * chk["e2e_mean_s"])
        dominant = max(STAGES, key=lambda s: d[s]["p95_s"])
        results[name] = {
            "stages": {s: d[s] for s in STAGES},
            "e2e": d["e2e"],
            "sum_check": chk,
            "records_delivered": eng.metrics.records_delivered,
            "dominant_p95_stage": dominant,
            "conservation": rep.to_dict(),
            "digest_matches_unobserved": same,
            "sketch_p95_rel_err": rel,
        }
        violations_total += len(rep.violations)
        rel_errs.append(rel)
        identical.append(same)
        reconciled.append(recon)
        if name == "default":
            trace_eng = eng
        frac = {s: d[s]["mean_s"] / chk["e2e_mean_s"] for s in STAGES}
        rows.append((f"obs.{name}", d["e2e"]["p95_s"] * 1e6,
                     " ".join(f"{s}={frac[s]:.0%}" for s in STAGES)
                     + f" dom={dominant} viol={len(rep.violations)}"))

    rebalance = _rebalance_window(quick)
    violations_total += rebalance["conservation_violations"]

    trace_eng.obs.tracer.dump("TRACE_obs.json")
    n_events = len(trace_eng.obs.tracer.events)

    # -- overhead: observed vs unobserved, best of N ----------------------
    # measured in a fresh subprocess (pyperf-style process isolation: the
    # ratio is then independent of heap state the strategy runs above
    # leave behind) at an amortizing record density — see FIELD_DOCS
    proc = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.obs_report import _overhead_main; "
         "_overhead_main()"],
        capture_output=True, text=True, check=True)
    pair = json.loads(proc.stdout.splitlines()[-1])
    off_s, on_s = min(pair["offs"]), min(pair["ons"])
    overhead = on_s / off_s

    out = {
        "quick": quick,
        "stages": list(STAGES),
        "strategies": results,
        "bit_identical_all": all(identical),
        "reconciliation_ok": all(reconciled),
        "conservation_violations_total": violations_total,
        "sketch_p95_rel_err_max": max(rel_errs),
        "overhead_ratio": overhead,
        "overhead_scale": OVERHEAD_SCALE,
        "obs_on_best_s": on_s,
        "obs_off_best_s": off_s,
        "rebalance": rebalance,
        "trace_events": n_events,
    }
    out["_doc"] = {k: FIELD_DOCS[k] for k in out if k in FIELD_DOCS}
    with open("BENCH_obs.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")

    rows.append(("obs.gates", 0.0,
                 f"bit_identical={out['bit_identical_all']} "
                 f"reconciled={out['reconciliation_ok']} "
                 f"viol={violations_total} "
                 f"sketch_err={out['sketch_p95_rel_err_max']:.4f} "
                 f"overhead={overhead:.3f} trace_events={n_events}"))
    return rows


def main() -> None:
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
