"""One benchmark per paper figure/table (Figs. 5–9 + §4 validation).

Each ``fig*`` function returns CSV rows (name, us_per_call, derived) where
us_per_call is the simulator/model wall time and ``derived`` carries the
reproduced quantity next to the paper's reported value.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import ModelParams, SimConfig, simulate
from repro.core import analytical as A

MiB = 1024 ** 2
GiB = 1024 ** 3
Row = Tuple[str, float, str]


def _timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


def fig5_latency_cdf() -> List[Row]:
    """Latency CDFs: shuffle / PUT / GET (24 instances, 16 MiB)."""
    r, us = _timed(lambda: simulate(SimConfig()))
    rows = []
    for q, paper in ((50, 1.07), (95, 1.73), (99, 2.24)):
        rows.append((f"fig5.shuffle_p{q}", us,
                     f"{r.latency_p(q):.2f}s (paper {paper}s)"))
    put_med = float(np.median(r.put_latencies))
    get_med = float(np.median(r.get_latencies))
    rows.append(("fig5.put_median", us, f"{put_med:.3f}s"))
    rows.append(("fig5.get_median", us, f"{get_med:.3f}s"))
    rows.append(("fig5.put_over_get", us,
                 f"{put_med / get_med:.1f}x (paper 7-9x)"))
    return rows


def fig6_batch_size() -> List[Row]:
    """Batch-size sweep 1–128 MiB: throughput, latency, requests, costs."""
    rows = []
    for mib in (1, 2, 4, 8, 16, 32, 64, 128):
        r, us = _timed(lambda m=mib: simulate(
            SimConfig(batch_bytes=m * MiB, max_interval_s=1e9)))
        tput = r.throughput_bytes_s / GiB
        rows.append((f"fig6.batch{mib}MiB", us,
                     f"tput={tput:.2f}GiB/s p95={r.latency_p(95):.2f}s "
                     f"put/s={r.puts_per_s:.0f} get/s={r.gets_per_s:.0f} "
                     f"getput={r.gets_per_s / r.puts_per_s:.3f} "
                     f"s3=${r.s3_cost_per_hour_at_1gib:.2f}/h "
                     f"infra=${r.infra_cost_per_hour_at_1gib:.2f}/h "
                     f"actual={r.mean_actual_batch:.2f}"))
    rows.append(("fig6.anchor_peak", 0,
                 "paper: peak 1.43GiB/s @32MiB; s3 20.63->0.29 USD/h"))
    return rows


def fig7_cost_latency() -> List[Row]:
    """Cost–latency trade-off + the >40× headline vs native Kafka."""
    rows = []
    r16, us = _timed(lambda: simulate(SimConfig(max_interval_s=1e9)))
    total = r16.total_cost_at_1gib
    kafka = r16.kafka_cost_per_hour_at_1gib
    rows.append(("fig7.blobshuffle_16MiB", us,
                 f"${total:.2f}/h @p95={r16.latency_p(95):.2f}s "
                 f"(paper $4.46/h @1.73s)"))
    rows.append(("fig7.kafka_native", us,
                 f"${kafka:.0f}/h at 1 GiB/s "
                 f"(paper $192/h at 1 GB/s)"))
    rows.append(("fig7.saving", us,
                 f"{kafka / total:.1f}x (paper >40x)"))
    return rows


def fig8_partitions() -> List[Row]:
    """Partition-count sweep at 16 MiB, 24 instances."""
    rows = []
    base = None
    for factor in (3, 6, 9, 12, 18):
        r, us = _timed(lambda f=factor: simulate(
            SimConfig(partitions_factor=f)))
        tput = r.throughput_bytes_s / GiB
        if factor == 3:
            base = tput
        rows.append((f"fig8.partitions{factor}x", us,
                     f"tput={tput:.2f}GiB/s notes/s="
                     f"{r.notifications_per_s:.0f} "
                     f"rel={tput / base:.2f}"))
    rows.append(("fig8.anchor", 0,
                 "paper: 3x partitions => ~26% lower throughput"))
    return rows


def fig9_scalability() -> List[Row]:
    """Cluster scaling 3→24 nodes (6→48 instances), 6× partitions."""
    rows = []
    for nodes in (3, 6, 9, 12, 18, 24):
        r, us = _timed(lambda n=nodes: simulate(
            SimConfig(n_nodes=n, partitions_factor=6)))
        tput = r.throughput_bytes_s / GiB
        per_node = r.throughput_bytes_s / MiB / nodes
        rows.append((f"fig9.nodes{nodes}", us,
                     f"tput={tput:.2f}GiB/s per_node={per_node:.1f}MiB/s "
                     f"p95={r.latency_p(95):.2f}s"))
    rows.append(("fig9.anchor", 0,
                 "paper: 0.37->2.39GiB/s, per-node 144.2->102.0MiB/s"))
    return rows


def model_validation() -> List[Row]:
    """§4 analytical model vs the discrete-event simulator."""
    p = ModelParams(n_inst=24, n_az=3, rate=1.38 * GiB / 1024, s_rec=1024,
                    s_batch=16 * MiB)
    r, us = _timed(lambda: simulate(SimConfig()))
    rows = [
        ("model.mu_put", us,
         f"analytic={A.put_rate(p):.1f}/s sim={r.puts_per_s:.1f}/s"),
        ("model.mu_get", us,
         f"analytic={A.get_rate(p):.1f}/s sim={r.gets_per_s:.1f}/s"),
        ("model.t_batch", us, f"{A.t_batch(p):.2f}s"),
        ("model.latency_mean", us,
         f"analytic={A.shuffle_latency_mean(p):.2f}s "
         f"sim={float(np.mean(r.shuffle_latencies)):.2f}s"),
        ("model.latency_max_bound", us,
         f"{A.shuffle_latency_max(p):.2f}s >= sim p50 "
         f"{r.latency_p(50):.2f}s"),
    ]
    return rows
