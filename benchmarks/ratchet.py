"""Throughput ratchet: fail when BENCH_micro throughput regresses.

The committed ``BENCH_micro.json`` is the baseline. CI copies it aside,
reruns the micro suite, and compares the fresh numbers against the copy:

    cp BENCH_micro.json /tmp/bench_baseline.json
    python -m benchmarks.run --suite micro --quick
    python -m benchmarks.ratchet BENCH_micro.json \
        --baseline /tmp/bench_baseline.json

Exit status 1 (and a per-key report) when any ratcheted key falls below
``tolerance × baseline``. The tolerance band absorbs shared-runner
timing noise and the quick-vs-full geometry difference; it is tight
enough to catch the regression class the ratchet exists for (an
accidental fallback to a scalar path is a multi-x cliff, not a few
percent).

``--update`` rewrites the baseline file with the fresh values when they
improve (per key, monotonic — the ratchet only ever goes up). CI cannot
commit, so the loop is: CI uploads the fresh json as an artifact; a
developer reruns locally with ``--update`` and commits the raised
baseline.
"""

from __future__ import annotations

import argparse
import json
import sys

#: keys the ratchet enforces — the headline data-plane throughputs (see
#: FIELD_DOCS in benchmarks/micro.py; all are GB/s over logical bytes,
#: so baseline and fresh runs are directly comparable). The device-lane
#: key only exists when the Pallas lane ran on a real accelerator: a
#: baseline committed from a TPU/GPU machine ratchets it there, while a
#: CPU-only CI fresh run skips it with a warning (never a failure — the
#: lane being absent is an environment property, not a regression).
RATCHET_KEYS = ("pack_gb_s", "v2_encode_gb_s", "device_pack_gb_s")

#: fresh value must be >= TOLERANCE * baseline to pass. The band absorbs
#: both runner timing noise and the committed baseline having been
#: produced on a different machine than CI; the regressions the ratchet
#: exists to catch (falling back to a scalar path, losing arena reuse,
#: re-introducing a tobytes copy chain) are multi-x cliffs.
TOLERANCE = 0.6


def compare(fresh: dict, baseline: dict, keys=RATCHET_KEYS,
            tolerance: float = TOLERANCE):
    """Returns (failures, improvements, skipped): lists of
    (key, baseline, fresh) — ``skipped`` holds (key, baseline) pairs
    present in the baseline but absent from the fresh run (e.g. a
    device-lane throughput ratcheted on an accelerator machine while CI
    runs CPU-only): warn-and-skip, not a regression."""
    failures, improvements, skipped = [], [], []
    for key in keys:
        base = baseline.get(key)
        val = fresh.get(key)
        if base is None:
            continue                    # new key: nothing to ratchet yet
        if val is None:
            skipped.append((key, base))
        elif val < tolerance * base:
            failures.append((key, base, val))
        elif val > base:
            improvements.append((key, base, val))
    return failures, improvements, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", help="freshly-written BENCH_micro.json")
    ap.add_argument("--baseline", default="BENCH_micro.json",
                    help="committed baseline to ratchet against")
    ap.add_argument("--tolerance", type=float, default=TOLERANCE,
                    help="fresh must reach this fraction of baseline "
                         f"(default {TOLERANCE})")
    ap.add_argument("--update", action="store_true",
                    help="raise the baseline file to any improved values")
    args = ap.parse_args(argv)

    with open(args.fresh) as f:
        fresh = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures, improvements, skipped = compare(fresh, baseline,
                                              tolerance=args.tolerance)
    for key, base in skipped:
        print(f"ratchet: WARNING {key} in baseline ({base:.3f}) but "
              f"absent from the fresh run — skipped (lane did not run "
              f"in this environment)")
    for key, base, val in improvements:
        print(f"ratchet: {key} improved {base:.3f} -> {val:.3f}")
    if improvements and args.update:
        for key, _, val in improvements:
            baseline[key] = val
        with open(args.baseline, "w") as f:
            json.dump(baseline, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"ratchet: baseline {args.baseline} raised")
    for key, base, val in failures:
        print(f"ratchet: REGRESSION {key}: {val:.3f} < "
              f"{args.tolerance:.2f} x baseline {base:.3f}")
    if not failures:
        enforced = [k for k in RATCHET_KEYS
                    if baseline.get(k) is not None
                    and fresh.get(k) is not None]
        print("ratchet: ok "
              + " ".join(f"{k}={fresh[k]:.3f}"
                         f"(>= {args.tolerance:.2f}x{baseline[k]:.3f})"
                         for k in enforced))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
