"""Shuffle-strategy head-to-head on one Zipf-skewed open workload.

Runs the identical skewed workload (same seed, same arrivals, same key
stream) through the async engine once per registered strategy against a
zonal ``ExpressOneZoneStore`` and compares what each policy actually
moves through the object store:

  * **default** — producer-AZ placement, one notification + ranged GET
    per small blob. The baseline every ratio below is against.
  * **combining** — map-side pre-aggregation (last-wins per key, the
    KTable upsert combiner) inside each ingest micro-batch; under Zipf
    skew the hot keys collapse and shipped logical bytes drop.
  * **push** — destination-AZ-local placement: blobs are homed + cache
    -filled where their consumer runs, so zonal reads replace every
    cross-AZ GET; the producer's cross-AZ routing bytes are priced in.
  * **merge** — two-round push-merge: a virtual-clock compactor
    coalesces ``fan_in`` small per-batcher blobs into one merged
    per-partition blob, dividing notification and GET request counts.

Correctness is asserted inline, not sampled: push and merge must
deliver record-for-record bit-identically to the default run; the
combining run must deliver exactly the reference combine of the same
input micro-batches (recomputed independently here); every run must be
duplicate-free (exactly-once).

Writes ``BENCH_strategies.json`` with per-strategy shipped bytes,
request counts, cross-AZ GETs, $/logical-GiB, and p95 — plus the CI
gate fields (combining shipped-bytes ratio, push cross-AZ GETs, merge
GET ratio).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Tuple

from repro.core import (ExpressOneZoneStore, SimConfig, WorkloadConfig,
                        default_partitioner_batch, generate_batch,
                        simulate_async)
from repro.core.strategy import CombiningStrategy

Row = Tuple[str, float, str]
GiB = 1024 ** 3

STRATEGY_NAMES = ("default", "combining", "push", "merge")

#: one skewed open workload shared by every strategy run: 6 instances
#: across 3 AZs, 18 partitions, Zipf(1.2) keys over 10k distinct —
#: skewed enough that hot keys dominate (combining's target) while the
#: tail keeps every partition busy (merge's small-blob fan-in target)
CFG = SimConfig(n_nodes=3, inst_per_node=2, n_az=3, duration_s=3.0,
                commit_interval_s=0.5, seed=13)
KEY_SKEW = 1.2
SCALE = 0.002
BATCH_RECORDS = 256


def _sim_args(quick: bool) -> Tuple[SimConfig, float]:
    if quick:
        return dataclasses.replace(CFG, duration_s=1.5), SCALE
    return CFG, SCALE


def _workload(cfg: SimConfig, scale: float) -> WorkloadConfig:
    # must mirror simulate_async's WorkloadConfig construction exactly:
    # the reference combine below replays the same byte stream
    return WorkloadConfig(
        arrival_rate=cfg.offered_gib_s * GiB * scale / cfg.record_bytes,
        duration_s=min(cfg.duration_s, 10.0),
        record_bytes=cfg.record_bytes, key_skew=KEY_SKEW, seed=cfg.seed)


def _multiset(eng) -> Dict[int, list]:
    return {p: sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                      for r in rs)
            for p, rs in eng.out.items() if rs}


def _reference_combine(cfg: SimConfig, scale: float) -> Dict[int, list]:
    """Independently recompute what a correct combining run must
    deliver: the same micro-batch slices ``drive`` hands the engine,
    combined per batch by the declared combiner, partitioned by the
    vectorized default partitioner."""
    combiner = CombiningStrategy().combiner
    _, batch = generate_batch(_workload(cfg, scale))
    out: Dict[int, list] = {}
    for s in range(0, len(batch), BATCH_RECORDS):
        part_batch = batch.slice_rows(s, min(s + BATCH_RECORDS, len(batch)))
        combined, _ = combiner.combine(part_batch)
        if combined is None:
            combined = part_batch
        parts = default_partitioner_batch(combined, cfg.partitions)
        for i in range(len(combined)):
            out.setdefault(int(parts[i]), []).append(
                (combined.key(i), combined.value(i),
                 int(combined.timestamps[i])))
    return {p: sorted(v) for p, v in out.items()}


def _run_strategy(name: str, cfg: SimConfig, scale: float):
    store = ExpressOneZoneStore(seed=cfg.seed, num_az=cfg.n_az)
    eng, summary = simulate_async(
        cfg, scale=scale, exactly_once=True, key_skew=KEY_SKEW,
        store=store, ingest_batch_records=BATCH_RECORDS, strategy=name)
    return eng, store, summary


#: written into the JSON under "_doc" (see docs/benchmarks.md)
FIELD_DOCS = {
    "quick": "true when the run used the --quick smoke geometry",
    "key_skew": "Zipf exponent of the workload's key distribution",
    "batch_records": "records per submitted RecordBatch",
    "strategies": "per-strategy raw metrics: delivered/duplicate counts, "
                  "shipped bytes, puts/gets (cross-AZ split), "
                  "notifications, merge stats, cost, p50/p95 latency, "
                  "makespan, plus ratios vs the default strategy",
    "payload_bit_identical": "GATE: push and merge deliver the same "
                             "multiset as the default strategy",
    "combining_matches_reference": "GATE: map-side combining delivery == "
                                   "reference combine of the same batches",
    "combining_delivery_count_ok": "default delivered - records combined "
                                   "== combining delivered",
    "exactly_once_ok": "GATE: zero duplicate deliveries in every strategy",
    "combining_shipped_ratio": "GATE(<1): combining shipped bytes / "
                               "default shipped bytes",
    "push_cross_az_gets": "GATE(=0): cross-AZ GETs under push-based "
                          "AZ-local placement",
    "merge_get_ratio": "GATE(>=3x): default GETs / two-round-merge GETs",
}


def run(quick: bool = False) -> List[Row]:
    cfg, scale = _sim_args(quick)
    rows: List[Row] = []
    results: Dict[str, dict] = {}
    engines: Dict[str, object] = {}

    for name in STRATEGY_NAMES:
        eng, store, summary = _run_strategy(name, cfg, scale)
        st, ss, m = store.stats, eng.strategy.stats, eng.metrics
        # $: the store bill (requests + bytes + cross-AZ GET routing +
        # retention storage) plus the push placement's cross-AZ PUT
        # routing, which the zonal store cannot see (it only knows the
        # placement AZ) — priced at the same cross-AZ $/GB
        cost = (st.cost_usd(store.costs, store.retention_s)
                + ss.push_cross_az_bytes / 1e9 * store.costs.cross_az_per_gb)
        results[name] = {
            "records_delivered": m.records_delivered,
            "duplicates_delivered": m.duplicates_delivered,
            "records_combined": ss.records_combined,
            "shipped_bytes": st.put_bytes,
            "puts": st.puts,
            "gets": st.gets,
            "cross_az_gets": st.cross_az_gets,
            "push_cross_az_bytes": ss.push_cross_az_bytes,
            "notifications": len(eng.published),
            "merged_blobs": ss.merged_blobs,
            "merged_inputs": ss.merged_inputs,
            "merge_fallback_notes": ss.merge_fallback_notes,
            "cost_usd": cost,
            "p50_s": m.latency_p(50),
            "p95_s": m.latency_p(95),
            "makespan_s": m.makespan_s,
        }
        engines[name] = eng

    base = results["default"]
    logical_gib = base["shipped_bytes"] / GiB   # pre-policy byte volume
    for name, r in results.items():
        r["shipped_ratio_vs_default"] = (r["shipped_bytes"]
                                         / base["shipped_bytes"])
        r["get_ratio_vs_default"] = base["gets"] / max(r["gets"], 1)
        r["cost_per_logical_gib"] = r["cost_usd"] / logical_gib
        rows.append((f"strategies.{name}", r["p95_s"] * 1e6,
                     f"shipped={r['shipped_bytes']} "
                     f"ratio={r['shipped_ratio_vs_default']:.3f} "
                     f"gets={r['gets']} xaz={r['cross_az_gets']} "
                     f"$|GiB={r['cost_per_logical_gib']:.3f}"))

    # -- correctness gates (asserted here, re-checked by CI) --------------
    m_default = _multiset(engines["default"])
    bit_identical = all(_multiset(engines[n]) == m_default
                        for n in ("push", "merge"))
    combine_ok = (_multiset(engines["combining"])
                  == _reference_combine(cfg, scale))
    exactly_once = all(r["duplicates_delivered"] == 0
                       for r in results.values())
    delivered_ok = (
        base["records_delivered"] - results["combining"]["records_combined"]
        == results["combining"]["records_delivered"])

    out = {
        "quick": quick,
        "key_skew": KEY_SKEW,
        "batch_records": BATCH_RECORDS,
        "strategies": results,
        "payload_bit_identical": bit_identical,
        "combining_matches_reference": combine_ok,
        "combining_delivery_count_ok": delivered_ok,
        "exactly_once_ok": exactly_once,
        # headline gates (see ISSUE 8 acceptance + CI)
        "combining_shipped_ratio": results["combining"][
            "shipped_ratio_vs_default"],
        "push_cross_az_gets": results["push"]["cross_az_gets"],
        "merge_get_ratio": results["merge"]["get_ratio_vs_default"],
    }
    out["_doc"] = {k: FIELD_DOCS[k] for k in out if k in FIELD_DOCS}
    with open("BENCH_strategies.json", "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")

    rows.append(("strategies.gates", 0.0,
                 f"bit_identical={bit_identical} combine_ok={combine_ok} "
                 f"exactly_once={exactly_once} "
                 f"ship_ratio={out['combining_shipped_ratio']:.3f} "
                 f"push_xaz_gets={out['push_cross_az_gets']} "
                 f"merge_get_ratio={out['merge_get_ratio']:.1f}"))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
