"""Async-engine sweeps: the paper's Fig.-style latency-vs-batch-interval
and cost-vs-throughput curves, plus the overlap (makespan) comparison,
measured on the event-driven engine under a ShuffleBench-style open
workload. Rows follow the harness CSV contract (name, us, derived)."""

from __future__ import annotations

import time
from typing import List, Optional, Tuple

from repro.core import (AsyncShuffleEngine, BlobShuffleConfig, EngineConfig,
                        WorkloadConfig, drive)

Row = Tuple[str, float, str]


def _run(cfg: BlobShuffleConfig, ecfg: EngineConfig, wl: WorkloadConfig,
         n_instances: int = 6):
    eng = AsyncShuffleEngine(cfg, ecfg, n_instances=n_instances,
                             exactly_once=False, seed=wl.seed)
    drive(eng, wl)
    metrics = eng.run()
    return eng, metrics, metrics.summary(eng.store)


def latency_vs_batch_interval(intervals=(0.1, 0.25, 0.5, 1.0),
                              rate: float = 4000.0) -> List[Row]:
    """Shuffle latency percentiles + $/GiB as the max batching interval
    sweeps (paper Fig. 6a/6d analogue, measured not modeled)."""
    rows: List[Row] = []
    for iv in intervals:
        cfg = BlobShuffleConfig(batch_bytes=8 << 20, max_interval_s=iv,
                                num_partitions=9, num_az=3)
        wl = WorkloadConfig(arrival_rate=rate, duration_s=3.0,
                            record_bytes=1024, key_skew=0.5, seed=7)
        t0 = time.perf_counter()
        _, m, s = _run(cfg, EngineConfig(), wl)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append((f"async.latency.interval={iv}", wall,
                     f"p50={s['p50_s']:.3f}s p95={s['p95_s']:.3f}s "
                     f"p99={s['p99_s']:.3f}s cost=${s['cost_per_gib']:.4f}/GiB "
                     f"n={m.records_delivered}"))
    return rows


def cost_vs_throughput(rates=(1000.0, 4000.0, 16000.0)) -> List[Row]:
    """$/GiB and achieved latency as offered load sweeps (Fig. 7
    analogue): request costs amortize as batches fill before the interval
    expires."""
    rows: List[Row] = []
    for rate in rates:
        cfg = BlobShuffleConfig(batch_bytes=4 << 20, max_interval_s=0.5,
                                num_partitions=9, num_az=3)
        wl = WorkloadConfig(arrival_rate=rate, duration_s=3.0,
                            record_bytes=1024, key_skew=0.5, seed=7)
        t0 = time.perf_counter()
        _, m, s = _run(cfg, EngineConfig(), wl)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append((f"async.cost.rate={rate:g}rec_s", wall,
                     f"tput={s['throughput_bytes_s'] / 2**20:.2f}MiB/s "
                     f"p95={s['p95_s']:.3f}s "
                     f"cost=${s['cost_per_gib']:.4f}/GiB"))
    return rows


def overlap_makespan(parallelism=(1, 4, 8)) -> List[Row]:
    """Fixed workload, sweep in-flight I/O: with upload parallelism >= 4
    the makespan must come out below the single-in-flight configuration
    of the same engine (the acceptance gate for the async refactor)."""
    cfg = BlobShuffleConfig(batch_bytes=256 * 1024, max_interval_s=0.5,
                            num_partitions=9, num_az=3)
    wl = WorkloadConfig(arrival_rate=4000, duration_s=3.0,
                        record_bytes=1024, key_skew=0.5, seed=1)
    rows: List[Row] = []
    base: Optional[float] = None
    for par in parallelism:
        ecfg = EngineConfig(upload_parallelism=par,
                            fetch_parallelism=max(par, 1))
        t0 = time.perf_counter()
        _, m, s = _run(cfg, ecfg, wl)
        wall = (time.perf_counter() - t0) * 1e6
        if par == 1:
            base = s["makespan_s"]
        speedup = base / s["makespan_s"] if base else float("nan")
        rows.append((f"async.overlap.parallelism={par}", wall,
                     f"makespan={s['makespan_s']:.3f}s "
                     f"p50={s['p50_s']:.3f}s speedup={speedup:.2f}x"))
    return rows


def run() -> List[Row]:
    return (latency_vs_batch_interval() + cost_vs_throughput()
            + overlap_makespan())


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
