"""Emit the EXPERIMENTS.md roofline tables from results/dryrun JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_table [--mesh single]
"""

from __future__ import annotations

import argparse
import json
import os

from repro.configs import ARCH_IDS, all_skips


def load(out_dir, mesh):
    d = os.path.join(out_dir, mesh)
    cells = {}
    if not os.path.isdir(d):
        return cells
    for name in sorted(os.listdir(d)):
        if "__" not in name or name.count("__") > 1:
            continue  # skip tagged perf-iteration runs
        with open(os.path.join(d, name)) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"])] = r
    return cells


SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    cells = load(args.out, args.mesh)
    skips = {(a, s): why for a, s, why in all_skips()}

    print("| arch | shape | dominant | compute s | memory s | collective s"
          " | step s | useful | roofline frac | peak GiB | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_IDS:
        for shape in SHAPES:
            if (arch, shape) in skips:
                why = skips[(arch, shape)]
                print(f"| {arch} | {shape} | — | — | — | — | — | — | — | — |"
                      f" SKIP: {why.split(';')[0][:40]} |")
                continue
            r = cells.get((arch, shape))
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | | | | | |")
                continue
            rl = r["roofline"]
            peak = r["memory"]["peak_est_bytes"] / 2**30
            fits = "yes" if peak <= 16.0 else f"NO ({peak:.0f}G)"
            print(f"| {arch} | {shape} | {rl['dominant'][:-2]} "
                  f"| {rl['compute_s']:.3f} | {rl['memory_s']:.3f} "
                  f"| {rl['collective_s']:.3f} | {rl['step_time_s']:.3f} "
                  f"| {rl['useful_flops_ratio']:.2f} "
                  f"| {rl['roofline_fraction']:.3f} | {peak:.1f} | {fits} |")


if __name__ == "__main__":
    main()
