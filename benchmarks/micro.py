"""Data-plane microbenchmarks: legacy per-record vs columnar hot path.

Measures, in one run (so the comparison is apples-to-apples):

  * **ingest** — records/s through ``Batcher.process`` (per-``Record``
    loop: scalar FNV-1a, per-record serialize, dict churn) vs
    ``Batcher.ingest`` (vectorized FNV-1a over the key arena, one
    argsort, one serialized chunk per destination partition), and
    asserts the finalized blob payloads are **bit-identical**;
  * **pack** — blobs/s through the fused single-pass pack op
    (sort/rank + gather in one jitted pass, jnp path on CPU);
  * **debatch** — bytes/s extracting partitions from a blob payload,
    legacy ``extract`` (per-``Record``) vs columnar ``extract_batch``
    (memoryview slice + vectorized arena gather);
  * **format** — columnar-v2 encode/decode GB/s on the same Zipf blob,
    the compressed ratio, and $/logical-GiB per storage tier with and
    without compression (request charges fixed, byte charges scaled);
  * **compress-pack** — blobs/s through the fused compress+pack op
    (gather + int8 quantize in one pass) next to the uncompressed pack.

Writes ``BENCH_micro.json`` so CI can track the perf trajectory, and
returns ``(name, us_per_call, derived)`` rows for ``benchmarks.run``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.blob import extract, extract_batch
from repro.core.cache import DistributedCache
from repro.core.recordbatch import default_partitioner_batch
from repro.core.records import default_partitioner
from repro.core.stores import SimulatedS3
from repro.core.workload import WorkloadConfig, generate_batch

Row = Tuple[str, float, str]

N_RECORDS = 50_000
RECORD_BYTES = 256
NUM_PARTITIONS = 64


def _make_batcher(name: str):
    """Single-AZ batcher with an infinite batch size: exactly one blob per
    flush, captured by the uploader hook (no store writes on the clock)."""
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    blobs = []
    b = Batcher(
        BlobShuffleConfig(batch_bytes=1 << 62, num_partitions=NUM_PARTITIONS,
                          num_az=1),
        lambda p: 0,
        lambda k: default_partitioner(k, NUM_PARTITIONS),
        cache,
        uploader=lambda blob, notes, counts, now: blobs.append((blob, notes)),
        name=name,
        partitioner_batch=lambda bt: default_partitioner_batch(
            bt, NUM_PARTITIONS))
    return b, blobs


def _best_of(f, iters: int = 3) -> float:
    """Best-of-N wall time (fresh state per iteration, first run warms
    pages/caches) — robust against transient machine load in CI."""
    return min(f() for _ in range(iters))


def bench_ingest() -> Tuple[List[Row], dict]:
    wl = WorkloadConfig(arrival_rate=N_RECORDS, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=7)
    _, batch = generate_batch(wl)
    records = batch.to_records()
    n = len(records)

    def run_legacy() -> float:
        legacy, blobs = _make_batcher("m")
        t0 = time.perf_counter()
        for r in records:
            legacy.process(r, 0.0)
        dt = time.perf_counter() - t0
        legacy.flush_all(0.0)
        run_legacy.blobs = blobs
        return dt

    def run_columnar() -> float:
        columnar, blobs = _make_batcher("m")
        batch.partitions = None        # don't amortize across iterations
        t0 = time.perf_counter()
        columnar.ingest(batch, 0.0)
        dt = time.perf_counter() - t0
        columnar.flush_all(0.0)
        run_columnar.blobs = blobs
        return dt

    legacy_s = _best_of(run_legacy)
    col_s = _best_of(run_columnar)
    legacy_blobs, col_blobs = run_legacy.blobs, run_columnar.blobs

    assert len(legacy_blobs) == len(col_blobs) == 1
    bit_identical = (legacy_blobs[0][0].payload == col_blobs[0][0].payload
                     and legacy_blobs[0][1] == col_blobs[0][1])
    assert bit_identical, "legacy vs columnar blob payloads diverged"

    legacy_rps = n / legacy_s
    col_rps = n / col_s
    rows = [
        ("micro.ingest_legacy", legacy_s / n * 1e6,
         f"{legacy_rps:,.0f}rec/s"),
        ("micro.ingest_columnar", col_s / n * 1e6,
         f"{col_rps:,.0f}rec/s speedup={col_rps / legacy_rps:.1f}x"),
    ]
    data = {
        "records": n,
        "records_s_ingest_legacy": legacy_rps,
        "records_s_ingest_columnar": col_rps,
        "ingest_speedup": col_rps / legacy_rps,
        "payload_bit_identical": bool(bit_identical),
    }
    return rows, data


def bench_pack() -> Tuple[List[Row], dict]:
    import jax
    from repro.kernels.blob_codec.ops import compress_pack_fused
    from repro.kernels.blob_pack.ops import blob_pack_fused

    T, d, bins, cap = 16384, 512, 64, 512
    x = jax.random.normal(jax.random.key(2), (T, d), jax.numpy.bfloat16)
    keys = jax.random.randint(jax.random.key(3), (T,), 0, bins)

    def timed(fn):
        jax.block_until_ready(fn(x, keys))      # compile
        iters = 5
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(x, keys)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    f_pack = jax.jit(lambda x, k: blob_pack_fused(
        x, k, num_bins=bins, capacity=cap, use_pallas=False)[0])
    f_codec = jax.jit(lambda x, k: compress_pack_fused(
        x, k, num_bins=bins, capacity=cap, use_pallas=False)[0])
    per_call = timed(f_pack)
    per_call_v2 = timed(f_codec)
    blobs_s = bins / per_call
    gbps = T * d * 2 / per_call / 1e9
    gbps_v2 = T * d * 2 / per_call_v2 / 1e9
    # int8 codes + f32 scale per row vs bf16 rows
    out_ratio = (cap * d + cap * 4) / (cap * d * 2)
    rows = [
        ("micro.blob_pack_fused", per_call * 1e6,
         f"{blobs_s:,.0f}blobs/s {gbps:.1f}GB/s (jnp path)"),
        ("micro.compress_pack_fused", per_call_v2 * 1e6,
         f"{bins / per_call_v2:,.0f}blobs/s {gbps_v2:.1f}GB/s "
         f"out_bytes={out_ratio:.2f}x (jnp path)"),
    ]
    return rows, {"blobs_s_pack": blobs_s, "pack_gb_s": gbps,
                  "pack_gb_s_v2": gbps_v2,
                  "pack_v2_out_bytes_ratio": out_ratio}


def bench_debatch() -> Tuple[List[Row], dict]:
    wl = WorkloadConfig(arrival_rate=N_RECORDS, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=11)
    _, batch = generate_batch(wl)
    b, blobs = _make_batcher("d")
    b.ingest(batch, 0.0)
    b.flush_all(0.0)
    blob, notes = blobs[0]
    total = blob.size
    counted = {}

    def run_legacy() -> float:
        t0 = time.perf_counter()
        counted["legacy"] = sum(
            len(extract(blob.payload, nt.byte_range)) for nt in notes)
        return time.perf_counter() - t0

    def run_columnar() -> float:
        t0 = time.perf_counter()
        counted["columnar"] = sum(
            len(extract_batch(blob.payload, nt.byte_range)) for nt in notes)
        return time.perf_counter() - t0

    legacy_s = _best_of(run_legacy)
    col_s = _best_of(run_columnar)
    assert counted["legacy"] == counted["columnar"] == len(batch)

    rows = [
        ("micro.debatch_legacy", legacy_s * 1e6,
         f"{total / legacy_s / 1e6:,.0f}MB/s"),
        ("micro.debatch_columnar", col_s * 1e6,
         f"{total / col_s / 1e6:,.0f}MB/s speedup={legacy_s / col_s:.1f}x"),
    ]
    data = {
        "bytes_s_debatch_legacy": total / legacy_s,
        "bytes_s_debatch": total / col_s,
    }
    return rows, data


def bench_format() -> Tuple[List[Row], dict]:
    """Columnar-v2 encode/decode throughput + $/logical-GiB with and
    without compression, on the same Zipf-skewed blob the other
    microbenchmarks use."""
    from repro.core.costs import TIERS, shuffle_cost_per_logical_gib
    from repro.core.formats import COLUMNAR_V2, detect_format

    wl = WorkloadConfig(arrival_rate=N_RECORDS, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=7)
    _, batch = generate_batch(wl)
    wire = bytes(batch.serialize_rows())

    def run_encode() -> float:
        t0 = time.perf_counter()
        run_encode.out = COLUMNAR_V2.encode_block([wire])
        return time.perf_counter() - t0

    enc_s = _best_of(run_encode)
    block = run_encode.out[0]
    ratio = len(block) / len(wire)
    assert detect_format(block) is COLUMNAR_V2

    def run_decode() -> float:
        t0 = time.perf_counter()
        run_decode.out = COLUMNAR_V2.decode_block(block)
        return time.perf_counter() - t0

    dec_s = _best_of(run_decode)
    assert run_decode.out == wire, "v2 round-trip diverged"

    data = {
        "v2_encode_gb_s": len(wire) / enc_s / 1e9,
        "v2_decode_gb_s": len(wire) / dec_s / 1e9,
        "v2_compressed_ratio": ratio,
    }
    for tier in ("standard", "express-one-zone"):
        prices = TIERS[tier]
        raw = shuffle_cost_per_logical_gib(prices)
        v2 = shuffle_cost_per_logical_gib(prices, compressed_ratio=ratio)
        data[f"cost_per_gib_raw_{tier}"] = raw
        data[f"cost_per_gib_v2_{tier}"] = v2
    rows = [
        ("micro.format_v2_encode", enc_s * 1e6,
         f"{data['v2_encode_gb_s']:.2f}GB/s ratio={ratio:.3f}"),
        ("micro.format_v2_decode", dec_s * 1e6,
         f"{data['v2_decode_gb_s']:.2f}GB/s"),
        ("micro.format_v2_cost", 0.0,
         " ".join(f"{t}=${data[f'cost_per_gib_v2_{t}']:.4f}"
                  f"(raw ${data[f'cost_per_gib_raw_{t}']:.4f})/GiB"
                  for t in ("standard", "express-one-zone"))),
    ]
    return rows, data


def run(json_path: str = "BENCH_micro.json") -> List[Row]:
    rows: List[Row] = []
    data = {}
    for bench in (bench_ingest, bench_pack, bench_debatch, bench_format):
        r, d = bench()
        rows.extend(r)
        data.update(d)
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
