"""Data-plane microbenchmarks: legacy per-record vs columnar hot path.

Measures, in one run (so the comparison is apples-to-apples):

  * **ingest** — records/s through ``Batcher.process`` (per-``Record``
    loop: scalar FNV-1a, per-record serialize, dict churn) vs
    ``Batcher.ingest`` (vectorized FNV-1a over the key arena, one
    argsort, one serialized chunk per destination partition), and
    asserts the finalized blob payloads are **bit-identical**;
  * **pack** — GB/s through the pack hot path. The headline lane is the
    host fast path (``blob_pack_fused_host`` / ``compress_pack_fused_
    host``: numpy sorted-order + block copies, arena-reused output) —
    the path a CPU deployment actually runs. The jitted XLA oracle lane
    is kept alongside for trajectory continuity with earlier runs;
  * **pack (device)** — real ``jax.jit`` Pallas timing with
    ``block_until_ready`` across the ``SWEEP_ROW_TILES`` tile
    geometries, **skipped gracefully off-accelerator** (interpret-mode
    Pallas timings are meaningless as throughput, so the lane only runs
    on tpu/gpu backends);
  * **debatch** — bytes/s extracting partitions from a blob payload,
    legacy ``extract`` (per-``Record``) vs columnar ``extract_batch``
    (memoryview slice + vectorized arena gather);
  * **format** — columnar-v2 encode/decode GB/s on the same Zipf blob,
    the compressed ratio, and $/logical-GiB per storage tier with and
    without compression (request charges fixed, byte charges scaled).

**Byte accounting:** every GB/s figure in BENCH_micro.json is over
**logical (pre-compression) bytes** — the serialized wire bytes for the
format lanes, rows × features × itemsize for the pack lanes — so raw
and compressed paths are directly comparable and a codec cannot "speed
up" by shrinking its own denominator.

Writes ``BENCH_micro.json`` (every field documented under its ``_doc``
key, so the CI gates are self-describing) and appends one JSON line per
run to ``BENCH_trajectory.jsonl`` so the throughput trajectory across
runs/commits is recoverable. ``quick=True`` shrinks record counts and
iteration counts for a sub-2-minute CI smoke lane; GB/s figures are
size-stable enough for the ratchet's tolerance band.

Returns ``(name, us_per_call, derived)`` rows for ``benchmarks.run``.
"""

from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np

from repro.core.batcher import Batcher, BlobShuffleConfig
from repro.core.blob import extract, extract_batch
from repro.core.cache import DistributedCache
from repro.core.recordbatch import default_partitioner_batch
from repro.core.records import default_partitioner
from repro.core.stores import SimulatedS3
from repro.core.workload import WorkloadConfig, generate_batch

Row = Tuple[str, float, str]

N_RECORDS = 50_000
N_RECORDS_QUICK = 10_000
RECORD_BYTES = 256
NUM_PARTITIONS = 64

#: pack-lane geometry: (rows, features, bins, capacity). Quick mode
#: keeps the full geometry on the host/jnp lanes (they are vectorized —
#: the full sweep costs single-digit seconds — and shrinking the arrays
#: shifts GB/s out of the ratchet's tolerance band); only the device
#: lane, where compile time dominates, uses the quick shape.
PACK_SHAPE = (16384, 512, 64, 512)
PACK_SHAPE_QUICK = (4096, 512, 64, 128)

#: every BENCH_micro.json field, documented where the numbers are made —
#: written into the JSON itself under "_doc" so the gates in CI (and the
#: ratchet baseline) are self-describing
FIELD_DOCS = {
    "records": "records per ingest iteration (quick mode uses fewer)",
    "quick": "true when the run used the --quick smoke geometry",
    "records_s_ingest_legacy":
        "records/s through Batcher.process (per-Record scalar loop)",
    "records_s_ingest_columnar":
        "records/s through Batcher.ingest (vectorized columnar path)",
    "ingest_speedup": "records_s_ingest_columnar / records_s_ingest_legacy",
    "payload_bit_identical":
        "legacy and columnar ingest produced byte-identical blob payloads "
        "and notifications (correctness gate, must stay true)",
    "blobs_s_pack": "blobs/s through the host pack fast path",
    "pack_gb_s":
        "GB/s of logical input bytes (rows*features*itemsize) through "
        "blob_pack_fused_host with a reused output arena — the CPU "
        "deployment pack path (RATCHETED)",
    "pack_gb_s_v2":
        "GB/s of logical input bytes through compress_pack_fused_host "
        "(quantize-before-gather + int8 gathers, reused arenas)",
    "pack_gb_s_jnp":
        "GB/s through the jitted XLA oracle pack (pre-PR-7 headline lane, "
        "kept for trajectory continuity)",
    "pack_gb_s_v2_jnp":
        "GB/s through the jitted XLA oracle compress+pack",
    "pack_v2_out_bytes_ratio":
        "compressed pack output bytes / raw pack output bytes "
        "(int8 codes + f32 scale vs bf16 rows)",
    "bytes_s_debatch_legacy": "payload bytes/s via per-Record extract",
    "bytes_s_debatch": "payload bytes/s via columnar extract_batch",
    "v2_encode_gb_s":
        "GB/s of logical wire bytes through ColumnarV2.encode_block "
        "(RATCHETED)",
    "v2_decode_gb_s":
        "GB/s of logical wire bytes recovered by ColumnarV2.decode_block",
    "v2_compressed_ratio": "encoded block bytes / logical wire bytes",
    "cost_per_gib_raw_standard":
        "$/logical-GiB shuffled, raw blobs on S3 Standard",
    "cost_per_gib_v2_standard": "same with columnar-v2 compression",
    "cost_per_gib_raw_express-one-zone":
        "$/logical-GiB shuffled, raw blobs on S3 Express One Zone",
    "cost_per_gib_v2_express-one-zone":
        "same with columnar-v2 compression",
    "device_lane":
        "why the device-mode kernel lane did not run (absent when it did)",
    "device_backend": "jax backend the device lane ran on (tpu/gpu)",
    "device_pack_row_tile_gb_s":
        "row_tile -> GB/s sweep of blob_pack_fused_pallas, compiled "
        "(interpret=False), block_until_ready timing",
    "device_best_row_tile": "argmax of device_pack_row_tile_gb_s",
    "device_pack_gb_s": "GB/s of the best row_tile config",
    "device_pack_v2_gb_s":
        "GB/s of compress_pack_fused_pallas at the best row_tile",
}


def _make_batcher(name: str):
    """Single-AZ batcher with an infinite batch size: exactly one blob per
    flush, captured by the uploader hook (no store writes on the clock)."""
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    blobs = []
    b = Batcher(
        BlobShuffleConfig(batch_bytes=1 << 62, num_partitions=NUM_PARTITIONS,
                          num_az=1),
        lambda p: 0,
        lambda k: default_partitioner(k, NUM_PARTITIONS),
        cache,
        uploader=lambda blob, notes, counts, now: blobs.append((blob, notes)),
        name=name,
        partitioner_batch=lambda bt: default_partitioner_batch(
            bt, NUM_PARTITIONS))
    return b, blobs


def _best_of(f, iters: int = 3) -> float:
    """Best-of-N wall time (fresh state per iteration, first run warms
    pages/caches) — robust against transient machine load in CI."""
    return min(f() for _ in range(iters))


def bench_ingest(quick: bool = False) -> Tuple[List[Row], dict]:
    n_records = N_RECORDS_QUICK if quick else N_RECORDS
    wl = WorkloadConfig(arrival_rate=n_records, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=7)
    _, batch = generate_batch(wl)
    records = batch.to_records()
    n = len(records)

    def run_legacy() -> float:
        legacy, blobs = _make_batcher("m")
        t0 = time.perf_counter()
        for r in records:
            legacy.process(r, 0.0)
        dt = time.perf_counter() - t0
        legacy.flush_all(0.0)
        run_legacy.blobs = blobs
        return dt

    def run_columnar() -> float:
        columnar, blobs = _make_batcher("m")
        batch.partitions = None        # don't amortize across iterations
        t0 = time.perf_counter()
        columnar.ingest(batch, 0.0)
        dt = time.perf_counter() - t0
        columnar.flush_all(0.0)
        run_columnar.blobs = blobs
        return dt

    iters = 2 if quick else 3
    legacy_s = _best_of(run_legacy, iters)
    col_s = _best_of(run_columnar, iters)
    legacy_blobs, col_blobs = run_legacy.blobs, run_columnar.blobs

    assert len(legacy_blobs) == len(col_blobs) == 1
    bit_identical = (
        bytes(legacy_blobs[0][0].payload) == bytes(col_blobs[0][0].payload)
        and legacy_blobs[0][1] == col_blobs[0][1])
    assert bit_identical, "legacy vs columnar blob payloads diverged"

    legacy_rps = n / legacy_s
    col_rps = n / col_s
    rows = [
        ("micro.ingest_legacy", legacy_s / n * 1e6,
         f"{legacy_rps:,.0f}rec/s"),
        ("micro.ingest_columnar", col_s / n * 1e6,
         f"{col_rps:,.0f}rec/s speedup={col_rps / legacy_rps:.1f}x"),
    ]
    data = {
        "records": n,
        "records_s_ingest_legacy": legacy_rps,
        "records_s_ingest_columnar": col_rps,
        "ingest_speedup": col_rps / legacy_rps,
        "payload_bit_identical": bool(bit_identical),
    }
    return rows, data


def _pack_inputs(quick: bool):
    import jax
    T, d, bins, cap = PACK_SHAPE_QUICK if quick else PACK_SHAPE
    x = jax.random.normal(jax.random.key(2), (T, d), jax.numpy.bfloat16)
    keys = jax.random.randint(jax.random.key(3), (T,), 0, bins)
    return T, d, bins, cap, x, keys


def bench_pack(quick: bool = False) -> Tuple[List[Row], dict]:
    import jax
    from repro.kernels.blob_codec.host import compress_pack_fused_host
    from repro.kernels.blob_codec.ops import compress_pack_fused
    from repro.kernels.blob_pack.host import blob_pack_fused_host
    from repro.kernels.blob_pack.ops import blob_pack_fused

    # full geometry even in quick mode: the lanes are vectorized, so the
    # run stays fast and the GB/s stay comparable to the full baseline
    T, d, bins, cap, x, keys = _pack_inputs(quick=False)
    logical = T * d * x.dtype.itemsize
    x_np = np.asarray(x)
    keys_np = np.asarray(keys)
    iters = 3 if quick else 5

    # best-of-N per-call times, like _best_of: a throughput-capability
    # number should not be dragged down by a transient load spike on a
    # shared runner mid-loop
    def timed(fn):
        jax.block_until_ready(fn())      # compile/warm
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best = min(best, time.perf_counter() - t0)
        return best

    def timed_host(fn):
        fn()                             # warm pages
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    # headline lane: host fast path with steady-state arena reuse
    arena = np.zeros((bins, cap, d), x_np.dtype)
    per_call = timed_host(lambda: blob_pack_fused_host(
        x_np, keys_np, num_bins=bins, capacity=cap, out=arena))
    q_arena = np.zeros((bins, cap, d), np.int8)
    s_arena = np.ones((bins, cap), np.float32)
    per_call_v2 = timed_host(lambda: compress_pack_fused_host(
        x_np, keys_np, num_bins=bins, capacity=cap,
        out=(q_arena, s_arena)))

    # jitted XLA oracle lane (the pre-PR-7 headline, kept for trajectory
    # continuity across BENCH_trajectory.jsonl)
    f_pack = jax.jit(lambda x, k: blob_pack_fused(
        x, k, num_bins=bins, capacity=cap, use_pallas=False)[0])
    f_codec = jax.jit(lambda x, k: compress_pack_fused(
        x, k, num_bins=bins, capacity=cap, use_pallas=False)[0])
    per_jnp = timed(lambda: f_pack(x, keys))
    per_jnp_v2 = timed(lambda: f_codec(x, keys))

    blobs_s = bins / per_call
    gbps = logical / per_call / 1e9
    gbps_v2 = logical / per_call_v2 / 1e9
    # int8 codes + f32 scale per row vs bf16 rows
    out_ratio = (cap * d + cap * 4) / (cap * d * 2)
    rows = [
        ("micro.blob_pack_host", per_call * 1e6,
         f"{blobs_s:,.0f}blobs/s {gbps:.2f}GB/s (host fast path)"),
        ("micro.compress_pack_host", per_call_v2 * 1e6,
         f"{bins / per_call_v2:,.0f}blobs/s {gbps_v2:.2f}GB/s "
         f"out_bytes={out_ratio:.2f}x (host fast path)"),
        ("micro.blob_pack_fused", per_jnp * 1e6,
         f"{logical / per_jnp / 1e9:.2f}GB/s (jnp path)"),
        ("micro.compress_pack_fused", per_jnp_v2 * 1e6,
         f"{logical / per_jnp_v2 / 1e9:.2f}GB/s (jnp path)"),
    ]
    return rows, {"blobs_s_pack": blobs_s, "pack_gb_s": gbps,
                  "pack_gb_s_v2": gbps_v2,
                  "pack_gb_s_jnp": logical / per_jnp / 1e9,
                  "pack_gb_s_v2_jnp": logical / per_jnp_v2 / 1e9,
                  "pack_v2_out_bytes_ratio": out_ratio}


def bench_pack_device(quick: bool = False) -> Tuple[List[Row], dict]:
    """Device-mode kernel lane: compiled (interpret=False) Pallas timing
    with ``block_until_ready`` across the row-tile sweep. Interpret-mode
    timings measure the Python emulator, not the kernel, so off
    accelerator the lane reports itself skipped instead of lying."""
    import jax

    backend = jax.default_backend()
    if backend not in ("tpu", "gpu"):
        return ([("micro.pack_device", 0.0,
                  f"skipped (backend={backend}; needs tpu/gpu)")],
                {"device_lane": f"skipped (backend={backend})"})

    from repro.kernels.blob_codec.kernel import compress_pack_fused_pallas
    from repro.kernels.blob_pack.kernel import (SWEEP_ROW_TILES,
                                                blob_pack_fused_pallas)
    from repro.shuffle.binning import sorted_order

    T, d, bins, cap, x, keys = _pack_inputs(quick)
    logical = T * d * x.dtype.itemsize
    order, starts, counts = jax.block_until_ready(sorted_order(keys, bins))
    iters = 3 if quick else 10

    def timed(fn):
        jax.block_until_ready(fn())      # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn()
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    sweep = {}
    for rt in SWEEP_ROW_TILES:
        per = timed(lambda: blob_pack_fused_pallas(
            x, order, starts, counts, capacity=cap, interpret=False,
            row_tile=rt))
        sweep[str(rt)] = logical / per / 1e9
    best = max(sweep, key=sweep.get)
    per_v2 = timed(lambda: compress_pack_fused_pallas(
        x, order, starts, counts, capacity=cap, interpret=False,
        row_tile=int(best)))
    v2_gbps = logical / per_v2 / 1e9
    rows = [
        ("micro.pack_device", logical / sweep[best] / 1e9 * 1e6,
         f"{sweep[best]:.2f}GB/s best row_tile={best} on {backend} " +
         " ".join(f"rt{t}={g:.2f}" for t, g in sweep.items())),
        ("micro.pack_device_v2", per_v2 * 1e6,
         f"{v2_gbps:.2f}GB/s fused compress+pack at row_tile={best}"),
    ]
    return rows, {"device_backend": backend,
                  "device_pack_row_tile_gb_s": sweep,
                  "device_best_row_tile": int(best),
                  "device_pack_gb_s": sweep[best],
                  "device_pack_v2_gb_s": v2_gbps}


def bench_debatch(quick: bool = False) -> Tuple[List[Row], dict]:
    n_records = N_RECORDS_QUICK if quick else N_RECORDS
    wl = WorkloadConfig(arrival_rate=n_records, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=11)
    _, batch = generate_batch(wl)
    b, blobs = _make_batcher("d")
    b.ingest(batch, 0.0)
    b.flush_all(0.0)
    blob, notes = blobs[0]
    total = blob.size
    counted = {}

    def run_legacy() -> float:
        t0 = time.perf_counter()
        counted["legacy"] = sum(
            len(extract(blob.payload, nt.byte_range)) for nt in notes)
        return time.perf_counter() - t0

    def run_columnar() -> float:
        t0 = time.perf_counter()
        counted["columnar"] = sum(
            len(extract_batch(blob.payload, nt.byte_range)) for nt in notes)
        return time.perf_counter() - t0

    iters = 2 if quick else 3
    legacy_s = _best_of(run_legacy, iters)
    col_s = _best_of(run_columnar, iters)
    assert counted["legacy"] == counted["columnar"] == len(batch)

    rows = [
        ("micro.debatch_legacy", legacy_s * 1e6,
         f"{total / legacy_s / 1e6:,.0f}MB/s"),
        ("micro.debatch_columnar", col_s * 1e6,
         f"{total / col_s / 1e6:,.0f}MB/s speedup={legacy_s / col_s:.1f}x"),
    ]
    data = {
        "bytes_s_debatch_legacy": total / legacy_s,
        "bytes_s_debatch": total / col_s,
    }
    return rows, data


def bench_format(quick: bool = False) -> Tuple[List[Row], dict]:
    """Columnar-v2 encode/decode throughput + $/logical-GiB with and
    without compression, on the same Zipf-skewed blob the other
    microbenchmarks use. GB/s figures are over the **logical wire
    bytes** in both directions (see module docstring). Quick mode keeps
    the full blob (encode/decode are vectorized and fast; a smaller blob
    would drift the ratcheted v2_encode_gb_s out of tolerance)."""
    from repro.core.costs import TIERS, shuffle_cost_per_logical_gib
    from repro.core.formats import COLUMNAR_V2, detect_format

    wl = WorkloadConfig(arrival_rate=N_RECORDS, duration_s=1.0,
                        record_bytes=RECORD_BYTES, key_skew=0.5, seed=7)
    _, batch = generate_batch(wl)
    wire = bytes(batch.serialize_rows())
    iters = 2 if quick else 3

    def run_encode() -> float:
        t0 = time.perf_counter()
        run_encode.out = COLUMNAR_V2.encode_block([wire])
        return time.perf_counter() - t0

    enc_s = _best_of(run_encode, iters)
    block = run_encode.out[0]
    ratio = len(block) / len(wire)
    assert detect_format(block) is COLUMNAR_V2

    def run_decode() -> float:
        t0 = time.perf_counter()
        run_decode.out = COLUMNAR_V2.decode_block(block)
        return time.perf_counter() - t0

    dec_s = _best_of(run_decode, iters)
    assert run_decode.out == wire, "v2 round-trip diverged"

    data = {
        "v2_encode_gb_s": len(wire) / enc_s / 1e9,
        "v2_decode_gb_s": len(wire) / dec_s / 1e9,
        "v2_compressed_ratio": ratio,
    }
    for tier in ("standard", "express-one-zone"):
        prices = TIERS[tier]
        raw = shuffle_cost_per_logical_gib(prices)
        v2 = shuffle_cost_per_logical_gib(prices, compressed_ratio=ratio)
        data[f"cost_per_gib_raw_{tier}"] = raw
        data[f"cost_per_gib_v2_{tier}"] = v2
    rows = [
        ("micro.format_v2_encode", enc_s * 1e6,
         f"{data['v2_encode_gb_s']:.2f}GB/s ratio={ratio:.3f}"),
        ("micro.format_v2_decode", dec_s * 1e6,
         f"{data['v2_decode_gb_s']:.2f}GB/s"),
        ("micro.format_v2_cost", 0.0,
         " ".join(f"{t}=${data[f'cost_per_gib_v2_{t}']:.4f}"
                  f"(raw ${data[f'cost_per_gib_raw_{t}']:.4f})/GiB"
                  for t in ("standard", "express-one-zone"))),
    ]
    return rows, data


def _append_trajectory(data: dict, path: str) -> None:
    """One JSON line per benchmark run: wall-clock timestamp + every
    numeric field. The file is append-only and git-ignored — CI uploads
    it as an artifact, locally it accumulates the machine's history (see
    README "how to read BENCH_trajectory.jsonl")."""
    rec = {"ts": time.time(), **{k: v for k, v in data.items()
                                 if not k.startswith("_")}}
    with open(path, "a") as f:
        json.dump(rec, f, sort_keys=True)
        f.write("\n")


def run(json_path: str = "BENCH_micro.json", quick: bool = False,
        trajectory_path: str = "BENCH_trajectory.jsonl") -> List[Row]:
    rows: List[Row] = []
    data: dict = {"quick": quick}
    for bench in (bench_ingest, bench_pack, bench_pack_device,
                  bench_debatch, bench_format):
        r, d = bench(quick=quick)
        rows.extend(r)
        data.update(d)
    data["_doc"] = {k: FIELD_DOCS[k] for k in data if k in FIELD_DOCS}
    with open(json_path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    _append_trajectory(data, trajectory_path)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
