"""Benchmark harness — one suite per paper table/figure + the TPU
adaptation and kernel microbenches. Prints ``name,us_per_call,derived``
CSV (and a dry-run roofline summary if results/dryrun exists)."""

from __future__ import annotations

import argparse
import json
import os


def _dryrun_summary(out_dir="results/dryrun"):
    rows = []
    for mesh in ("single", "multi"):
        d = os.path.join(out_dir, mesh)
        if not os.path.isdir(d):
            continue
        for name in sorted(os.listdir(d)):
            with open(os.path.join(d, name)) as f:
                r = json.load(f)
            rl = r["roofline"]
            rows.append((f"dryrun.{mesh}.{r['arch']}.{r['shape']}",
                         r["compile_s"] * 1e6,
                         f"dom={rl['dominant'][:-2]} "
                         f"step={rl['step_time_s']:.3f}s "
                         f"frac={rl['roofline_fraction']:.3f} "
                         f"mem={r['memory']['peak_est_bytes'] / 2**30:.1f}GiB"))
    return rows


SUITES = {
    "all": "every suite below",
    "paper": "paper figure/table reproductions (Figs. 5-9 + model)",
    "async": "async engine latency/cost sweeps",
    "tiers": "storage-tier sweep (S3 Standard / Express / faulty)",
    "micro": "data-plane microbenchmarks: ingest/pack/debatch/format "
             "host lanes + a device-mode Pallas kernel lane (compiled, "
             "block_until_ready; skipped off-accelerator). Writes "
             "BENCH_micro.json, appends BENCH_trajectory.jsonl",
    "elastic": "elasticity: rebalance, exactly-once handoff, autoscale "
               "(writes BENCH_elastic.json)",
    "strategies": "shuffle-strategy head-to-head on one Zipf-skewed "
                  "workload: default vs map-side combining vs push-based "
                  "AZ-local vs two-round merge (writes "
                  "BENCH_strategies.json)",
    "obs": "observability acceptance: per-strategy latency decomposition "
           "with bit-identity, conservation, reconciliation, sketch "
           "accuracy and <10% overhead gates (writes BENCH_obs.json + "
           "TRACE_obs.json)",
    "tpu": "TPU shuffle adaptation",
    "kernels": "Pallas kernel microbenchmarks",
    "train_input": "shuffle-fed MoE train loop: input GB/s + overlap, "
                   "resume-after-AZ-outage bit-identity, sharded "
                   "input-spec dryrun (writes BENCH_train_input.json)",
    "dryrun": "roofline summary of results/dryrun",
}


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
        epilog="suites:\n" + "\n".join(
            f"  {name:<8} {desc}" for name, desc in SUITES.items()))
    ap.add_argument("--suite", default="all", choices=sorted(SUITES),
                    metavar="SUITE",
                    help="one of: " + ", ".join(SUITES) + " (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="micro/strategies suites: shrunk record/iteration "
                         "counts for a sub-2-minute CI smoke lane (micro "
                         "GB/s figures stay within the ratchet tolerance "
                         "band; strategy gates still hold)")
    args = ap.parse_args()

    rows = []
    if args.suite in ("all", "train_input"):
        # first: its XLA_FLAGS (8 host devices for the pod/data/model
        # mesh) must be set before any other suite initializes jax
        from benchmarks import train_input
        rows += train_input.run(quick=args.quick)  # BENCH_train_input.json
    if args.suite in ("all", "micro"):
        from benchmarks import micro
        rows += micro.run(quick=args.quick)  # also writes BENCH_micro.json
    if args.suite in ("all", "async"):
        from benchmarks import async_engine
        rows += async_engine.run()
    if args.suite in ("all", "tiers"):
        from benchmarks import tier_sweep
        rows += tier_sweep.run()
    if args.suite in ("all", "elastic"):
        from benchmarks import elastic
        rows += elastic.run()  # also writes BENCH_elastic.json
    if args.suite in ("all", "strategies"):
        from benchmarks import strategies
        rows += strategies.run(quick=args.quick)  # BENCH_strategies.json
    if args.suite in ("all", "obs"):
        from benchmarks import obs_report
        rows += obs_report.run(quick=args.quick)  # BENCH_obs + TRACE_obs
    if args.suite in ("all", "paper"):
        from benchmarks import paper_figs as F
        rows += F.fig5_latency_cdf()
        rows += F.fig6_batch_size()
        rows += F.fig7_cost_latency()
        rows += F.fig8_partitions()
        rows += F.fig9_scalability()
        rows += F.model_validation()
    if args.suite in ("all", "tpu"):
        from benchmarks import tpu_shuffle
        rows += tpu_shuffle.run()
    if args.suite in ("all", "kernels"):
        from benchmarks import kernel_bench
        rows += kernel_bench.run()
    if args.suite in ("all", "dryrun"):
        rows += _dryrun_summary()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
