"""Kernel microbenchmarks: jnp-path timings + interpret-mode oracle checks.

On this CPU container the Pallas kernels run in interpret mode (Python),
so wall time is meaningful only for the jnp paths; the kernels are checked
allclose against their oracles here and timed per call for bookkeeping.
"""

from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

Row = Tuple[str, float, str]


def _time(f, *args, iters=5) -> float:
    f(*args)  # compile
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    # flash attention jnp (custom VJP) vs dense
    from repro.models.attention import dense_attention
    from repro.models.flash import flash_attention
    B, S, H, D = 2, 1024, 8, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    f_flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, causal=True))
    f_dense = jax.jit(lambda q, k, v: dense_attention(q, k, v, causal=True))
    us_f = _time(f_flash, q, k, v)
    us_d = _time(f_dense, q, k, v)
    err = float(jnp.max(jnp.abs(
        f_flash(q, k, v).astype(jnp.float32)
        - f_dense(q, k, v).astype(jnp.float32))))
    rows.append(("kernel.flash_jnp_1k", us_f,
                 f"dense={us_d:.0f}us maxerr={err:.3e}"))

    # ssd chunked vs reference
    from repro.models.ssm import ssd_chunked, ssd_reference
    b, S2, H2, P2, N2 = 1, 2048, 8, 64, 64
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (b, S2, H2, P2), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S2, H2)))
    A = -jnp.exp(jax.random.normal(ks[2], (H2,)) * 0.3)
    Bm = jax.random.normal(ks[3], (b, S2, 1, N2))
    Cm = jax.random.normal(ks[4], (b, S2, 1, N2))
    f_chunk = jax.jit(lambda *a: ssd_chunked(*a, chunk=128)[0])
    f_ref = jax.jit(lambda *a: ssd_reference(*a)[0])
    us_c = _time(f_chunk, x, dt, A, Bm, Cm)
    us_r = _time(f_ref, x, dt, A, Bm, Cm)
    rows.append(("kernel.ssd_chunked_2k", us_c,
                 f"naive_scan={us_r:.0f}us speedup={us_r / us_c:.1f}x"))

    # blob pack/unpack oracle paths
    from repro.kernels.blob_pack.ops import pack_from_keys
    T, d = 16384, 512
    xt = jax.random.normal(jax.random.key(2), (T, d), jnp.bfloat16)
    keys = jax.random.randint(jax.random.key(3), (T,), 0, 64)
    f_pack = jax.jit(lambda x, k: pack_from_keys(
        x, k, num_bins=64, capacity=512, use_pallas=False)[0])
    us_p = _time(f_pack, xt, keys)
    gbps = T * d * 2 / (us_p / 1e6) / 1e9
    rows.append(("kernel.blob_pack_16k", us_p, f"{gbps:.1f}GB/s (jnp path)"))

    # interpret-mode kernels (correctness-only timing)
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    q2 = q[:1, :256]
    k2 = k[:1, :256]
    v2 = v[:1, :256]
    t0 = time.perf_counter()
    out = flash_attention_pallas(q2, k2, v2, causal=True, interpret=True)
    us_i = (time.perf_counter() - t0) * 1e6
    ref = f_dense(q2, k2, v2)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - ref.astype(jnp.float32))))
    rows.append(("kernel.flash_pallas_interp", us_i,
                 f"maxerr={err:.3e} (interpret mode)"))
    return rows
