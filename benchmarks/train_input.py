"""Shuffle-fed training suite: the BlobShuffle engine as the input
pipeline for a real MoE train loop (ROADMAP item 5).

Three lanes, one scenario (run via ``python -m benchmarks.run --suite
train_input [--quick]``):

* **pipeline** — an uninterrupted shuffle-fed run: step-keyed records
  flow source → Batcher → blob → ExpressOneZone store → notification
  log (ElasticCluster) → Debatcher → ``ShuffleFedInput`` → sharded
  device batches → jitted ``make_train_step``; reports input GB/s,
  the step-time overlap fraction of the double buffer, and the loss
  trajectory (gate: decreasing).
* **resume** — the same engine factory with an **AZ outage** on the
  virtual clock (every worker in AZ 1 fail-stops; partitions reassign
  cross-AZ and uncommitted notifications replay) and a ``SimulatedCrash``
  mid-step after it; the resumed run restores the manifest from the
  tiered checkpoint store (``BlobCheckpointer`` over a
  ``FaultyStore``-wrapped ``SimulatedS3``), fast-forwards the replayed
  engine past the committed offsets, and must reproduce the
  uninterrupted run's loss trajectory **bit-identically** with zero
  skipped and zero re-trained batches (gates).
* **dryrun** — ``train_input.specs_check``: the sharded input specs of
  the shuffle-fed batch validate against ``launch.specs`` +
  ``distributed.sharding`` and lower through the real train step.

Writes ``BENCH_train_input.json`` (fields documented under ``_doc``).
"""

from __future__ import annotations

import os

# 8 fake host devices for the (pod=2, data=2, model=2) mesh; must be set
# before the first jax import (run.py imports this suite before any
# other so the flag wins even under --suite all)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import json                                                      # noqa: E402
from typing import List, Tuple                                   # noqa: E402

Row = Tuple[str, float, str]

#: written into the JSON under "_doc" so CI gates and docs/benchmarks.md
#: stay in sync with the producer
FIELD_DOCS = {
    "quick": "true when the run used the --quick smoke geometry",
    "arch": "model architecture (smoke-scaled) under training",
    "devices": "host device count backing the mesh",
    "mesh": "mesh axis sizes the batch is sharded over",
    "steps": "training steps per run",
    "ckpt_every": "checkpoint cadence (steps per manifest commit)",
    "crash_at_step": "step at which the interrupted run dies mid-step",
    "resume_step": "first step the resumed run re-trains (last manifest)",
    "az_outage_at_s": "virtual time when every worker in one AZ "
                      "fail-stops (partitions reassign cross-AZ, "
                      "uncommitted notifications replay)",
    "input_gb_s": "delivered input bytes / host seconds spent advancing "
                  "the engine (blocking wait + overlapped prefetch)",
    "overlap_fraction": "fraction of batches already staged when the "
                        "trainer asked — the double-buffer hit rate",
    "input_wait_s": "host seconds the train step actually blocked on "
                    "input (not absorbed by prefetch)",
    "step_time_s_mean": "mean wall seconds per train step (compute)",
    "records_delivered": "records the engine delivered (uninterrupted "
                         "run)",
    "records_replayed": "records replayed by commit-protocol recovery "
                        "across the AZ outage (interrupted+resumed runs)",
    "duplicate_rows_filtered": "replayed/duplicate (step,row) deliveries "
                               "the consumer filtered (exactly-once "
                               "consumption)",
    "loss_first": "loss at step 0",
    "loss_last": "loss at the final step",
    "loss_decreasing": "GATE: mean of last 3 losses < mean of first 3",
    "resume_loss_bit_identical": "GATE: committed-prefix + resumed losses "
                                 "equal the uninterrupted trajectory "
                                 "bit-for-bit",
    "batches_skipped": "GATE(=0): steps trained by neither the committed "
                       "prefix nor the resumed run",
    "batches_duplicated": "GATE(=0): steps trained more than once across "
                          "the committed timeline",
    "offsets_match_manifest": "GATE: per-partition offsets recomputed by "
                              "the resume replay equal the checkpoint "
                              "manifest's",
    "ckpt_retries": "StoreError retries absorbed by the tiered "
                    "checkpoint store (fault injection was live)",
    "dryrun_input_specs_ok": "GATE: sharded input specs validate and "
                             "lower through the real train step",
    "input_specs": "per-input global shape / PartitionSpec / per-device "
                   "shard shape from the dryrun lane",
}


def run(quick: bool = False) -> List[Row]:
    import jax
    import numpy as np

    from repro.cluster import ElasticCluster
    from repro.configs import get_config
    from repro.core import AsyncShuffleEngine, BlobShuffleConfig, \
        EngineConfig
    from repro.core.stores import ExpressOneZoneStore, FaultyStore, \
        SimulatedS3
    from repro.checkpoint import BlobCheckpointer, TieredCheckpointStore
    from repro.launch import make_test_mesh
    from repro.shuffle import ShuffleConfig
    from repro.train_input import (TokenStreamConfig, train_shuffle_fed,
                                   validate_device_batch, lower_train_step,
                                   input_spec_report)
    from repro.training import OptConfig, TrainConfig, make_train_step

    n_dev = jax.device_count()
    mesh = make_test_mesh(devices=8 if n_dev >= 8 else
                          (4 if n_dev >= 4 else n_dev))
    multi_pod = "pod" in mesh.axis_names
    arch = "deepseek-v2-lite-16b"
    cfg = get_config(arch, smoke=True)
    steps = 12 if quick else 16
    ckpt_every = 4
    crash_at = steps - 6           # mid-step crash after the outage
    # outage lands between two commit ticks (0.15s cadence) so a batch of
    # notifications is genuinely uncommitted and must replay cross-AZ
    outage_t = 0.30
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, batch=8,
                               seq_len=32, seed=0)

    shuf = ShuffleConfig(mode="blob" if multi_pod else "dense",
                         token_axes=("pod", "data", "model"),
                         expert_axes=("pod", "model"),
                         capacity_factor=2.0)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=3e-3, warmup_steps=5,
                                     total_steps=steps),
                       shuffle=shuf,
                       grad_sync="blob_int8" if multi_pod else "auto",
                       grad_sync_blob_bytes=1 << 16)
    step_fn = jax.jit(make_train_step(cfg, tcfg, mesh=mesh))

    def make_engine():
        """Fresh, deterministic engine: zonal express tier behind mild
        fault injection, elastic cluster with an AZ-1 outage mid-stream."""
        store = FaultyStore(ExpressOneZoneStore(seed=7, num_az=3),
                            seed=11, transient_p=0.02)
        bcfg = BlobShuffleConfig(batch_bytes=4096, max_interval_s=0.02,
                                 num_partitions=9, num_az=3)
        eng = AsyncShuffleEngine(bcfg, EngineConfig(commit_interval_s=0.15),
                                 n_instances=3, store=store, seed=5,
                                 exactly_once=True)
        cluster = ElasticCluster(eng, mode="cooperative")
        cluster.az_outage_at(outage_t, 1)
        return eng

    def make_ckpt(store):
        # sync uploads: a deterministic crash window for the resume gate
        return BlobCheckpointer(TieredCheckpointStore(store),
                                async_upload=False)

    common = dict(steps=steps, engine_factory=make_engine,
                  ckpt_every=ckpt_every, step_fn=step_fn,
                  pipeline_kwargs={"step_interval_s": 0.05,
                                   "prefetch_steps": 2})

    # -- lane 1: uninterrupted run -----------------------------------------
    base = train_shuffle_fed(cfg, tcfg, mesh, stream,
                             ckpt=make_ckpt(
                                 FaultyStore(SimulatedS3(seed=21), seed=23,
                                             transient_p=0.05)),
                             **common)
    st = base.input_stats
    host_s = st["host_wait_s"] + st["host_prefetch_s"]
    input_gb_s = (st["bytes_delivered"] / host_s / 1e9) if host_s else 0.0
    losses = base.losses
    loss_decreasing = (float(np.mean(losses[-3:]))
                       < float(np.mean(losses[:3])))

    # -- lane 2: crash mid-step after the AZ outage, then resume -----------
    ckpt_store = FaultyStore(SimulatedS3(seed=31), seed=33,
                             transient_p=0.05)
    ckpt = make_ckpt(ckpt_store)
    broken = train_shuffle_fed(cfg, tcfg, mesh, stream, ckpt=ckpt,
                               crash_at_step=crash_at, **common)
    assert broken.crashed
    resumed = train_shuffle_fed(cfg, tcfg, mesh, stream, ckpt=ckpt,
                                resume=True, **common)
    resume_step = resumed.start_step
    committed = broken.steps[:resume_step]        # steps the manifest covers
    timeline = committed + resumed.steps
    spliced = broken.losses[:resume_step] + resumed.losses
    bit_identical = (timeline == list(range(steps))
                     and spliced == losses)
    skipped = len(set(range(steps)) - set(timeline))
    duplicated = sum(n - 1 for n in
                     np.unique(timeline, return_counts=True)[1] if n > 1)

    # -- lane 3: dryrun input-spec validation ------------------------------
    # validate a real device batch from a fresh pipeline (base consumed its
    # stream); one step is enough
    from repro.train_input import ShuffleFedInput
    p3 = ShuffleFedInput(make_engine(), stream, steps=1, mesh=mesh,
                         model_cfg=cfg, step_interval_s=0.05)
    p3.submit()
    _, batch, _ = p3.next_batch()
    report = validate_device_batch(batch, cfg, p3.shape, mesh)
    lower_train_step(cfg, tcfg, mesh, p3.shape)
    dryrun_ok = report == input_spec_report(cfg, p3.shape, mesh)

    data = {
        "quick": quick,
        "arch": arch,
        "devices": n_dev,
        "mesh": dict(mesh.shape),
        "steps": steps,
        "ckpt_every": ckpt_every,
        "crash_at_step": crash_at,
        "resume_step": resume_step,
        "az_outage_at_s": outage_t,
        "input_gb_s": input_gb_s,
        "overlap_fraction": st["overlap_fraction"],
        "input_wait_s": st["host_wait_s"],
        "step_time_s_mean": st["step_time_s"] / max(len(base.steps), 1),
        "records_delivered": st["records_delivered"],
        "records_replayed": (broken.input_stats["records_replayed"]
                             + resumed.input_stats["records_replayed"]),
        "duplicate_rows_filtered": (
            st["duplicate_rows_filtered"]
            + broken.input_stats["duplicate_rows_filtered"]
            + resumed.input_stats["duplicate_rows_filtered"]),
        "loss_first": losses[0],
        "loss_last": losses[-1],
        "loss_decreasing": loss_decreasing,
        "resume_loss_bit_identical": bit_identical,
        "batches_skipped": skipped,
        "batches_duplicated": int(duplicated),
        "offsets_match_manifest": resumed.offsets_checked,
        "ckpt_retries": ckpt.store.retries,
        "dryrun_input_specs_ok": bool(dryrun_ok),
        "input_specs": report,
    }
    data["_doc"] = {k: FIELD_DOCS[k] for k in data if k in FIELD_DOCS}
    with open("BENCH_train_input.json", "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")

    rows: List[Row] = [
        ("train_input.pipeline", st["step_time_s"] * 1e6 / max(steps, 1),
         f"gb_s={input_gb_s:.3f} overlap={st['overlap_fraction']:.2f} "
         f"loss {losses[0]:.3f}->{losses[-1]:.3f} "
         f"decreasing={loss_decreasing}"),
        ("train_input.resume", 0.0,
         f"bit_identical={bit_identical} skipped={skipped} "
         f"dup={duplicated} resume_step={resume_step} "
         f"replayed={data['records_replayed']} "
         f"offsets_ok={resumed.offsets_checked}"),
        ("train_input.dryrun", 0.0,
         f"specs_ok={dryrun_ok} "
         f"tokens={report['tokens']['partition_spec']}"
         f"->{tuple(report['tokens']['per_device_shape'])}"),
    ]
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
