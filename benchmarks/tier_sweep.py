"""Storage-tier sweep: SimulatedS3 vs ExpressOneZone vs FaultyStore.

Runs the same open workload through ``simulate_async`` against each
storage backend and reports p50/p95/p99 record latency and $/GiB per
tier — the swappable-exchange-layer economics the BlobShuffle design
enables (paper §5.3/§6): S3 Standard is the cost floor, Express One
Zone buys latency with request/storage price, and a throttled Standard
tier shows the engine's retry + backoff lanes delivering every record
exactly-once under injected 503s, bit-reproducibly for a fixed seed.

Rows follow the harness CSV contract (name, us, derived).
"""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

from repro.core import (EngineConfig, ExpressOneZoneStore, FaultyStore,
                        SimConfig, SimulatedS3, simulate_async)
from repro.core.stores import BlobStore

Row = Tuple[str, float, str]

GiB = 1024 ** 3

CFG = SimConfig(n_nodes=3, inst_per_node=2, n_az=3, duration_s=3.0,
                commit_interval_s=1.0, seed=7)
SCALE = 0.002


def _standard(seed: int) -> BlobStore:
    return SimulatedS3(seed=seed)


def _express(seed: int) -> BlobStore:
    return ExpressOneZoneStore(seed=seed, num_az=CFG.n_az)


def _faulty_standard(seed: int) -> BlobStore:
    return FaultyStore(SimulatedS3(seed=seed), seed=seed,
                       throttle_rate=8.0, throttle_burst=4, prefix_len=2,
                       transient_p=0.05, timeout_p=0.01, timeout_s=1.5)


TIERS: List[Tuple[str, Callable[[int], BlobStore]]] = [
    ("standard", _standard),
    ("express-one-zone", _express),
    ("faulty-standard", _faulty_standard),
]


def _run_tier(make_store: Callable[[int], BlobStore]):
    eng, summary = simulate_async(
        CFG, scale=SCALE, exactly_once=True,
        engine_cfg=EngineConfig(commit_interval_s=CFG.commit_interval_s,
                                retention_sweep_s=1.0),
        store=make_store(CFG.seed))
    return eng, summary


def tier_sweep() -> List[Row]:
    rows: List[Row] = []
    for name, make_store in TIERS:
        t0 = time.perf_counter()
        eng, s = _run_tier(make_store)
        wall = (time.perf_counter() - t0) * 1e6
        m = eng.metrics
        complete = m.records_delivered == m.records_in
        rows.append((
            f"tiers.{name}", wall,
            f"p50={s['p50_s']:.3f}s p95={s['p95_s']:.3f}s "
            f"p99={s['p99_s']:.3f}s cost=${s['cost_per_gib']:.4f}/GiB "
            f"delivered={m.records_delivered}/{m.records_in} "
            f"dups={m.duplicates_delivered} retries="
            f"{m.put_retries + m.get_retries} throttled={m.throttle_events} "
            f"exactly_once_ok={complete and m.duplicates_delivered == 0}"))
    return rows


def reproducibility_check() -> List[Row]:
    """The degraded-store run (retries, backoff, throttling and all) must
    be bit-identical for a fixed seed — the determinism acceptance gate."""
    t0 = time.perf_counter()
    eng1, _ = _run_tier(_faulty_standard)
    eng2, _ = _run_tier(_faulty_standard)
    wall = (time.perf_counter() - t0) * 1e6
    m1, m2 = eng1.metrics, eng2.metrics
    same = (m1.record_latencies == m2.record_latencies
            and m1.makespan_s == m2.makespan_s
            and m1.put_retries == m2.put_retries
            and m1.get_retries == m2.get_retries)
    return [("tiers.reproducible", wall,
             f"bit_identical={same} retries={m1.put_retries + m1.get_retries} "
             f"records={m1.records_delivered}")]


def run() -> List[Row]:
    return tier_sweep() + reproducibility_check()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
