"""Storage-tier sweep: SimulatedS3 vs ExpressOneZone vs FaultyStore.

Runs the same open workload through ``simulate_async`` against each
storage backend and reports p50/p95/p99 record latency and $/GiB per
tier — the swappable-exchange-layer economics the BlobShuffle design
enables (paper §5.3/§6): S3 Standard is the cost floor, Express One
Zone buys latency with request/storage price, and a throttled Standard
tier shows the engine's retry + backoff lanes delivering every record
exactly-once under injected 503s, bit-reproducibly for a fixed seed.

The **compression lane** reruns the standard tier with
``wire_format="columnar-v2"``: same records delivered, shipped bytes cut
by the compressed ratio, $/GiB reported against *logical* (pre-encode)
bytes so the two lanes are directly comparable.

Rows follow the harness CSV contract (name, us, derived).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Tuple

from repro.core import (EngineConfig, ExpressOneZoneStore, FaultyStore,
                        SimConfig, SimulatedS3, simulate_async)
from repro.core.costs import dollars_per_gib
from repro.core.stores import BlobStore

Row = Tuple[str, float, str]

GiB = 1024 ** 3

CFG = SimConfig(n_nodes=3, inst_per_node=2, n_az=3, duration_s=3.0,
                commit_interval_s=1.0, seed=7)
SCALE = 0.002


def _standard(seed: int) -> BlobStore:
    return SimulatedS3(seed=seed)


def _express(seed: int) -> BlobStore:
    return ExpressOneZoneStore(seed=seed, num_az=CFG.n_az)


def _faulty_standard(seed: int) -> BlobStore:
    return FaultyStore(SimulatedS3(seed=seed), seed=seed,
                       throttle_rate=8.0, throttle_burst=4, prefix_len=2,
                       transient_p=0.05, timeout_p=0.01, timeout_s=1.5)


TIERS: List[Tuple[str, Callable[[int], BlobStore]]] = [
    ("standard", _standard),
    ("express-one-zone", _express),
    ("faulty-standard", _faulty_standard),
]


def _run_tier(make_store: Callable[[int], BlobStore],
              wire_format: str = "raw-v1"):
    eng, summary = simulate_async(
        dataclasses.replace(CFG, wire_format=wire_format), scale=SCALE,
        exactly_once=True,
        engine_cfg=EngineConfig(commit_interval_s=CFG.commit_interval_s,
                                retention_sweep_s=1.0),
        store=make_store(CFG.seed))
    return eng, summary


def tier_sweep() -> List[Row]:
    rows: List[Row] = []
    for name, make_store in TIERS:
        t0 = time.perf_counter()
        eng, s = _run_tier(make_store)
        wall = (time.perf_counter() - t0) * 1e6
        m = eng.metrics
        complete = m.records_delivered == m.records_in
        rows.append((
            f"tiers.{name}", wall,
            f"p50={s['p50_s']:.3f}s p95={s['p95_s']:.3f}s "
            f"p99={s['p99_s']:.3f}s cost=${s['cost_per_gib']:.4f}/GiB "
            f"delivered={m.records_delivered}/{m.records_in} "
            f"dups={m.duplicates_delivered} retries="
            f"{m.put_retries + m.get_retries} throttled={m.throttle_events} "
            f"exactly_once_ok={complete and m.duplicates_delivered == 0}"))
    return rows


def compression_lane() -> List[Row]:
    """raw-v1 vs columnar-v2 on the standard tier: identical delivery,
    shipped bytes cut by the compressed ratio, $/logical-GiB side by
    side (request charges fixed, byte charges scaled)."""
    rows: List[Row] = []
    results = {}
    for fmt in ("raw-v1", "columnar-v2"):
        t0 = time.perf_counter()
        eng, s = _run_tier(_standard, wire_format=fmt)
        wall = (time.perf_counter() - t0) * 1e6
        logical = sum(b.stats.bytes_in for b in eng.batchers)
        shipped = eng.store.stats.put_bytes
        results[fmt] = (eng.metrics, logical, shipped)
        rows.append((
            f"tiers.standard[{fmt}]", wall,
            f"p95={s['p95_s']:.3f}s shipped={shipped / 1e6:.1f}MB "
            f"logical={logical / 1e6:.1f}MB ratio={shipped / logical:.4f} "
            f"cost=${dollars_per_gib(s['cost_usd'], logical):.4f}/logical-GiB "
            f"(${s['cost_per_gib']:.4f}/shipped-GiB) "
            f"delivered={results[fmt][0].records_delivered}"))
    m_raw, m_v2 = results["raw-v1"][0], results["columnar-v2"][0]
    identical = (m_raw.records_delivered == m_v2.records_delivered
                 and m_raw.records_in == m_v2.records_in)
    compressed = results["columnar-v2"][2] < results["raw-v1"][2]
    rows.append(("tiers.compression_lane", 0.0,
                 f"delivery_identical={identical} "
                 f"shipped_reduced={compressed}"))
    return rows


def reproducibility_check() -> List[Row]:
    """The degraded-store run (retries, backoff, throttling and all) must
    be bit-identical for a fixed seed — the determinism acceptance gate."""
    t0 = time.perf_counter()
    eng1, _ = _run_tier(_faulty_standard)
    eng2, _ = _run_tier(_faulty_standard)
    wall = (time.perf_counter() - t0) * 1e6
    m1, m2 = eng1.metrics, eng2.metrics
    same = (m1.record_latencies == m2.record_latencies
            and m1.makespan_s == m2.makespan_s
            and m1.put_retries == m2.put_retries
            and m1.get_retries == m2.get_retries)
    return [("tiers.reproducible", wall,
             f"bit_identical={same} retries={m1.put_retries + m1.get_retries} "
             f"records={m1.records_delivered}")]


def run() -> List[Row]:
    return tier_sweep() + compression_lane() + reproducibility_check()


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
