#!/usr/bin/env python
"""Docs lane: intra-repo markdown link check + docstring examples.

Two passes, both blocking in CI (.github/workflows/ci.yml, job ``docs``):

1. every relative link/image in every tracked ``*.md`` must resolve to a
   file or directory inside the repo (``#fragment`` suffixes are
   stripped; ``http(s)://`` / ``mailto:`` targets are skipped — this is
   a link checker for the repo's own docs, not the internet);
2. every module under ``src/`` whose source contains a ``>>>`` example
   is run through ``doctest`` — executable documentation must execute.

Run locally from the repo root: ``python tools/check_docs.py``.
"""

from __future__ import annotations

import doctest
import importlib
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# [text](target) and ![alt](target); target up to the first unescaped ')'
_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")


def _tracked(suffix: str):
    out = subprocess.run(["git", "ls-files", f"*{suffix}"], cwd=ROOT,
                         capture_output=True, text=True, check=True)
    return [p for p in out.stdout.splitlines() if p]


def check_links() -> list:
    errors = []
    for md in _tracked(".md"):
        base = os.path.dirname(os.path.join(ROOT, md))
        with open(os.path.join(ROOT, md), encoding="utf-8") as f:
            text = f.read()
        # fenced code blocks routinely show link-shaped syntax; skip them
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path = target.split("#", 1)[0]
            if not path:          # pure in-page anchor
                continue
            resolved = os.path.normpath(os.path.join(base, path))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")
    return errors


def check_doctests() -> list:
    sys.path.insert(0, os.path.join(ROOT, "src"))
    errors = []
    ran = 0
    for py in _tracked(".py"):
        if not py.startswith("src/"):
            continue
        with open(os.path.join(ROOT, py), encoding="utf-8") as f:
            if ">>> " not in f.read():
                continue
        mod_name = py[len("src/"):-len(".py")].replace("/", ".")
        if mod_name.endswith(".__init__"):
            mod_name = mod_name[:-len(".__init__")]
        try:
            mod = importlib.import_module(mod_name)
            res = doctest.testmod(mod, verbose=False)
        except Exception as e:  # import or doctest harness failure
            errors.append(f"{py}: doctest run failed: {e!r}")
            continue
        ran += res.attempted
        if res.failed:
            errors.append(f"{py}: {res.failed}/{res.attempted} "
                          f"doctest(s) failed")
    print(f"doctests: {ran} example(s) executed")
    return errors


def main() -> int:
    errors = check_links() + check_doctests()
    for e in errors:
        print(f"ERROR: {e}", file=sys.stderr)
    if errors:
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
