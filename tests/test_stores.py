"""Pluggable BlobStore layer: protocol conformance, tier behavior, fault
injection, engine resilience (retry/backoff/hedging), storage accrual,
and unified GET accounting."""

import numpy as np
import pytest

from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,
                        DistributedCache, EngineConfig,
                        ExpressOneZoneStore, FaultyStore, Record,
                        SimulatedS3)
from repro.core.costs import EXPRESS_ONE_ZONE, STANDARD, TIERS
from repro.core.stores import (BlobStore, LatencyModel, SlowDownError,
                               StoreTimeoutError, TransientStoreError)

CFG = BlobShuffleConfig(batch_bytes=64 * 1024, max_interval_s=0.5,
                        num_partitions=9, num_az=3)
DET = LatencyModel(sigma=0.0)


def make_records(n, vsize=200, seed=0):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(vsize), timestamp_us=i)
            for i in range(n)]


def faulty(seed=5, **kw):
    kw.setdefault("throttle_rate", 5.0)
    kw.setdefault("throttle_burst", 3)
    kw.setdefault("prefix_len", 2)
    kw.setdefault("transient_p", 0.15)
    return FaultyStore(SimulatedS3(seed=0, retention_s=CFG.retention_s),
                       seed=seed, **kw)


def run_engine(store, ecfg=None, n=400, exactly_once=True, seed=0, cfg=CFG):
    eng = AsyncShuffleEngine(cfg, ecfg or EngineConfig(), n_instances=6,
                             store=store, seed=seed,
                             exactly_once=exactly_once)
    for i, rec in enumerate(make_records(n)):
        eng.submit(i * 1e-4, rec)
    return eng, eng.run()


# -- protocol ---------------------------------------------------------------

def test_all_backends_satisfy_blobstore_protocol():
    stores = [SimulatedS3(), ExpressOneZoneStore(),
              FaultyStore(SimulatedS3()),
              FaultyStore(ExpressOneZoneStore())]
    for s in stores:
        assert isinstance(s, BlobStore)


def test_tier_prices_map_to_store_costs():
    assert set(TIERS) == {"standard", "express-one-zone",
                          "premium-low-latency"}
    std, exp = STANDARD.store_costs(), EXPRESS_ONE_ZONE.store_costs()
    assert std.put_per_req == pytest.approx(0.005 / 1000)
    assert exp.put_per_req > std.put_per_req       # premium request price
    assert exp.storage_per_gb_month > std.storage_per_gb_month
    assert ExpressOneZoneStore().costs.put_per_req == exp.put_per_req


# -- storage accrual (byte·seconds) ----------------------------------------

def test_accrue_storage_is_idempotent_and_retention_does_not_double_count():
    s = SimulatedS3(retention_s=50.0)
    s.put("obj", b"x" * 100, now=0.0)
    s.accrue_storage(10.0)
    assert s.stats.byte_seconds == pytest.approx(100 * 10.0)
    s.accrue_storage(10.0)                         # same instant: no-op
    assert s.stats.byte_seconds == pytest.approx(100 * 10.0)
    s.run_retention(100.0)   # deletes; bills only up to expiry (t=50)
    assert not s.contains("obj")
    assert s.stats.byte_seconds == pytest.approx(100 * 50.0)
    s.accrue_storage(200.0)                        # object gone: no-op
    assert s.stats.byte_seconds == pytest.approx(100 * 50.0)


def test_byte_seconds_invariant_to_sweep_cadence():
    """The storage bill is a property of the object's lifetime
    (put → expiry), not of when sweeps happen to run: frequent sweeps, a
    single late sweep, and no sweep at all (only the end-of-run accrual)
    must all charge the same byte·seconds."""
    def bill(sweep_times, final_accrue=300.0):
        s = SimulatedS3(retention_s=50.0)
        s.put("a", b"x" * 100, now=0.0)
        s.put("b", b"y" * 300, now=20.0)
        for t in sweep_times:
            s.run_retention(t)
        s.accrue_storage(final_accrue)
        return s.stats.byte_seconds

    expected = 100 * 50.0 + 300 * 50.0   # each object bills one lifetime
    assert bill([]) == pytest.approx(expected)
    assert bill([60.0, 80.0, 120.0]) == pytest.approx(expected)
    assert bill([299.0]) == pytest.approx(expected)
    # accruals BEFORE expiry don't change the total either
    s = SimulatedS3(retention_s=50.0)
    s.put("a", b"x" * 100, now=0.0)
    for t in (10.0, 30.0, 49.0, 200.0):
        s.accrue_storage(t)
    s.run_retention(250.0)
    assert s.stats.byte_seconds == pytest.approx(100 * 50.0)


def test_engine_accrues_live_objects_at_end_of_run():
    store = SimulatedS3(seed=0, retention_s=3600.0)
    _, m = run_engine(store, exactly_once=False)
    assert store.stats.byte_seconds > 0            # accrued without expiry
    explicit = store.stats.cost_usd(store.costs, explicit_storage=True)
    requests_only = (store.stats.puts * store.costs.put_per_req
                     + store.stats.gets * store.costs.get_per_req)
    assert explicit > requests_only


def test_engine_retention_sweep_deletes_expired_blobs():
    store = SimulatedS3(latency=DET, seed=0, retention_s=0.6)
    eng = AsyncShuffleEngine(CFG, EngineConfig(retention_sweep_s=0.2),
                             n_instances=3, store=store, seed=0,
                             exactly_once=False)
    for i, rec in enumerate(make_records(300)):
        eng.submit(i * 0.01, rec)                  # ingest spans 3 s
    m = eng.run()
    assert m.records_delivered == 300
    assert m.retention_sweeps >= 2
    assert m.retention_deleted > 0
    assert store.stats.byte_seconds > 0


# -- express one zone -------------------------------------------------------

def test_expiry_racing_fetches_aborts_cleanly_instead_of_crashing():
    """A blob deleted by retention before (or during) its fetch must not
    crash the run: the flight aborts, slots free, the loss is counted."""
    store = SimulatedS3(latency=DET, seed=0, retention_s=0.05)
    eng = AsyncShuffleEngine(
        CFG, EngineConfig(notification_latency_s=1.0,
                          retention_sweep_s=0.02),
        n_instances=3, store=store, seed=0, exactly_once=False)
    for i, rec in enumerate(make_records(200)):
        eng.submit(i * 0.01, rec)
    m = eng.run()                                  # must not raise
    assert m.fetches_aborted > 0
    assert m.retention_deleted > 0
    assert all(n == 0 for n in eng._fetch_inflight)  # slots all released


def test_sync_read_releases_leadership_on_missing_object():
    store = SimulatedS3(seed=0)
    cache = DistributedCache(az=0, members=1, capacity_per_member=1 << 20,
                             store=store, cache_on_write=False)
    with pytest.raises(KeyError):
        cache.read("expired")
    assert cache.flight.begin("expired")           # leadership released
    cache.flight.complete("expired", b"")
    store.put("expired", b"z" * 16)
    payload, _, src = cache.read("expired")        # recovers normally
    assert payload == b"z" * 16 and src == "store"


def test_express_cross_az_reads_pay_penalty_and_are_counted():
    e = ExpressOneZoneStore(latency=LatencyModel(sigma=0.0), seed=0,
                            cross_az_penalty_s=0.02)
    e.put("b", b"x" * 1000, now=0.0, az=1)
    _, same = e.get("b", az=1)
    _, cross = e.get("b", az=2)
    assert cross == pytest.approx(same + 0.02)
    assert e.stats.cross_az_gets == 1
    assert e.stats.cross_az_get_bytes == 1000
    _, unknown = e.get("b")                        # az-less caller: no fee
    assert unknown == pytest.approx(same)
    assert e.stats.cross_az_gets == 1
    # the routing charge lands on the bill (zonal tiers only)
    expected = (e.stats.puts * e.costs.put_per_req
                + e.stats.gets * e.costs.get_per_req
                + 1000 / 1e9 * e.costs.cross_az_per_gb)
    assert e.costs.cross_az_per_gb > 0
    assert e.stats.cost_usd(e.costs) == pytest.approx(expected)


def test_express_is_faster_than_standard_for_same_seed():
    std = SimulatedS3(latency=LatencyModel(sigma=0.0))
    exp = ExpressOneZoneStore(latency=None, seed=0)
    exp.latency.sigma = 0.0
    size = 1 << 20
    assert (exp.latency.put_median(size) < std.latency.put_median(size))
    assert (exp.latency.get_median(size) < std.latency.get_median(size))


# -- fault injection --------------------------------------------------------

def test_token_bucket_throttles_per_prefix_and_refills():
    f = FaultyStore(SimulatedS3(), seed=0, throttle_rate=1.0,
                    throttle_burst=2, prefix_len=2)
    f.put("aa-1", b"x", now=0.0)
    f.put("aa-2", b"x", now=0.0)
    with pytest.raises(SlowDownError) as ei:
        f.put("aa-3", b"x", now=0.0)               # bucket drained
    assert ei.value.retry_after_s > 0
    f.put("bb-1", b"x", now=0.0)                   # other prefix unaffected
    f.put("aa-4", b"x", now=5.0)                   # refilled by now
    assert f.faults.slowdowns == 1
    assert f.stats.puts == 4                       # failed PUT never billed
    assert not f.contains("aa-3")                  # ... nor applied


def test_transient_and_timeout_faults_have_detection_latency():
    f = FaultyStore(SimulatedS3(), seed=3, transient_p=1.0, detect_s=0.07)
    with pytest.raises(TransientStoreError) as ei:
        f.begin_put("b", 100, now=0.0)
    assert ei.value.detect_after_s == pytest.approx(0.07)
    t = FaultyStore(SimulatedS3(), seed=3, timeout_p=1.0, timeout_s=1.5)
    with pytest.raises(StoreTimeoutError) as ei:
        t.begin_get("missing", now=0.0)            # fails before lookup
    assert ei.value.detect_after_s == pytest.approx(1.5)
    assert t.stats.gets == 0


# -- engine resilience ------------------------------------------------------

def test_retries_deliver_every_record_exactly_once_under_faults():
    store = faulty()
    eng, m = run_engine(store, n=600)
    flat = [r.timestamp_us for rs in eng.out.values() for r in rs]
    assert sorted(flat) == list(range(600))        # no loss, no duplicates
    assert m.duplicates_delivered == 0
    assert m.put_retries + m.get_retries > 0
    assert m.uploads_aborted == 0 and m.fetches_aborted == 0
    assert store.faults.total > 0


def test_throttling_applies_lane_backpressure():
    store = faulty(transient_p=0.0, throttle_rate=2.0, throttle_burst=2)
    _, m = run_engine(store, n=600)
    assert m.throttle_events > 0
    assert m.records_delivered == 600


def test_faulty_run_is_bit_reproducible_for_fixed_seed():
    def once():
        _, m = run_engine(faulty(), n=500)
        return (m.makespan_s, tuple(m.record_latencies), m.put_retries,
                m.get_retries, m.throttle_events)
    assert once() == once()


def test_get_accounting_is_consistent_across_layers():
    """Satellite invariant: every store GET is led by exactly one cache
    cluster — store-side and cache-side request counts must agree."""
    for store in (SimulatedS3(seed=0), faulty()):
        eng, m = run_engine(store, n=500)
        assert m.records_delivered == 500
        assert store.stats.gets == sum(c.stats.store_gets
                                       for c in eng.caches)


def test_hedged_gets_fire_on_slow_tail_and_deliver_exactly_once():
    cfg = BlobShuffleConfig(batch_bytes=8 * 1024, max_interval_s=0.2,
                            num_partitions=9, num_az=3,
                            cache_on_write=False, distributed_cache_bytes=1)
    store = SimulatedS3(latency=LatencyModel(sigma=1.5), seed=0)
    eng = AsyncShuffleEngine(
        cfg, EngineConfig(hedge_quantile=50.0, hedge_min_samples=5),
        n_instances=3, store=store, seed=0, exactly_once=True)
    for i, rec in enumerate(make_records(600)):
        eng.submit(i * 1e-5, rec)
    m = eng.run()
    flat = [r.timestamp_us for rs in eng.out.values() for r in rs]
    assert sorted(flat) == list(range(600))
    assert m.hedges_issued > 0
    assert m.hedges_won <= m.hedges_issued
    # hedge requests are billed + counted through the same choke point
    assert store.stats.gets == sum(c.stats.store_gets for c in eng.caches)


def test_pipeline_runs_on_alternate_backends():
    from repro.core import BlobShufflePipeline
    recs = make_records(300)
    for store in (ExpressOneZoneStore(seed=0, num_az=CFG.num_az),
                  faulty(transient_p=0.1)):
        pipe = BlobShufflePipeline(CFG, n_instances=6, store=store,
                                   exactly_once=True)
        out = pipe.run(recs, commit_every=100)
        flat = [r.timestamp_us for rs in out.values() for r in rs]
        assert sorted(flat) == list(range(300))


def test_legacy_store_shim_reexports_the_stores_package():
    """``repro.core.store`` is a back-compat shim: every name it exports
    must be the SAME object as in ``repro.core.stores``."""
    import warnings

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        import repro.core.store as shim
    import repro.core.stores as stores
    assert shim.__all__                      # shim keeps a public surface
    for name in shim.__all__:
        assert getattr(shim, name) is getattr(stores, name)


def test_legacy_store_shim_warns_once_on_import():
    """Importing the shim emits exactly one ``DeprecationWarning`` (at
    module execution); the cached re-import stays silent."""
    import importlib
    import sys
    import warnings

    sys.modules.pop("repro.core.store", None)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        import repro.core.store  # noqa: F401
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)
           and "repro.core.store is deprecated" in str(w.message)]
    assert len(dep) == 1

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        importlib.import_module("repro.core.store")   # cached: no re-exec
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]
