"""Ratchet comparator: regression detection, monotonic update, and the
warn-and-skip rule for baseline keys absent from a fresh run (e.g. the
device-lane throughput on CPU-only CI)."""

import json

from benchmarks.ratchet import TOLERANCE, compare, main


def test_regression_detected():
    failures, improvements, skipped = compare(
        {"pack_gb_s": 1.0}, {"pack_gb_s": 3.0}, keys=("pack_gb_s",))
    assert failures == [("pack_gb_s", 3.0, 1.0)]
    assert improvements == [] and skipped == []


def test_within_tolerance_passes():
    failures, _, _ = compare(
        {"pack_gb_s": 3.0 * TOLERANCE + 1e-9}, {"pack_gb_s": 3.0},
        keys=("pack_gb_s",))
    assert failures == []


def test_improvement_reported():
    _, improvements, _ = compare(
        {"pack_gb_s": 4.0}, {"pack_gb_s": 3.0}, keys=("pack_gb_s",))
    assert improvements == [("pack_gb_s", 3.0, 4.0)]


def test_new_key_not_ratcheted():
    # fresh produces a key the baseline has never seen: nothing to do
    failures, improvements, skipped = compare(
        {"new_metric": 1.0}, {}, keys=("new_metric",))
    assert failures == [] and improvements == [] and skipped == []


def test_baseline_only_key_warns_and_skips():
    # the satellite case: a device-lane number ratcheted on a TPU/GPU
    # machine, absent from a CPU-only fresh run — must skip, not fail
    failures, improvements, skipped = compare(
        {"pack_gb_s": 3.0},
        {"pack_gb_s": 3.0, "device_pack_gb_s": 42.0},
        keys=("pack_gb_s", "device_pack_gb_s"))
    assert failures == []
    assert skipped == [("device_pack_gb_s", 42.0)]


def test_main_exit_codes_and_skip(tmp_path, capsys):
    fresh = tmp_path / "fresh.json"
    base = tmp_path / "base.json"
    fresh.write_text(json.dumps({"pack_gb_s": 3.0, "v2_encode_gb_s": 1.0}))
    base.write_text(json.dumps({"pack_gb_s": 3.0, "v2_encode_gb_s": 1.0,
                                "device_pack_gb_s": 42.0}))
    rc = main([str(fresh), "--baseline", str(base)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "WARNING device_pack_gb_s" in out
    assert "ratchet: ok" in out
    # a real regression still fails regardless of the skipped lane
    fresh.write_text(json.dumps({"pack_gb_s": 0.1, "v2_encode_gb_s": 1.0}))
    assert main([str(fresh), "--baseline", str(base)]) == 1


def test_main_update_raises_baseline_monotonically(tmp_path):
    fresh = tmp_path / "fresh.json"
    base = tmp_path / "base.json"
    fresh.write_text(json.dumps({"pack_gb_s": 5.0, "v2_encode_gb_s": 0.8}))
    base.write_text(json.dumps({"pack_gb_s": 3.0, "v2_encode_gb_s": 0.9,
                                "device_pack_gb_s": 42.0}))
    assert main([str(fresh), "--baseline", str(base), "--update"]) == 0
    updated = json.loads(base.read_text())
    assert updated["pack_gb_s"] == 5.0          # improved: raised
    assert updated["v2_encode_gb_s"] == 0.9     # within band: untouched
    assert updated["device_pack_gb_s"] == 42.0  # skipped lane: untouched
