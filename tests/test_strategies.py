"""Pluggable shuffle strategies (repro.core.strategy): default-strategy
bit-identity with the pre-seam engine, combiner semantics, per-strategy
engine behavior (combining / push / merge), fault injection, and a
cooperative rebalance mid-stream under every strategy."""

import dataclasses
import hashlib

import numpy as np
import pytest

import benchmarks.strategies as S
from repro.cluster import ElasticCluster
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig, EngineConfig,
                        ExpressOneZoneStore, FaultyStore, Record, SimConfig,
                        WorkloadConfig, simulate_async)
from repro.core.recordbatch import RecordBatch
from repro.core.strategy import (CombiningStrategy, DefaultStrategy,
                                 LastWinsCombiner, PushStrategy,
                                 SumU64Combiner, TwoRoundMergeStrategy,
                                 make_strategy)
from repro.core.workload import drive

#: the benchmark's head-to-head geometry at the CI-quick duration: six
#: instances over three AZs, Zipf(1.2) keys, columnar ingest
QCFG = dataclasses.replace(S.CFG, duration_s=1.5)

STRATEGY_NAMES = ("default", "combining", "push", "merge")


@pytest.fixture(scope="module")
def clean_runs():
    """One clean run per strategy on the shared skewed workload
    (module-scoped: every behavioral test below reads these)."""
    return {name: S._run_strategy(name, QCFG, S.SCALE)
            for name in STRATEGY_NAMES}


# -- the seam itself ---------------------------------------------------------

def test_default_strategy_is_bit_identical_to_pre_seam_engine():
    """The acceptance pin: a default-strategy run must reproduce the
    exact pre-PR digests (delivery multiset, latency samples, store
    request counts, makespan) on both the batch-ingest and the
    scalar/zonal configurations."""
    def digest(eng):
        h = hashlib.sha256()
        for p in sorted(eng.out):
            h.update(str(p).encode())
            for r in sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                            for r in eng.out[p]):
                h.update(r[0])
                h.update(r[1])
                h.update(str(r[2]).encode())
        h.update(repr([round(x, 12)
                       for x in eng.metrics.record_latencies[:50]]).encode())
        h.update(repr((eng.store.stats.puts, eng.store.stats.gets,
                       eng.store.stats.put_bytes)).encode())
        h.update(repr(round(eng.metrics.makespan_s, 9)).encode())
        return h.hexdigest()

    cfg = SimConfig(n_nodes=3, inst_per_node=2, n_az=3, duration_s=2.0,
                    commit_interval_s=0.5, seed=13)
    eng, _ = simulate_async(cfg, scale=0.002, exactly_once=True,
                            key_skew=1.2, ingest_batch_records=256)
    assert digest(eng) == ("61e106bb8413bd21037ee5453253a683"
                           "35e565419477921f1b56ba67176387a4")
    eng2, _ = simulate_async(cfg, scale=0.002, exactly_once=True,
                             key_skew=1.2,
                             store=ExpressOneZoneStore(seed=13, num_az=3))
    assert digest(eng2) == ("3fa47d963ce97f02fc0a0b96e92ddf3e"
                            "4a593d34fb1436bef83137c89d6c7e30")


def test_make_strategy_resolves_names_instances_and_rejects_unknown():
    assert type(make_strategy(None)) is DefaultStrategy
    assert type(make_strategy("default")) is DefaultStrategy
    assert type(make_strategy("combining")) is CombiningStrategy
    assert type(make_strategy("push")) is PushStrategy
    assert type(make_strategy("merge")) is TwoRoundMergeStrategy
    inst = TwoRoundMergeStrategy(fan_in=4)
    assert make_strategy(inst) is inst
    with pytest.raises(ValueError, match="unknown shuffle strategy"):
        make_strategy("pull")


# -- combiners ---------------------------------------------------------------

def _batch(triples):
    return RecordBatch.from_records(
        [Record(k, v, timestamp_us=t) for k, v, t in triples])


def test_last_wins_keeps_latest_record_per_key_in_row_order():
    b = _batch([(b"aaaaaaaa", b"v1", 0), (b"bbbbbbbb", b"v2", 1),
                (b"aaaaaaaa", b"v3", 2), (b"cccccccc", b"v4", 3),
                (b"bbbbbbbb", b"v5", 4)])
    out, sel = LastWinsCombiner().combine(b)
    assert list(sel) == [2, 3, 4]          # ascending last occurrences
    assert [(out.key(i), out.value(i), int(out.timestamps[i]))
            for i in range(len(out))] == [
        (b"aaaaaaaa", b"v3", 2), (b"cccccccc", b"v4", 3),
        (b"bbbbbbbb", b"v5", 4)]


def test_last_wins_passes_through_when_all_keys_distinct():
    b = _batch([(b"aaaaaaaa", b"v", 0), (b"bbbbbbbb", b"v", 1)])
    assert LastWinsCombiner().combine(b) == (None, None)


def test_last_wins_ragged_keys_take_the_memo_path_and_agree():
    # ragged key widths defeat the void-view fast path; the dict memo
    # fallback must produce the same latest-record-per-key answer
    b = _batch([(b"a", b"v1", 0), (b"long-key", b"v2", 1),
                (b"a", b"v3", 2), (b"long-key", b"v4", 3)])
    out, sel = LastWinsCombiner().combine(b)
    assert list(sel) == [2, 3]
    assert [(out.key(i), out.value(i)) for i in range(len(out))] == [
        (b"a", b"v3"), (b"long-key", b"v4")]


def test_sum_u64_sums_word_vectors_per_key_modulo_2_64():
    def words(*ws):
        return b"".join(int(w % 2**64).to_bytes(8, "little") for w in ws)
    b = _batch([(b"aaaaaaaa", words(1, 10), 0),
                (b"bbbbbbbb", words(2, 20), 1),
                (b"aaaaaaaa", words(2**64 - 1, 30), 2),  # forces wraparound
                (b"bbbbbbbb", words(5, 40), 3)])
    out, sel = SumU64Combiner().combine(b)
    assert list(sel) == [2, 3]
    assert out.value(0) == words(0, 40)    # 1 + (2^64-1) wraps to 0
    assert out.value(1) == words(7, 60)
    # representative rows keep the latest key/timestamp per group
    assert [int(out.timestamps[i]) for i in range(2)] == [2, 3]


def test_sum_u64_guards_pass_through_unsummable_shapes():
    c = SumU64Combiner()
    # ragged value widths
    assert c.combine(_batch([(b"aaaaaaaa", b"x" * 8, 0),
                             (b"aaaaaaaa", b"x" * 16, 1)])) == (None, None)
    # width not a multiple of 8
    assert c.combine(_batch([(b"aaaaaaaa", b"x" * 12, 0),
                             (b"aaaaaaaa", b"x" * 12, 1)])) == (None, None)


def test_combiners_are_deterministic():
    rng = np.random.default_rng(3)
    recs = [(bytes(rng.bytes(8)) if rng.random() < 0.5 else b"hot-key!",
             bytes(rng.bytes(16)), i) for i in range(200)]
    for combiner in (LastWinsCombiner(), SumU64Combiner()):
        a, sa = combiner.combine(_batch(recs))
        b, sb = combiner.combine(_batch(recs))
        assert list(sa) == list(sb)
        assert a.serialize_rows() == b.serialize_rows()


# -- engine behavior per strategy -------------------------------------------

def test_combining_delivery_matches_reference_combine(clean_runs):
    eng, _, _ = clean_runs["combining"]
    assert S._multiset(eng) == S._reference_combine(QCFG, S.SCALE)
    st = eng.strategy.stats
    assert st.records_combined > 0 and st.bytes_saved_logical > 0
    assert (eng.metrics.records_delivered
            == eng.metrics.records_in - st.records_combined)


def test_combining_ships_fewer_bytes_than_default(clean_runs):
    _, base_store, _ = clean_runs["default"]
    _, comb_store, _ = clean_runs["combining"]
    assert comb_store.stats.put_bytes < base_store.stats.put_bytes


def test_push_placement_eliminates_cross_az_gets(clean_runs):
    eng_d, store_d, _ = clean_runs["default"]
    eng_p, store_p, _ = clean_runs["push"]
    assert store_d.stats.cross_az_gets > 0     # default really pays them
    assert store_p.stats.cross_az_gets == 0
    # the routing bytes moved to PUT time and are surfaced for pricing
    assert eng_p.strategy.stats.push_cross_az_bytes > 0
    assert S._multiset(eng_p) == S._multiset(eng_d)


def test_merge_compaction_divides_gets_and_notifications(clean_runs):
    eng_d, store_d, _ = clean_runs["default"]
    eng_m, store_m, _ = clean_runs["merge"]
    st = eng_m.strategy.stats
    assert st.merged_blobs > 0
    assert st.merged_inputs >= 2 * st.merged_blobs   # real fan-in
    assert st.merge_fallback_notes == 0              # clean store: no falls
    assert store_d.stats.gets >= 3 * max(store_m.stats.gets, 1)
    assert len(eng_d.published) >= 3 * len(eng_m.published)
    assert S._multiset(eng_m) == S._multiset(eng_d)


def test_every_strategy_is_exactly_once_on_a_clean_store(clean_runs):
    for name, (eng, _, _) in clean_runs.items():
        assert eng.metrics.duplicates_delivered == 0, name


# -- fault injection ---------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_strategies_survive_throttling_and_transients(name, clean_runs):
    """Each strategy under a throttling + transient-fault store must
    deliver exactly what its clean run delivered — zero lost, zero
    duplicated (for merge the compactor's own fetches/PUTs retry or
    fall back to the original notifications, never dropping them)."""
    store = FaultyStore(ExpressOneZoneStore(seed=QCFG.seed, num_az=QCFG.n_az),
                        seed=5, throttle_rate=5.0, throttle_burst=3,
                        prefix_len=2, transient_p=0.15)
    # this fault intensity needs a longer retry budget than the default
    # 8 attempts — the test's contract is zero loss, so every retry
    # chain must be allowed to outlast the throttle window
    ecfg = EngineConfig(commit_interval_s=QCFG.commit_interval_s,
                        max_attempts=16)
    eng, _ = simulate_async(QCFG, scale=S.SCALE, exactly_once=True,
                            key_skew=S.KEY_SKEW, store=store,
                            ingest_batch_records=S.BATCH_RECORDS,
                            strategy=name, engine_cfg=ecfg)
    assert store.faults.total > 0              # faults actually fired
    assert eng.metrics.duplicates_delivered == 0
    assert eng.metrics.uploads_aborted == 0
    assert eng.metrics.fetches_aborted == 0
    clean_eng, _, _ = clean_runs[name]
    assert S._multiset(eng) == S._multiset(clean_eng)
    if name == "merge":
        # under store pressure the compactor must degrade by delivering
        # the ORIGINAL notifications, never by dropping records
        assert eng.strategy.stats.merge_fallback_notes > 0


# -- cooperative rebalance mid-stream ---------------------------------------

RCFG = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                         num_partitions=18, num_az=3)
RWL = WorkloadConfig(arrival_rate=2000.0, duration_s=1.5, record_bytes=300,
                     key_skew=1.2, seed=11)


def _rebalance_run(strategy=None, join_t=0.4):
    eng = AsyncShuffleEngine(RCFG, EngineConfig(commit_interval_s=0.1),
                             n_instances=4, seed=7, exactly_once=True,
                             strategy=strategy)
    cluster = ElasticCluster(eng, mode="cooperative",
                             heartbeat_timeout_s=0.15)
    if join_t is not None:
        eng.loop.at(join_t, cluster.add_worker)
    drive(eng, RWL, batch_records=64)
    return eng, cluster, eng.run()


@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_strategies_survive_a_cooperative_rebalance_mid_stream(name):
    """A worker joins mid-stream under each strategy: the cooperative
    rebalance must stay exactly-once and deliver bit-identically to the
    same strategy's static-cluster run (which for default/push/merge is
    also the default static delivery)."""
    static_eng, _, ms = _rebalance_run(strategy=name, join_t=None)
    eng, cluster, m = _rebalance_run(strategy=name)
    events = [e for e in cluster.rebalancer.events if not e.superseded]
    assert [e.reason for e in events] == ["join"]
    assert m.duplicates_delivered == ms.duplicates_delivered == 0
    assert m.records_delivered == ms.records_delivered
    assert S._multiset(eng) == S._multiset(static_eng)


def test_push_follows_the_assignors_owner_az_after_rebalance():
    """Push placement must re-snapshot ownership when assignment
    changes: with a cluster attached, ``partition_target_az`` is the
    live owner's AZ, not the static partition→AZ map."""
    eng, cluster, _ = _rebalance_run(strategy="push")
    strat = eng.strategy
    for p, st in cluster.parts.items():
        owner = st.owner
        if owner is not None and cluster.membership.is_alive_now(owner):
            assert (strat.partition_target_az(p)
                    == cluster.membership.workers[owner].az)
