"""Observability layer (repro.obs): enabled-run bit-identity with the
unobserved engine, exact stage decomposition, conservation laws on
clean / faulty / rebalancing runs for every strategy, sketch-backed
hedge thresholds with the exact cross-check, windowed queries, and the
Chrome-trace artifact."""

import dataclasses
import hashlib
import json

import pytest

import benchmarks.strategies as S
from repro.cluster import ElasticCluster
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig, EngineConfig,
                        ExpressOneZoneStore, FaultyStore, SimConfig,
                        WorkloadConfig, simulate_async)
from repro.core.workload import drive
from repro.obs import (STAGES, ConservationError, ObsConfig, Observability,
                       check_conservation, make_observability)

STRATEGY_NAMES = ("default", "combining", "push", "merge")

QCFG = dataclasses.replace(S.CFG, duration_s=1.5)


def _digest(eng):
    """The bit-identity digest from test_strategies: delivery multiset,
    latency samples, store request counts, makespan."""
    h = hashlib.sha256()
    for p in sorted(eng.out):
        h.update(str(p).encode())
        for r in sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                        for r in eng.out[p]):
            h.update(r[0])
            h.update(r[1])
            h.update(str(r[2]).encode())
    h.update(repr([round(x, 12)
                   for x in eng.metrics.record_latencies[:50]]).encode())
    h.update(repr((eng.store.stats.puts, eng.store.stats.gets,
                   eng.store.stats.put_bytes)).encode())
    h.update(repr(round(eng.metrics.makespan_s, 9)).encode())
    return h.hexdigest()


def _obs_run(strategy="default", obs=True, store=None, engine_cfg=None):
    return simulate_async(QCFG, scale=S.SCALE, exactly_once=True,
                          key_skew=S.KEY_SKEW,
                          ingest_batch_records=S.BATCH_RECORDS,
                          store=store or ExpressOneZoneStore(
                              seed=QCFG.seed, num_az=QCFG.n_az),
                          strategy=strategy, obs=obs,
                          engine_cfg=engine_cfg)


# -- bit-identity ------------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_observed_run_is_bit_identical_to_unobserved(name):
    """The acceptance pin of the whole layer: enabling observability
    never schedules an event or consumes RNG, so the observed run's
    digest equals the unobserved run's for every strategy."""
    eng_off, _ = _obs_run(name, obs=None)
    eng_on, _ = _obs_run(name, obs=True)
    assert eng_off.obs is None
    assert eng_on.obs is not None
    assert _digest(eng_on) == _digest(eng_off)


def test_make_observability_resolves_and_rejects():
    assert make_observability(None) is None
    assert make_observability(False) is None
    assert isinstance(make_observability(True), Observability)
    cfg = ObsConfig(window_s=0.5)
    o = make_observability(cfg)
    assert o.cfg is cfg
    assert make_observability(o) is o
    with pytest.raises(TypeError, match="obs must be"):
        make_observability(42)


# -- latency decomposition ---------------------------------------------------

@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_stage_decomposition_reconciles_with_end_to_end(name):
    """batch_wait + upload + commit_wait + notify + fetch is an EXACT
    partition of the end-to-end latency: per-record stage sums equal the
    e2e samples, so the mean sums agree to float precision and no record
    is left unattributed."""
    eng, _ = _obs_run(name)
    d = eng.obs.stage_decomposition(qs=(50, 95))
    chk = d["sum_check"]
    assert chk["unattributed_records"] == 0
    assert chk["stage_records"] == chk["e2e_records"] \
        == eng.metrics.records_delivered
    assert chk["e2e_mean_s"] > 0
    assert chk["stage_mean_sum_s"] == pytest.approx(chk["e2e_mean_s"],
                                                    rel=1e-9)
    for s in STAGES:
        assert 0.0 <= d[s]["p50_s"] <= d[s]["p95_s"]
    assert d["e2e"]["p50_s"] <= d["e2e"]["p95_s"]
    # the sketch's p95 tracks the exact per-record p95 within its bound
    import numpy as np
    exact = float(np.percentile(eng.metrics.record_latencies, 95))
    assert d["e2e"]["p95_s"] == pytest.approx(exact, rel=0.02)


# -- conservation laws -------------------------------------------------------

@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_conservation_holds_on_a_clean_run(name):
    eng, _ = _obs_run(name)
    rep = eng.obs.report
    assert rep is not None and rep.checked >= 10
    assert rep.violations == [], rep.summary()


@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_conservation_holds_under_throttling_and_transients(name):
    """The checker must hold (not just skip everything) when the store
    throttles and faults: retries, fallbacks and hedges all stay inside
    the flow identities."""
    store = FaultyStore(ExpressOneZoneStore(seed=QCFG.seed, num_az=QCFG.n_az),
                       seed=5, throttle_rate=5.0, throttle_burst=3,
                       prefix_len=2, transient_p=0.15)
    eng, _ = _obs_run(name, store=store,
                      engine_cfg=EngineConfig(
                          commit_interval_s=QCFG.commit_interval_s,
                          max_attempts=16))
    assert store.faults.total > 0
    rep = eng.obs.report
    assert rep.violations == [], rep.summary()


RCFG = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                         num_partitions=18, num_az=3)
RWL = WorkloadConfig(arrival_rate=2000.0, duration_s=1.5, record_bytes=300,
                     key_skew=1.2, seed=11)


def _rebalance_run(strategy, obs=True):
    eng = AsyncShuffleEngine(RCFG, EngineConfig(commit_interval_s=0.1),
                             n_instances=4, seed=7, exactly_once=True,
                             strategy=strategy, obs=obs)
    cluster = ElasticCluster(eng, mode="cooperative",
                             heartbeat_timeout_s=0.15)
    eng.loop.at(0.4, cluster.add_worker)
    drive(eng, RWL, batch_records=64)
    return eng, cluster, eng.run()


@pytest.mark.parametrize("name", STRATEGY_NAMES)
def test_conservation_holds_across_a_cooperative_rebalance(name):
    """A worker joining mid-stream must leave every law intact, and the
    rebalance window must be queryable from the recorded marks."""
    eng, _, m = _rebalance_run(name)
    rep = eng.obs.report
    assert rep.violations == [], rep.summary()
    triggers = eng.obs.registry.marks_named("rebalance_trigger:")
    completes = eng.obs.registry.marks_named("rebalance_complete")
    assert len(triggers) == 1 and len(completes) >= 1
    t0, t1 = triggers[0][0], completes[-1][0]
    assert t0 <= t1      # cooperative handoff can complete in the same tick
    # "p95 during the rebalance" is a query, not bespoke code
    p95_rebal = eng.obs.e2e_percentile(95, t0, t1 + 0.25)
    p95_all = eng.obs.e2e_percentile(95)
    assert p95_all is not None and p95_all > 0
    assert p95_rebal is None or p95_rebal > 0


def test_strict_conservation_raises_on_a_cooked_counter():
    """Corrupting one stats counter after the run must flip exactly the
    laws that reference it — and strict mode must raise."""
    eng, _ = _obs_run("default")
    eng.metrics.records_delivered += 1
    rep = check_conservation(eng)
    assert any(r.name == "delivered_records_match_debatchers"
               for r in rep.violations)
    with pytest.raises(ConservationError,
                       match="delivered_records_match_debatchers"):
        check_conservation(eng, strict=True)


# -- sketch-backed hedging ---------------------------------------------------

def test_hedge_threshold_from_sketch_passes_the_exact_cross_check():
    """``hedge_debug_exact`` recomputes every threshold with
    np.percentile and asserts the sketch stays within 2%: the run
    completing IS the property holding on real latency data."""
    cfg = BlobShuffleConfig(batch_bytes=32 * 1024, max_interval_s=0.1,
                            num_partitions=9, num_az=3,
                            cache_on_write=False)   # force store GETs
    eng = AsyncShuffleEngine(
        cfg, EngineConfig(commit_interval_s=0.05, hedge_quantile=50.0,
                          hedge_min_samples=5, hedge_debug_exact=True),
        n_instances=4, seed=1, exactly_once=True, obs=True)
    wl = WorkloadConfig(arrival_rate=2500.0, duration_s=0.6,
                        record_bytes=300, key_skew=0.8, seed=3)
    drive(eng, wl, batch_records=64)
    m = eng.run()
    assert m.hedges_issued > 0          # thresholds really computed
    assert eng.obs.report.violations == []


# -- registry / windows ------------------------------------------------------

def test_counter_and_histogram_window_slicing():
    from repro.obs.registry import MetricsRegistry
    reg = MetricsRegistry(window_s=0.25)
    c = reg.counter("records", "engine", az=0)
    h = reg.histogram("lat", "store")
    for i in range(40):
        t = i * 0.05                     # windows of 5 observations
        c.inc(2, t)
        h.observe(0.010 if t < 1.0 else 0.100, t)
    assert c.total == 80
    assert c.total_in(0.0, 1.0) == 40
    assert c.total_in(1.0, 2.0) == 40
    # the same histogram answers differently per window
    assert h.percentile(50, 0.0, 1.0) == pytest.approx(0.010, rel=0.02)
    assert h.percentile(50, 1.0, 2.0) == pytest.approx(0.100, rel=0.02)
    assert h.percentile(50, 5.0, 6.0) is None      # empty slice
    snap = reg.snapshot()
    assert snap["counters"]["engine.records[az=0]"]["total"] == 80
    assert snap["histograms"]["store.lat"]["count"] == 40


# -- trace artifact ----------------------------------------------------------

def test_trace_artifact_is_valid_chrome_trace(tmp_path):
    eng, _ = _obs_run("default", obs=ObsConfig(trace_sample_every=2))
    path = tmp_path / "trace.json"
    eng.obs.tracer.dump(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    assert evs and {e["ph"] for e in evs} <= {"X", "i", "M"}
    spans = [e for e in evs if e["ph"] == "X"]
    assert {"pack", "upload", "notify", "fetch"} <= {e["name"] for e in spans}
    for e in spans:
        assert e["ts"] >= 0 and e["dur"] >= 0
    # every lane is named after its blob via thread_name metadata
    lanes = {e["tid"] for e in spans}
    named = {e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert lanes <= named
    # sampling is deterministic on the blob id, never engine RNG
    tracer = eng.obs.tracer
    sampled = {e["args"]["name"] for e in evs
               if e["ph"] == "M" and e["name"] == "thread_name"}
    assert all(tracer.sampled(b) for b in sampled)
