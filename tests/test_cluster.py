"""Elastic cluster subsystem: notification log + consumer offsets,
virtual-clock membership, sticky AZ-aware assignment, eager vs
cooperative rebalance with exactly-once handoff, and autoscaling."""

import numpy as np

from repro.cluster import (AutoscalePolicy, ElasticCluster, Membership,
                           NotificationLog, OffsetStore, PartitionMeta,
                           StickyAzAssignor, WorkerInfo)
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,
                        DistributedCache, EngineConfig, EventLoop, Record,
                        SimConfig, SimulatedS3, simulate_elastic)
from repro.core.blob import ByteRange, Notification

CFG = BlobShuffleConfig(batch_bytes=48 * 1024, max_interval_s=0.2,
                        num_partitions=18, num_az=3)


def make_records(n, vsize=300, seed=11):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(vsize), timestamp_us=i)
            for i in range(n)]


def make_engine(n_instances=4, seed=7, ecfg=None):
    return AsyncShuffleEngine(
        CFG, ecfg or EngineConfig(commit_interval_s=0.1),
        n_instances=n_instances, seed=seed, exactly_once=True)


def submit_all(eng, recs, rate=2000.0):
    for i, rec in enumerate(recs):
        eng.submit(i / rate, rec)


def out_multiset(eng):
    return {p: sorted((bytes(r.key), bytes(r.value), r.timestamp_us)
                      for r in rs)
            for p, rs in eng.out.items() if rs}


def note(partition, blob="b0", az=0):
    return Notification(blob, partition, ByteRange(0, 10), az)


# -- notification log + offsets --------------------------------------------

def test_notification_log_offsets_are_dense_and_replayable():
    log = NotificationLog()
    assert log.end_offset(3) == 0
    offs = [log.append(note(3, f"b{i}")) for i in range(5)]
    assert offs == [0, 1, 2, 3, 4]
    assert log.end_offset(3) == 5 and log.end_offset(4) == 0
    assert [o for o, _ in log.read(3, 1, 3)] == [1, 2]
    replayed = log.replay(3, 2)
    assert [o for o, _ in replayed] == [2, 3, 4]
    assert [n.blob_id for _, n in replayed] == ["b2", "b3", "b4"]
    assert log.stats.replayed == 3 and log.stats.appends == 5


def test_offset_store_commits_are_monotonic():
    st = OffsetStore()
    assert st.committed("g", 0) == 0
    assert st.commit("g", 0, 5) and st.committed("g", 0) == 5
    assert not st.commit("g", 0, 3)        # stale coordinator: rejected
    assert st.committed("g", 0) == 5
    assert st.committed("other", 0) == 0   # groups are independent


# -- membership -------------------------------------------------------------

def test_membership_crash_detected_one_timeout_later():
    loop = EventLoop()
    changes = []
    m = Membership(loop, heartbeat_timeout_s=0.5,
                   on_change=lambda k, w: changes.append(
                       (loop.now, k, w.worker_id)))
    m.join("a", 0, 0)
    m.join("b", 1, 1)
    loop.at(2.0, m.crash, "a")
    loop.run()
    # crash is silent at t=2: still in the group's alive() view, but
    # ground truth knows; detection lands exactly one timeout later
    assert (2.5, "crash", "a") in changes
    assert [w.worker_id for w in m.alive()] == ["b"]
    assert not m.is_alive_now("a") and m.is_alive_now("b")


def test_membership_heartbeat_cancels_pending_detection():
    loop = EventLoop()
    changes = []
    m = Membership(loop, heartbeat_timeout_s=0.5,
                   on_change=lambda k, w: changes.append(k))
    m.join("a", 0, 0)
    loop.at(1.0, m.crash, "a")
    loop.at(1.2, m.heartbeat, "a")   # recovered before the timeout
    loop.run()
    assert changes == ["join"]
    assert m.is_alive_now("a")


# -- sticky AZ-aware assignment ---------------------------------------------

def mk_workers(azs):
    return [WorkerInfo(f"w{i}", az=az, inst=i, joined_at=0.0)
            for i, az in enumerate(azs)]


def mk_parts(n, n_az=3):
    return [PartitionMeta(p, p % n_az) for p in range(n)]


def test_assignor_balances_and_aligns_with_home_az():
    parts, workers = mk_parts(18), mk_workers([0, 1, 2, 0, 1, 2])
    out = StickyAzAssignor().assign(parts, workers)
    loads = {w.worker_id: 0 for w in workers}
    by_id = {w.worker_id: w for w in workers}
    for p in parts:
        w = by_id[out[p.partition]]
        loads[w.worker_id] += 1
        assert w.az == p.home_az       # every partition lands in-home-AZ
    assert set(loads.values()) == {3}  # perfectly balanced


def test_assignor_join_moves_at_most_fair_share():
    parts, workers = mk_parts(18), mk_workers([0, 1, 2, 0])
    a = StickyAzAssignor()
    first = a.assign(parts, workers)
    joined = workers + [WorkerInfo("w4", az=1, inst=4, joined_at=1.0)]
    second = a.assign(parts, joined, first)
    moved = StickyAzAssignor.moved(first, second)
    assert 0 < len(moved) <= -(-18 // 5)   # <= ceil(P / W') = fair share
    assert any(second[p] == "w4" for p in moved)   # the join absorbs load
    # unmoved partitions all kept their previous owner (stickiness)
    assert all(second[p] == first[p] for p in first if p not in moved)


def test_assignor_crash_reassigns_only_dead_workers_partitions():
    parts, workers = mk_parts(18), mk_workers([0, 1, 2, 0, 1, 2])
    a = StickyAzAssignor()
    first = a.assign(parts, workers)
    workers[1].state = "crashed"
    second = a.assign(parts, workers, first)
    for p, w in second.items():
        if first[p] != "w1":
            assert w == first[p]       # survivors keep their partitions
        else:
            assert w != "w1"
    assert "w1" not in second.values()


def test_assignor_az_outage_falls_back_cross_az():
    parts = mk_parts(18)
    workers = mk_workers([0, 1, 2, 0, 1, 2])
    for w in workers:
        if w.az == 0:
            w.state = "crashed"        # whole AZ 0 gone
    out = StickyAzAssignor().assign(parts, workers)
    assert len(out) == 18              # nothing is left unowned
    by_id = {w.worker_id: w for w in workers}
    cross = [p for p in parts if by_id[out[p.partition]].az != p.home_az]
    assert {p.home_az for p in cross} == {0}   # only AZ-0 partitions move


# -- cache re-routing --------------------------------------------------------

def test_cache_resize_reroutes_entries_without_flushing():
    cache = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                             store=SimulatedS3(seed=0))
    blobs = {f"blob-{i}": bytes([i]) * 64 for i in range(40)}
    for k, v in blobs.items():
        cache.fill(k, v)
    moved_up = cache.resize(4)
    assert moved_up > 0                          # some keys re-routed...
    assert moved_up < 40                         # ...but not a flush
    for k, v in blobs.items():                   # nothing was lost
        assert cache.probe(k) == v
    hits = cache.stats.hits
    moved_down = cache.resize(1)
    assert cache.stats.reroutes == moved_up + moved_down
    for k, v in blobs.items():
        assert cache.probe(k) == v
    assert cache.stats.hits == hits + 40


# -- rebalance + exactly-once handoff ---------------------------------------

def run_scenario(mode, join_t=0.4, crash_t=0.9, n=3000, **kw):
    eng = make_engine()
    cluster = ElasticCluster(eng, mode=mode, heartbeat_timeout_s=0.15,
                             **kw)
    eng.loop.at(join_t, cluster.add_worker)
    cluster.crash_worker_at(crash_t, "w1")
    submit_all(eng, make_records(n))
    metrics = eng.run()
    return eng, cluster, metrics


def test_cooperative_join_crash_is_exactly_once_bit_identical():
    """The acceptance scenario: a worker joins mid-stream (cooperative
    rebalance), then an original worker crashes (reassignment). Delivery
    must be record-by-record bit-identical to a static-cluster run."""
    static = make_engine()
    submit_all(static, make_records(3000))
    ms = static.run()
    eng, cluster, me = run_scenario("cooperative")
    assert out_multiset(eng) == out_multiset(static)
    assert me.records_delivered == ms.records_delivered == 3000
    assert me.duplicates_delivered == 0
    assert me.records_replayed > 0          # the crash really lost work
    events = [e for e in cluster.rebalancer.events if not e.superseded]
    assert [e.reason for e in events] == ["join", "crash"]
    join_ev = events[0]
    # sticky: the join moves at most the new worker's fair share
    assert 0 < len(join_ev.moved) <= -(-CFG.num_partitions // 5)
    assert cluster.total_lag() == 0


def run_join_only(mode, **kw):
    eng = make_engine()
    cluster = ElasticCluster(eng, mode=mode, heartbeat_timeout_s=0.15,
                             **kw)
    eng.loop.at(0.4, cluster.add_worker)
    submit_all(eng, make_records(3000))
    return eng, cluster, eng.run()


def test_eager_rebalance_pauses_the_world_cooperative_does_not():
    _, coop, mc = run_join_only("cooperative")
    _, eager, me = run_join_only("eager", sync_barrier_s=0.5)
    # both modes stay exactly-once
    assert me.duplicates_delivered == mc.duplicates_delivered == 0
    assert me.records_delivered == mc.records_delivered == 3000
    # during the eager barrier EVERY partition is revoked, so commits
    # publishing into the log find no owner and entries wait for the
    # resume; a cooperative join never pauses unmoved partitions
    assert eager.stats.undeliverable > 0
    assert coop.stats.undeliverable == 0
    assert eager.stats.replayed_entries >= coop.stats.replayed_entries


def test_cooperative_migration_waves_are_incremental():
    eng, cluster, _ = run_scenario("cooperative", migration_batch=1,
                                   migration_interval_s=0.02)
    ev = [e for e in cluster.rebalancer.events if not e.superseded][0]
    # one partition per wave: the join migration is spread over time
    assert ev.ended_at - ev.started_at >= 0.02 * (len(ev.moved) - 1) - 1e-9


def test_handoff_replays_from_committed_offset_and_dedups():
    """Offsets gate the handoff: the new owner replays everything after
    the committed offset; anything the old owner already delivered is
    dropped by the delivery-time dedup."""
    eng = make_engine(n_instances=2)
    cluster = ElasticCluster(eng, heartbeat_timeout_s=0.15)
    p = 0
    owner = cluster.parts[p].owner
    other = next(w.worker_id for w in cluster.membership.alive()
                 if w.worker_id != owner)
    notes = [note(p, f"blob-{i}") for i in range(5)]
    offs = [cluster.publish(n) for n in notes]
    assert offs == [0, 1, 2, 3, 4]
    # old owner delivers 0-2; only 0-1 get committed
    assert all(cluster.on_delivery(notes[i], i, owner) for i in range(2))
    cluster.commit_offsets(eng.loop.now)
    assert cluster.offsets.committed(cluster.GROUP, p) == 2
    assert cluster.on_delivery(notes[2], 2, owner)   # delivered, uncommitted
    # handoff: commits the frontier (now 3) and replays 3..5 to `other`
    replayed = cluster.assign_partition(p, other)
    assert cluster.offsets.committed(cluster.GROUP, p) == 3
    assert replayed == 2
    assert cluster.stats.replayed_entries == 2
    # a duplicate of the already-delivered entry 2 is dropped
    assert not cluster.on_delivery(notes[2], 2, other)
    assert cluster.stats.handoff_duplicates_dropped == 1
    # the replayed tail delivers exactly once
    assert cluster.on_delivery(notes[3], 3, other)
    assert not cluster.on_delivery(notes[3], 3, other)


def test_az_outage_falls_back_to_cross_az_consumption():
    eng = make_engine(n_instances=6)
    cluster = ElasticCluster(eng, heartbeat_timeout_s=0.15)
    cluster.az_outage_at(0.5, 0)
    submit_all(eng, make_records(2400))
    m = eng.run()
    flat = sorted(r.timestamp_us for rs in eng.out.values() for r in rs)
    assert flat == list(range(2400))        # no loss, no duplicates
    assert m.duplicates_delivered == 0
    alive_azs = {w.az for w in cluster.membership.alive()}
    assert 0 not in alive_azs
    # AZ-0 partitions are consumed by out-of-AZ owners now
    for st in cluster.parts.values():
        if st.home_az == 0:
            w = cluster.membership.workers[st.owner]
            assert w.az != 0
    assert cluster.stats.cross_az_deliveries > 0


# -- autoscaler --------------------------------------------------------------

def elastic_cfg(**kw):
    base = dict(n_nodes=2, inst_per_node=2, partitions_factor=3,
                duration_s=3.0, max_interval_s=0.25,
                commit_interval_s=0.25, seed=3)
    base.update(kw)
    return SimConfig(**base)


def test_autoscaler_scales_out_on_spike_and_back_in():
    eng, cluster, s = simulate_elastic(elastic_cfg(), scale=0.001,
                                       spike_factor=3.0)
    acts = [d.action for d in cluster.autoscaler.decisions]
    assert "scale_out" in acts
    assert s["lag_final"] == 0 and s["workers_final"] >= 2
    assert eng.metrics.duplicates_delivered == 0
    # the run pays for worker-time actually used, and reports it
    assert s["infra_cost_usd"] > 0


def test_autoscaler_respects_bounds_and_cooldown():
    pol = AutoscalePolicy(min_workers=2, max_workers=5, cooldown_s=1.0)
    _, cluster, _ = simulate_elastic(elastic_cfg(), scale=0.001,
                                     spike_factor=4.0, policy=pol)
    sizes = [d.workers_after for d in cluster.autoscaler.decisions]
    assert all(2 <= n <= 5 for n in sizes)
    times = [d.t for d in cluster.autoscaler.decisions]
    assert all(b - a >= 1.0 - 1e-9 for a, b in zip(times, times[1:]))


def test_simulate_elastic_crash_recovery_summary():
    eng, cluster, s = simulate_elastic(elastic_cfg(), scale=0.001,
                                       crash_at=2.0)
    assert s["rebalances"] >= 1 and s["partitions_moved"] > 0
    assert s["lag_final"] == 0
    assert eng.metrics.duplicates_delivered == 0
    crashed = [w for w in cluster.membership.workers.values()
               if w.state == "crashed"]
    assert [w.worker_id for w in crashed] == ["w1"]
