"""Blob wire formats: registry, raw-v1 back-compat, columnar-v2
round-trips, typed corruption errors, and the format threaded end to end
through the Batcher/engine."""

import dataclasses
import struct

import numpy as np
import pytest

from repro.core import BlobShuffleConfig, BlobShufflePipeline
from repro.core.blob import ByteRange, build_blob, build_blob_from_buffers, \
    extract, extract_batch
from repro.core.formats import (COLUMNAR_V2, COLUMNAR_V2_INT8, RAW_V1,
                                WIRE_MAGIC, CorruptBlobError,
                                UnknownFormatError, detect_format,
                                get_format, register_format,
                                registered_formats)
from repro.core.formats.codecs import (CODEC_STORED, decode_section,
                                       dequantize_value_arena,
                                       encode_section, quantize_value_arena)
from repro.core.recordbatch import RecordBatch
from repro.core.records import Record, serialize
from repro.core.simulator import SimConfig, simulate_async
from repro.core.workload import WorkloadConfig, generate_batch


def _zipf_wire(n=2000, seed=3) -> bytes:
    wl = WorkloadConfig(arrival_rate=n, duration_s=1.0, record_bytes=128,
                        key_skew=0.5, seed=seed)
    _, batch = generate_batch(wl)
    return bytes(batch.serialize_rows())


def _ragged_records(seed=5, n=60):
    """Ragged keys/values; values are runs of a repeated byte so the
    batch always compresses (v2 must not take the raw fallback here)."""
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(int(rng.integers(0, 24))),
                   bytes([int(rng.integers(0, 256))])
                   * int(rng.integers(0, 80)),
                   int(rng.integers(0, 2 ** 40)))
            for _ in range(n)]


# --- registry ---------------------------------------------------------------

def test_registry_names_and_detection():
    assert {"raw-v1", "columnar-v2",
            "columnar-v2-int8"} <= set(registered_formats())
    assert get_format("raw-v1") is RAW_V1
    assert get_format("columnar-v2") is COLUMNAR_V2
    with pytest.raises(UnknownFormatError):
        get_format("no-such-format")
    # duplicate registrations are rejected on name and on version byte
    with pytest.raises(ValueError):
        register_format(type("Dup", (), {"format_id": 77,
                                         "name": "raw-v1"})())
    with pytest.raises(ValueError):
        register_format(type("Dup2", (), {"format_id": 2,
                                          "name": "fresh-name"})())


def test_detect_format_sniffs_per_block():
    wire = _zipf_wire()
    assert detect_format(wire) is RAW_V1            # headerless -> raw
    assert detect_format(b"") is RAW_V1             # empty block
    block = COLUMNAR_V2.encode_block([wire])[0]
    assert bytes(block[:4]) == WIRE_MAGIC
    assert detect_format(block) is COLUMNAR_V2
    with pytest.raises(UnknownFormatError):
        detect_format(WIRE_MAGIC + bytes([99]) + b"rest")


# --- raw v1 back-compat -----------------------------------------------------

def test_raw_v1_blobs_are_byte_identical_to_legacy():
    """A blob built with fmt=RAW_V1 (and with the default config) must be
    byte-identical to the pre-registry layout: the plain concatenation of
    serialized records."""
    recs = _ragged_records()
    per_part = {0: recs[:30], 1: recs[30:]}
    legacy, legacy_notes = build_blob(per_part, target_az=0, blob_id="b")
    framed, notes = build_blob_from_buffers(
        {p: [serialize(r) for r in rs] for p, rs in per_part.items()},
        target_az=0, blob_id="b", fmt=RAW_V1)
    assert framed.payload == legacy.payload
    assert framed.payload == b"".join(serialize(r) for r in recs)
    assert notes == legacy_notes
    for nt in notes:
        assert extract(framed.payload, nt.byte_range) == \
            per_part[nt.partition]


# --- columnar v2 round-trips ------------------------------------------------

def test_v2_round_trip_zipf_batch_bit_exact_and_compressed():
    wire = _zipf_wire()
    out = COLUMNAR_V2.encode_block([wire])
    assert len(out) == 1 and len(out[0]) < len(wire) // 2
    assert COLUMNAR_V2.decode_block(out[0]) == wire
    batch = COLUMNAR_V2.decode_block_batch(out[0])
    assert bytes(batch.serialize_rows()) == wire


def test_v2_round_trip_ragged_records_bit_exact():
    wire = b"".join(serialize(r) for r in _ragged_records())
    block = COLUMNAR_V2.encode_block([wire])[0]
    assert COLUMNAR_V2.decode_block(block) == wire
    assert COLUMNAR_V2.decode_block_batch(block).to_records() == \
        _ragged_records()


def test_v2_multi_chunk_encode_matches_joined():
    # chunks split on record boundaries (as Batcher buffers do); whether
    # the block arrives as one chunk or sixty must not change the wire
    recs = [serialize(r) for r in _ragged_records()]
    one = COLUMNAR_V2.encode_block([b"".join(recs)])
    many = COLUMNAR_V2.encode_block(recs)
    assert bytes(one[0]) == bytes(many[0])
    assert bytes(one[0][:4]) == WIRE_MAGIC      # actually framed, no fallback


def test_v2_falls_back_to_raw_for_headers_and_incompressible():
    # record headers: v2 does not cover them -> chunks returned unchanged
    with_hdrs = [serialize(Record(b"k", b"v", 1, ((b"h", b"x"),)))]
    assert COLUMNAR_V2.encode_block(with_hdrs) is with_hdrs
    # a single incompressible record: encoding cannot pay for its framing
    rng = np.random.default_rng(9)
    lone = [serialize(Record(rng.bytes(8), rng.bytes(200), 7))]
    out = COLUMNAR_V2.encode_block(lone)
    assert b"".join(bytes(c) for c in out) == lone[0]
    # empty block stays empty
    assert COLUMNAR_V2.encode_block([b""]) == [b""]


def test_v2_int8_variant_is_lossy_but_decodable_by_canonical_decoder():
    rng = np.random.default_rng(11)
    vals = rng.normal(size=(50, 16)).astype("<f4")
    recs = [Record(int(i % 7).to_bytes(8, "little"), vals[i].tobytes(), i)
            for i in range(50)]
    wire = b"".join(serialize(r) for r in recs)
    block = COLUMNAR_V2_INT8.encode_block([wire])[0]
    # the canonical v2 decoder handles the int8 flag (shared version byte)
    back = COLUMNAR_V2.decode_block_batch(block)
    got = np.frombuffer(back.value_arena, "<f4").reshape(50, 16)
    err = np.abs(got - vals).max() / np.abs(vals).max()
    assert err < 0.02
    assert back.to_records()[3].key == recs[3].key
    assert back.to_records()[3].timestamp_us == 3


def test_int8_value_codec_matches_jax_twin():
    jax = pytest.importorskip("jax")
    from repro.shuffle.compression import int8_quantize
    rng = np.random.default_rng(13)
    arena = rng.normal(size=(40, 8)).astype("<f4")
    q, s = quantize_value_arena(arena.view(np.uint8).reshape(-1), 32)
    qj, sj = int8_quantize(jax.numpy.asarray(arena))
    np.testing.assert_array_equal(q, np.asarray(qj))
    np.testing.assert_allclose(s, np.asarray(sj), rtol=1e-6)
    back = dequantize_value_arena(q, s, 32)
    assert back.shape == (40 * 32,)


# --- corruption and typed errors --------------------------------------------

def test_truncated_v2_block_raises_corrupt():
    block = COLUMNAR_V2.encode_block([_zipf_wire()])[0]
    for cut in (5, 13, 14, 20, len(block) // 2, len(block) - 1):
        with pytest.raises(CorruptBlobError):
            COLUMNAR_V2.decode_block_batch(block[:cut])


def test_trailing_garbage_and_bad_flags_raise_corrupt():
    block = bytes(COLUMNAR_V2.encode_block([_zipf_wire()])[0])
    with pytest.raises(CorruptBlobError):
        COLUMNAR_V2.decode_block_batch(block + b"garbage")
    bad_flags = block[:5] + bytes([0x80 | block[5]]) + block[6:]
    with pytest.raises(CorruptBlobError):
        COLUMNAR_V2.decode_block_batch(bad_flags)


def test_wrong_magic_routes_to_raw_and_unknown_version_is_typed():
    block = bytes(COLUMNAR_V2.encode_block([_zipf_wire()])[0])
    # magic damaged -> sniffed as headerless raw v1 (and then fails to
    # parse as records, which is a plain struct error, not silence)
    assert detect_format(b"XSWF" + block[4:]) is RAW_V1
    with pytest.raises(UnknownFormatError):
        extract(WIRE_MAGIC + bytes([250]) + block[5:],
                ByteRange(0, len(block)))


def test_section_codec_truncation_and_unknown_codec():
    framed = encode_section(b"x" * 100)
    raw, off = decode_section(memoryview(framed), 0)
    assert raw == b"x" * 100 and off == len(framed)
    with pytest.raises(CorruptBlobError):
        decode_section(memoryview(framed[:-1]), 0)
    with pytest.raises(CorruptBlobError):
        decode_section(memoryview(b"\x07" + framed[1:]), 0)   # codec id 7
    hdr = struct.Struct("<BII")
    lie = hdr.pack(CODEC_STORED, 4, 9) + b"abcd"   # enc_len != raw_len
    with pytest.raises(CorruptBlobError):
        decode_section(memoryview(lie), 0)


# --- custom format registration ---------------------------------------------

class _XorFormat:
    """Toy custom format: frame + XOR-0x5A payload (order-preserving)."""
    format_id = 201
    name = "test-xor"

    def encode_block(self, chunks):
        wire = b"".join(bytes(c) for c in chunks)
        body = bytes(b ^ 0x5A for b in wire)
        return [WIRE_MAGIC + bytes([self.format_id]) + body]

    def decode_block(self, block):
        mv = memoryview(block)
        return bytes(b ^ 0x5A for b in bytes(mv[5:]))

    def decode_block_batch(self, block):
        return RecordBatch.from_buffer(self.decode_block(block))


def test_custom_format_registers_and_round_trips_through_blob():
    if "test-xor" not in registered_formats():
        register_format(_XorFormat())
    fmt = get_format("test-xor")
    recs = _ragged_records(seed=21)
    blob, notes = build_blob_from_buffers(
        {0: [serialize(r) for r in recs]}, target_az=0, fmt=fmt)
    assert detect_format(blob.payload) is fmt
    assert extract(blob.payload, notes[0].byte_range) == recs
    assert extract_batch(blob.payload,
                         notes[0].byte_range).to_records() == recs


# --- threaded through Batcher / engine --------------------------------------

def test_batcher_config_rejects_unknown_wire_format():
    from repro.core.pipeline import BlobShufflePipeline as P
    with pytest.raises(UnknownFormatError):
        P(BlobShuffleConfig(wire_format="typo-v9"), n_instances=1)


def test_pipeline_delivers_identical_records_raw_vs_v2():
    rng = np.random.default_rng(31)
    recs = [Record(int(rng.zipf(1.5) % 50).to_bytes(8, "little"),
                   bytes(64), i) for i in range(600)]

    def run(fmt):
        pipe = BlobShufflePipeline(
            BlobShuffleConfig(batch_bytes=8 * 1024, num_partitions=6,
                              num_az=1, wire_format=fmt),
            n_instances=2, seed=0)
        out = pipe.run(recs, commit_every=200)
        return out, pipe.store.stats.put_bytes

    out_raw, shipped_raw = run("raw-v1")
    out_v2, shipped_v2 = run("columnar-v2")
    # content-identical delivery per partition (blob size changes PUT
    # latency, so arrival *order* may differ — compare as multisets)
    assert set(out_raw) == set(out_v2)
    for part in out_raw:
        assert sorted(serialize(r) for r in out_raw[part]) == \
            sorted(serialize(r) for r in out_v2[part])
    assert sum(len(v) for v in out_raw.values()) == len(recs)
    assert shipped_v2 < shipped_raw              # and it actually compressed


def test_engine_v2_reduces_shipped_bytes_with_same_delivery():
    base = SimConfig(n_nodes=2, inst_per_node=1, duration_s=2.0,
                     warmup_s=0.0, offered_gib_s=0.02,
                     batch_bytes=128 * 1024)
    eng_raw, _ = simulate_async(base, scale=1.0, ingest_batch_records=256)
    eng_v2, _ = simulate_async(
        dataclasses.replace(base, wire_format="columnar-v2"), scale=1.0,
        ingest_batch_records=256)
    raw_delivered = sum(d.stats.records_out for d in eng_raw.debatchers)
    v2_delivered = sum(d.stats.records_out for d in eng_v2.debatchers)
    assert raw_delivered == v2_delivered > 0
    logical = sum(b.stats.bytes_in for b in eng_v2.batchers)
    assert eng_v2.store.stats.put_bytes < logical // 2
    assert eng_raw.store.stats.put_bytes == \
        sum(b.stats.bytes_in for b in eng_raw.batchers)


# -- CODEC_CONST edge cases (section codec negotiation boundaries) ---------

from repro.core.formats.codecs import CODEC_CONST  # noqa: E402


def _stored_reference(raw: bytes) -> bytes:
    """Round-trip through the never-compress (stored) path — the byte
    oracle every negotiated encoding must reproduce exactly."""
    out, nxt = decode_section(
        memoryview(encode_section(raw, try_compress=False)), 0)
    assert out == raw
    return out


def _codec_of(enc: bytes) -> int:
    return enc[0]


def test_const_period_not_dividing_arena_length():
    # 8-byte repeating pattern but a 20-byte arena: 20 % 8 != 0, and the
    # truncated tail also breaks the shorter probed periods — the const
    # codec must NOT fire, and the negotiated encoding (zlib or stored)
    # must still round-trip byte-identically
    pattern = bytes(range(1, 9))
    raw = (pattern * 3)[:20]
    enc = encode_section(raw)
    assert _codec_of(enc) != CODEC_CONST
    out, _ = decode_section(memoryview(enc), 0)
    assert out == raw == _stored_reference(raw)


def test_const_period_with_aligned_repeats_fires_and_round_trips():
    # the same pattern tiled a whole number of times DOES fire, stores
    # only one period, and inflates back bit-exactly
    pattern = bytes(range(1, 9))
    raw = pattern * 5
    enc = encode_section(raw)
    assert _codec_of(enc) == CODEC_CONST
    assert len(enc) == 9 + 8            # header + one period
    out, nxt = decode_section(memoryview(enc), 0)
    assert out == raw == _stored_reference(raw)
    assert nxt == len(enc)


def test_const_period_longer_than_arena_falls_back():
    # 10 distinct bytes: every probed period is either non-dividing or
    # longer than half the arena (n < 2p) — no constant encoding exists
    raw = bytes([7, 1, 250, 3, 99, 5, 180, 2, 41, 13])
    enc = encode_section(raw)
    assert _codec_of(enc) != CODEC_CONST
    out, _ = decode_section(memoryview(enc), 0)
    assert out == raw == _stored_reference(raw)


def test_all_same_arena_encodes_const_at_longest_admissible_period():
    # a 10-byte all-same arena: p=8 and p=4 don't divide 10, so the
    # longest-first probe lands on p=2 — CONST fires with a 2-byte
    # pattern (the probe order prefers longer periods, not shorter)
    raw = b"\x55" * 10
    enc = encode_section(raw)
    assert _codec_of(enc) == CODEC_CONST
    assert len(enc) == 9 + 2
    out, _ = decode_section(memoryview(enc), 0)
    assert out == raw == _stored_reference(raw)


@pytest.mark.parametrize("raw", [b"", b"\x00", b"\xff", b"ab"])
def test_tiny_arenas_store_verbatim(raw):
    # at or below the 9-byte section header there is nothing to win:
    # 1-byte (and empty) arenas must take the stored path and round-trip
    enc = encode_section(raw)
    assert _codec_of(enc) == CODEC_STORED
    out, nxt = decode_section(memoryview(enc), 0)
    assert out == raw == _stored_reference(raw)
    assert nxt == len(enc) == 9 + len(raw)


def test_const_vs_zlib_vs_stored_all_byte_identical_on_boundary_sizes():
    # sweep the negotiation boundary: for every size around the header
    # floor and the 2p admission threshold, whatever codec wins must
    # reproduce the stored oracle bit for bit
    for n in (1, 8, 9, 10, 15, 16, 17, 24):
        for fill in (b"\x00", b"\xa7", bytes(range(256))[:max(n, 1)]):
            raw = (fill * (n // len(fill) + 1))[:n]
            out, _ = decode_section(memoryview(encode_section(raw)), 0)
            assert out == raw == _stored_reference(raw), (n, fill[:4])
