"""Per-architecture smoke tests: reduced config of the same family, one
forward / train / decode step on CPU, asserting shapes + no NaNs."""


import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.models.common import applicable_shapes, init_params
from repro.training import OptConfig, TrainConfig, adamw_init, make_train_step


def make_batch(cfg, B, S, key, labels=False):
    ks = jax.random.split(key, 3)
    mm = cfg.multimodal
    if mm is not None and mm.kind == "audio":
        batch = {"frames": jax.random.normal(
            ks[0], (B, S, cfg.d_model), jnp.bfloat16)}
    elif mm is not None and mm.kind == "vision":
        P = mm.num_patches
        batch = {"tokens": jax.random.randint(
            ks[0], (B, S - P), 0, cfg.vocab_size),
            "patches": jax.random.normal(
                ks[1], (B, P, cfg.d_model), jnp.bfloat16)}
    else:
        batch = {"tokens": jax.random.randint(
            ks[0], (B, S), 0, cfg.vocab_size)}
    if labels:
        batch["labels"] = jax.random.randint(ks[2], (B, S), 0,
                                             cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    B, S = 2, 32
    batch = make_batch(cfg, B, S, jax.random.key(1))
    logits, aux = lm.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=1e-3), microbatches=1,
                       remat="full")
    step = make_train_step(cfg, tcfg)
    B, S = 2, 32
    batch = make_batch(cfg, B, S, jax.random.key(1), labels=True)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert metrics["grad_norm"] > 0
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).has_decode])
def test_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    B, MAXS = 2, 16
    cache = jax.tree.map(
        jnp.zeros_like,
        init_params(lm.cache_defs(cfg, B, MAXS), jax.random.key(1)))
    toks = jnp.ones((B, 1), jnp.int32)
    for t in range(3):
        logits, cache = lm.decode_step(
            cfg, params, cache, {"tokens": toks, "pos": jnp.int32(t)})
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not jnp.isnan(logits).any()


def test_encoder_has_no_decode_shapes():
    cfg = get_config("hubert-xlarge")
    names = {s.name for s in applicable_shapes(cfg)}
    assert names == {"train_4k", "prefill_32k"}


def test_full_attention_archs_skip_long():
    for arch in ("starcoder2-3b", "gemma-2b", "qwen2-72b",
                 "deepseek-v2-lite-16b", "llava-next-34b"):
        names = {s.name for s in applicable_shapes(get_config(arch))}
        assert "long_500k" not in names


def test_sub_quadratic_archs_run_long():
    for arch in ("mamba2-130m", "zamba2-2.7b"):
        names = {s.name for s in applicable_shapes(get_config(arch))}
        assert "long_500k" in names


def test_exact_assigned_configs():
    """The full configs match the assignment table exactly."""
    c = get_config("qwen2-72b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (80, 8192, 64, 8, 29568, 152064)
    c = get_config("deepseek-v2-lite-16b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (64, 6, 2)
    assert c.mla.kv_lora_rank == 512
    c = get_config("gemma-2b")
    assert (c.num_kv_heads, c.resolved_head_dim, c.vocab_size) == \
        (1, 256, 256000)
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.ssm.d_state) == (54, 64)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.moe.num_experts, c.moe.top_k, c.moe.num_shared) == (60, 4, 4)
    c = get_config("hubert-xlarge")
    assert c.kind == "encoder" and c.vocab_size == 504
