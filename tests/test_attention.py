"""Attention: flash (custom VJP) vs dense oracle — values and gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import dense_attention, flash_attention_jnp
from repro.models.flash import flash_attention


def rand_qkv(key, B, Sq, Skv, H, KVH, D, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, H, D), dtype)
    k = jax.random.normal(k2, (B, Skv, KVH, D), dtype)
    v = jax.random.normal(k3, (B, Skv, KVH, D), dtype)
    return q, k, v


CASES = [
    # B, Sq, Skv, H, KVH, D, causal, qc, kc
    (2, 128, 128, 4, 4, 32, True, 32, 64),
    (2, 128, 128, 4, 2, 32, True, 64, 32),    # GQA
    (1, 96, 96, 4, 1, 16, True, 32, 32),      # MQA, padding (96 % 64)
    (2, 128, 128, 4, 4, 32, False, 32, 64),   # bidirectional (encoder)
    (1, 64, 64, 2, 2, 64, True, 64, 64),      # single block
    (2, 200, 200, 2, 2, 16, True, 64, 64),    # non-divisible lengths
]


@pytest.mark.parametrize("B,Sq,Skv,H,KVH,D,causal,qc,kc", CASES)
def test_flash_matches_dense(B, Sq, Skv, H, KVH, D, causal, qc, kc):
    q, k, v = rand_qkv(jax.random.key(0), B, Sq, Skv, H, KVH, D)
    ref = dense_attention(q, k, v, causal=causal)
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Skv,H,KVH,D,causal,qc,kc", CASES[:4])
def test_flash_gradients_match_dense(B, Sq, Skv, H, KVH, D, causal, qc, kc):
    q, k, v = rand_qkv(jax.random.key(1), B, Sq, Skv, H, KVH, D)

    def loss_flash(q, k, v):
        o = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    def loss_dense(q, k, v):
        o = dense_attention(q, k, v, causal=causal)
        return jnp.sum(jnp.sin(o.astype(jnp.float32)))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gd):
        np.testing.assert_allclose(a, b, atol=3e-4, rtol=3e-4)


def test_flash_bf16_close_to_fp32_dense():
    q, k, v = rand_qkv(jax.random.key(2), 2, 256, 256, 4, 2, 64,
                       jnp.bfloat16)
    ref = dense_attention(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), causal=True)
    out = flash_attention(q, k, v, causal=True, q_chunk=64, kv_chunk=128)
    np.testing.assert_allclose(out.astype(jnp.float32), ref, atol=2e-2)


def test_legacy_chunked_matches_dense():
    """The original loop-based oracle (kept for the Pallas kernel tests)."""
    q, k, v = rand_qkv(jax.random.key(3), 2, 128, 128, 4, 4, 32)
    ref = dense_attention(q, k, v, causal=True)
    out = flash_attention_jnp(q, k, v, causal=True, q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_decode_offset_matches_prefix():
    """q_offset semantics: one-token attention == last row of full attn."""
    B, S, H, D = 2, 64, 4, 32
    q, k, v = rand_qkv(jax.random.key(4), B, S, S, H, H, D)
    full = dense_attention(q, k, v, causal=True)
    one = dense_attention(q[:, -1:], k, v, causal=True, q_offset=S - 1)
    np.testing.assert_allclose(one[:, 0], full[:, -1], atol=1e-5)
