"""Mamba2 SSD: chunked (matmul) form vs naive recurrence; decode chain."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.ssm import ssd_chunked, ssd_decode_step, ssd_reference


def rand_inputs(key, b, S, H, P, G, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    return x, dt, A, B, C


@pytest.mark.parametrize("b,S,H,P,G,N,chunk", [
    (2, 64, 4, 8, 1, 16, 16),
    (1, 60, 4, 8, 2, 16, 16),   # padding (60 % 16), grouped B/C
    (2, 32, 2, 4, 1, 8, 32),    # single chunk
    (1, 128, 8, 16, 4, 32, 64),
])
def test_chunked_matches_reference(b, S, H, P, G, N, chunk):
    x, dt, A, B, C = rand_inputs(jax.random.key(0), b, S, H, P, G, N)
    y_ref, st_ref = ssd_reference(x, dt, A, B, C)
    y_chk, st_chk = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y_chk, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(st_chk, st_ref, atol=1e-4, rtol=1e-4)


def test_chunked_gradients_match_reference():
    x, dt, A, B, C = rand_inputs(jax.random.key(1), 1, 48, 2, 4, 1, 8)

    def loss(fn, *args):
        y, _ = fn(*args)
        return jnp.sum(jnp.tanh(y))

    g_ref = jax.grad(lambda x: loss(ssd_reference, x, dt, A, B, C))(x)
    g_chk = jax.grad(
        lambda x: loss(lambda *a: ssd_chunked(*a, chunk=16),
                       x, dt, A, B, C))(x)
    np.testing.assert_allclose(g_chk, g_ref, atol=1e-4, rtol=1e-4)


def test_decode_chain_matches_full_sequence():
    """Stepwise decode through the state == full-sequence scan."""
    b, S, H, P, G, N = 1, 24, 2, 4, 1, 8
    x, dt, A, B, C = rand_inputs(jax.random.key(2), b, S, H, P, G, N)
    y_full, state_full = ssd_reference(x, dt, A, B, C)
    state = jnp.zeros((b, H, P, N))
    ys = []
    for t in range(S):
        y_t, state = ssd_decode_step(state, x[:, t], dt[:, t], A,
                                     B[:, t], C[:, t])
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_step, y_full, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(state, state_full, atol=1e-4, rtol=1e-4)


def test_initial_state_continuation():
    """Splitting a sequence in half with state carry == one pass."""
    b, S, H, P, G, N = 1, 64, 2, 4, 1, 8
    x, dt, A, B, C = rand_inputs(jax.random.key(3), b, S, H, P, G, N)
    y_full, _ = ssd_chunked(x, dt, A, B, C, chunk=16)
    half = S // 2
    y1, st1 = ssd_chunked(x[:, :half], dt[:, :half], A, B[:, :half],
                          C[:, :half], chunk=16)
    y2, _ = ssd_chunked(x[:, half:], dt[:, half:], A, B[:, half:],
                        C[:, half:], chunk=16, initial_state=st1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], axis=1), y_full,
                               atol=1e-4, rtol=1e-4)


@settings(deadline=None, max_examples=20)
@given(S=st.integers(4, 80), chunk=st.sampled_from([8, 16, 32]))
def test_property_chunked_equals_reference_any_length(S, chunk):
    x, dt, A, B, C = rand_inputs(jax.random.key(5), 1, S, 2, 4, 1, 8)
    y_ref, _ = ssd_reference(x, dt, A, B, C)
    y_chk, _ = ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y_chk, y_ref, atol=2e-4, rtol=2e-4)
