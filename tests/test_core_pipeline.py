"""End-to-end BlobShuffle pipeline: correctness, commit protocol,
failure/replay semantics, batching triggers, retention."""

import numpy as np

from repro.core import (Batcher, BlobShuffleConfig, BlobShufflePipeline,
                        DistributedCache, Record, SimulatedS3,
                        default_partitioner)

CFG = BlobShuffleConfig(batch_bytes=4096, max_interval_s=5.0,
                        num_partitions=9, num_az=3)


def make_records(n, vsize=100, seed=0):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(vsize), timestamp_us=i)
            for i, n_ in zip(range(n), range(n))]


def test_shuffle_routes_all_records_to_correct_partition():
    recs = make_records(500)
    pipe = BlobShufflePipeline(CFG, n_instances=6)
    out = pipe.run(recs, commit_every=100)
    flat = [r for part in out.values() for r in part]
    assert len(flat) == len(recs)
    for part, rs in out.items():
        for r in rs:
            assert default_partitioner(r.key, CFG.num_partitions) == part
    assert sorted(r.timestamp_us for r in flat) == list(range(len(recs)))


def test_records_for_partition_are_grouped_per_blob():
    """All data for one partition within a blob is one contiguous range."""
    recs = make_records(300)
    pipe = BlobShufflePipeline(CFG, n_instances=3)
    out = pipe.run(recs, commit_every=50)
    assert sum(len(v) for v in out.values()) == 300


def test_failure_before_commit_replays_exactly_once():
    """Crash before commit: at-least-once upstream (replay), exactly-once
    downstream (blob/partition dedup at the Debatcher)."""
    recs = make_records(400)
    pipe = BlobShufflePipeline(CFG, n_instances=4, exactly_once=True)
    out = pipe.run(recs, commit_every=100, fail_instance_before_commit=2)
    flat = [r.timestamp_us for part in out.values() for r in part]
    assert sorted(flat) == list(range(400))  # no loss, no duplicates


def test_at_least_once_without_dedup_can_duplicate():
    recs = make_records(400)
    pipe = BlobShufflePipeline(CFG, n_instances=4, exactly_once=False)
    out = pipe.run(recs, commit_every=100, fail_instance_before_commit=2)
    flat = [r.timestamp_us for part in out.values() for r in part]
    assert set(flat) == set(range(400))      # no loss
    assert len(flat) >= 400                  # duplicates allowed


def test_batcher_finalizes_on_size():
    store = SimulatedS3()
    cache = DistributedCache(0, 1, 1 << 20, store)
    b = Batcher(BlobShuffleConfig(batch_bytes=1000, num_partitions=3,
                                  num_az=1),
                lambda p: 0, lambda k: default_partitioner(k, 3), cache)
    recs = make_records(50, vsize=100)
    for i, r in enumerate(recs):
        b.process(r, now=float(i) * 1e-3)
    assert b.stats.finalize_size >= 1
    assert store.stats.puts == b.stats.blobs


def test_batcher_finalizes_on_interval():
    store = SimulatedS3()
    cache = DistributedCache(0, 1, 1 << 20, store)
    b = Batcher(BlobShuffleConfig(batch_bytes=1 << 30, max_interval_s=1.0,
                                  num_partitions=3, num_az=1),
                lambda p: 0, lambda k: default_partitioner(k, 3), cache)
    b.process(Record(b"k1", b"v"), now=0.0)
    b.process(Record(b"k2", b"v"), now=2.0)  # > max interval
    assert b.stats.finalize_interval == 1


def test_commit_blocks_until_uploads_durable():
    store = SimulatedS3(seed=1)
    cache = DistributedCache(0, 1, 1 << 20, store)
    b = Batcher(BlobShuffleConfig(batch_bytes=1 << 30, num_partitions=3,
                                  num_az=1),
                lambda p: 0, lambda k: default_partitioner(k, 3), cache)
    b.process(Record(b"k1", b"v" * 100), now=0.0)
    notes, blocked = b.on_commit(now=0.0)
    assert b.stats.finalize_commit == 1
    assert blocked > 0          # waited for the async upload
    assert len(notes) >= 1      # notifications released at commit
    assert not b.pending


def test_orphaned_blobs_collected_by_retention():
    store = SimulatedS3(retention_s=10.0)
    store.put("orphan", b"x" * 100, now=0.0)
    assert store.contains("orphan")
    removed = store.run_retention(now=100.0)
    assert removed == 1 and not store.contains("orphan")
