"""Property tests for the streaming quantile sketch (repro.obs.sketch):
relative error vs np.percentile across distributions, lossless merge,
vectorized-ingest consistency, and bounded memory under collapse.

The randomized sweep below is seeded and always runs; when Hypothesis is
installed an adversarial generator layer runs on top of it.
"""

import math

import numpy as np
import pytest

from repro.obs.sketch import QuantileSketch

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

QS = (0, 1, 10, 25, 50, 75, 90, 95, 99, 100)
#: the sketch's guarantee is alpha (=1%) relative error; the acceptance
#: bound for this PR is 2%
REL_ERR = 0.02


def _assert_close(sk, data, qs=QS, rel=REL_ERR):
    exact = np.percentile(data, qs)
    got = sk.percentiles(list(qs))
    for q, e, g in zip(qs, exact, got):
        if e == 0.0:
            assert abs(g) <= 1e-9, (q, e, g)
        else:
            assert abs(g - e) <= rel * abs(e), (q, e, g, rel)


def _distributions(rng):
    """One draw of every shape that has historically broken quantile
    estimators: heavy tails, huge gaps, duplicates, tiny n, constants."""
    n = int(rng.integers(1, 5000))
    return [
        rng.lognormal(mean=-3.0, sigma=1.5, size=n),          # latency-like
        rng.uniform(1e-6, 1e3, size=n),                       # 9 decades
        np.concatenate([rng.uniform(0.001, 0.002, size=n),
                        rng.uniform(500.0, 600.0, size=max(1, n // 10))]),
        np.repeat(rng.uniform(0.1, 10.0, size=max(1, n // 50)), 50)[:n + 1],
        np.full(n, float(rng.uniform(1e-4, 1e4))),            # constant
        rng.exponential(scale=0.05, size=n),
        np.abs(rng.standard_cauchy(size=n)) + 1e-9,           # heavy tail
    ]


@pytest.mark.parametrize("seed", range(8))
def test_sketch_percentiles_track_np_percentile(seed):
    rng = np.random.default_rng(seed)
    for data in _distributions(rng):
        sk = QuantileSketch()
        sk.add_many(data)
        assert sk.count == len(data)
        _assert_close(sk, data)


def test_zero_and_tiny_values_route_to_the_zero_bucket():
    data = np.array([0.0, 0.0, 1e-12, 0.5, 1.0, 2.0])
    sk = QuantileSketch()
    for x in data:
        sk.add(x)
    assert sk.zero_count == 3
    assert sk.percentile(0) == 0.0
    _assert_close(sk, data, qs=(50, 75, 100))


@pytest.mark.parametrize("seed", range(4))
def test_merge_is_lossless(seed):
    """merge(a, b) answers like one sketch that saw both streams — the
    property the windowed registry histograms rely on."""
    rng = np.random.default_rng(100 + seed)
    a_data = rng.lognormal(-3, 1.2, size=int(rng.integers(1, 2000)))
    b_data = rng.uniform(1e-3, 50.0, size=int(rng.integers(1, 2000)))
    a, b, whole = QuantileSketch(), QuantileSketch(), QuantileSketch()
    a.add_many(a_data)
    b.add_many(b_data)
    whole.add_many(np.concatenate([a_data, b_data]))
    a.merge(b)
    assert a.count == whole.count
    assert a.sum == pytest.approx(whole.sum)
    for q in QS:
        assert a.percentile(q) == pytest.approx(whole.percentile(q))


@pytest.mark.parametrize("seed", range(4))
def test_add_many_matches_scalar_add(seed):
    rng = np.random.default_rng(200 + seed)
    data = rng.lognormal(-2, 2.0, size=777)
    vec, sca = QuantileSketch(), QuantileSketch()
    vec.add_many(data)
    for x in data:
        sca.add(float(x))
    assert vec.count == sca.count
    assert vec.sum == pytest.approx(sca.sum)
    assert vec.percentiles(list(QS)) == pytest.approx(
        sca.percentiles(list(QS)))


def test_add_weighted_matches_repeated_add():
    w, r = QuantileSketch(), QuantileSketch()
    for x, n in ((0.003, 40), (0.2, 7), (11.0, 3)):
        w.add_weighted(x, n)
        for _ in range(n):
            r.add(x)
    assert w.count == r.count == 50
    assert w.percentiles([50, 95]) == pytest.approx(r.percentiles([50, 95]))


def test_memory_stays_bounded_under_collapse():
    """max_bins caps the bucket table; the low buckets collapse and only
    low quantiles degrade — the tail estimates keep their guarantee."""
    sk = QuantileSketch(max_bins=128)
    rng = np.random.default_rng(7)
    data = rng.uniform(1e-9, 1e9, size=20000)   # 18 decades >> 128 bins
    sk.add_many(data)
    assert len(sk._bins) <= 128
    exact99 = np.percentile(data, 99)
    assert abs(sk.percentile(99) - exact99) <= REL_ERR * exact99


def test_empty_sketch_answers_none():
    sk = QuantileSketch()
    assert sk.count == 0
    assert sk.percentile(50) is None
    assert sk.percentiles([50, 95]) == [None, None]


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.floats(min_value=0.0, max_value=1e12,
                              allow_nan=False, allow_infinity=False),
                    min_size=1, max_size=500),
           st.sampled_from(QS))
    def test_sketch_hypothesis_relative_error(data, q):
        arr = np.asarray(data, dtype=np.float64)
        sk = QuantileSketch()
        sk.add_many(arr)
        exact = float(np.percentile(arr, q))
        got = sk.percentile(q)
        if exact <= 1e-9:
            assert got == pytest.approx(exact, abs=1e-9)
        else:
            assert abs(got - exact) <= REL_ERR * exact
