"""Training substrate: microbatch equivalence, optimizer behavior, loss
masking, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.common import init_params
from repro.training import (OptConfig, TrainConfig, adamw_init,
                            make_loss_fn, make_train_step)
from repro.training.optimizer import global_norm, schedule
from repro.training.train_step import IGNORE, cross_entropy, _grads


def setup(arch="granite-3-2b"):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    k = jax.random.key(7)
    toks = jax.random.randint(k, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    return cfg, params, batch


def test_microbatch_grad_accumulation_matches_full_batch():
    """mean-of-microbatch-grads == full-batch grads (linearity of CE mean
    over equal-sized microbatches)."""
    cfg, params, batch = setup()
    loss_fn = make_loss_fn(cfg, TrainConfig())
    g1, m1 = _grads(loss_fn, params, batch, 1)
    g4, m4 = _grads(loss_fn, params, batch, 4)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=2e-3)
    r1, r4 = jax.tree.leaves(g1), jax.tree.leaves(g4)
    n1, n4 = float(global_norm(g1)), float(global_norm(g4))
    assert n1 == pytest.approx(n4, rel=2e-2)
    for a, b in zip(r1, r4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_cross_entropy_ignore_mask():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, IGNORE, IGNORE]])
    ce = cross_entropy(logits, labels)
    assert float(ce) == pytest.approx(np.log(8), rel=1e-5)


def test_cross_entropy_zero_when_certain():
    logits = jnp.full((1, 2, 4), -30.0)
    logits = logits.at[0, 0, 1].set(30.0).at[0, 1, 2].set(30.0)
    ce = cross_entropy(logits, jnp.array([[1, 2]]))
    assert float(ce) < 1e-5


def test_schedule_warmup_and_decay():
    cfg = OptConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert float(schedule(cfg, jnp.int32(10))) == pytest.approx(1e-3,
                                                                rel=1e-3)
    end = float(schedule(cfg, jnp.int32(100)))
    assert end == pytest.approx(1e-4, rel=1e-2)  # min_lr_frac


def test_grad_clip_bounds_update():
    cfg, params, batch = setup()
    tcfg = TrainConfig(opt=OptConfig(learning_rate=1e-3, grad_clip=1e-6))
    step = jax.jit(make_train_step(cfg, tcfg))
    p2, _, m = step(params, adamw_init(params), batch)
    # clipped to ~nothing: params barely move
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta < 2e-3  # lr * (step_norm ~ 1) bound


def test_loss_decreases_short_run():
    cfg, params, batch = setup("starcoder2-3b")
    tcfg = TrainConfig(opt=OptConfig(learning_rate=3e-3, warmup_steps=2,
                                     total_steps=40))
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = adamw_init(params)
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch)  # overfit one batch
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8
