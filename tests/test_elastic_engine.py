"""Engine-level elasticity: dynamic instance sets, AZ-aware notification
latency, and crash/recovery edges (post-commit failure, crash racing a
hedged GET)."""

import numpy as np

from repro.cluster import ElasticCluster
from repro.core import (AsyncShuffleEngine, BlobShuffleConfig,
                        EngineConfig, Record)

CFG = BlobShuffleConfig(batch_bytes=64 * 1024, max_interval_s=0.5,
                        num_partitions=9, num_az=3)


def make_records(n, vsize=200, seed=0):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(vsize), timestamp_us=i)
            for i in range(n)]


def delivered_ids(eng):
    return sorted(r.timestamp_us for rs in eng.out.values() for r in rs)


# -- dynamic instance set ---------------------------------------------------

def test_add_instance_mid_stream_receives_traffic():
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=3, seed=0)
    eng.loop.at(0.02, eng.add_instance)
    for i, rec in enumerate(make_records(800)):
        eng.submit(i * 1e-4, rec)       # arrivals span [0, 0.08]
    m = eng.run()
    assert delivered_ids(eng) == list(range(800))
    assert m.duplicates_delivered == 0
    assert eng.n_instances == 4
    # the joined instance took a share of the post-join arrivals
    assert eng.batchers[3].stats.records_in > 0


def test_remove_instance_drains_gracefully():
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=4, seed=0)
    eng.loop.at(0.03, eng.remove_instance, 1)
    for i, rec in enumerate(make_records(800)):
        eng.submit(i * 1e-4, rec)
    m = eng.run()
    # everything the instance had buffered was flushed + committed: no
    # loss, no duplicates, and no replay was needed
    assert delivered_ids(eng) == list(range(800))
    assert m.duplicates_delivered == 0 and m.records_replayed == 0
    assert not eng.active[1]
    n_before = eng.batchers[1].stats.records_in
    assert n_before < 800 / 4 + 50      # it stopped receiving traffic


# -- cross-AZ notification latency (satellite) -------------------------------

def run_with_extra(extra, num_az=3, seed=2):
    cfg = BlobShuffleConfig(batch_bytes=64 * 1024, max_interval_s=0.5,
                            num_partitions=9, num_az=num_az)
    eng = AsyncShuffleEngine(
        cfg, EngineConfig(cross_az_notification_extra_s=extra),
        n_instances=6, seed=seed)
    for i, rec in enumerate(make_records(600)):
        eng.submit(i * 1e-4, rec)
    return eng, eng.run()


def test_cross_az_extra_zero_is_bit_identical_to_default():
    _, base = run_with_extra(0.0)
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=6, seed=2)
    for i, rec in enumerate(make_records(600)):
        eng.submit(i * 1e-4, rec)
    default = eng.run()
    assert base.makespan_s == default.makespan_s
    assert base.record_latencies == default.record_latencies


def test_cross_az_extra_delays_only_cross_az_notifications():
    _, base = run_with_extra(0.0)
    _, slow = run_with_extra(0.050)
    assert slow.records_delivered == base.records_delivered == 600
    # with 3 AZs most notifications cross: latencies must shift up
    assert np.median(slow.record_latencies) \
        > np.median(base.record_latencies)
    # single-AZ topology has no crossings: the knob must be a no-op
    _, a = run_with_extra(0.0, num_az=1)
    _, b = run_with_extra(0.050, num_az=1)
    assert a.makespan_s == b.makespan_s
    assert a.record_latencies == b.record_latencies


# -- crash/recovery edges (satellite) ---------------------------------------

def test_failure_after_commit_does_not_replay_or_duplicate():
    """A crash AFTER a completed commit must not replay the committed
    records: the coordinator's uncommitted window is empty."""
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=4, seed=0,
                             exactly_once=True)
    for i, rec in enumerate(make_records(300)):
        eng.submit(i * 1e-5, rec, inst=i % 4)
    eng.commit_at(0.01)
    eng.fail_at(5.0, 2)      # long after the commit finished
    m = eng.run()
    assert delivered_ids(eng) == list(range(300))
    assert m.records_replayed == 0
    assert m.duplicates_delivered == 0
    assert eng.coordinators[2].stats.failures_injected == 1


def test_crash_with_hedged_get_in_flight_keeps_accounting_consistent():
    """A worker crash while hedged GETs race must neither double-count
    ``CacheStats.store_gets`` (every issued GET is billed exactly once)
    nor double-deliver."""
    cfg = BlobShuffleConfig(batch_bytes=32 * 1024, max_interval_s=0.1,
                            num_partitions=9, num_az=3,
                            cache_on_write=False)   # force store GETs
    eng = AsyncShuffleEngine(
        cfg, EngineConfig(commit_interval_s=0.05, hedge_quantile=50.0,
                          hedge_min_samples=5),
        n_instances=4, seed=1, exactly_once=True)
    cluster = ElasticCluster(eng, heartbeat_timeout_s=0.1)
    cluster.crash_worker_at(0.35, "w2")
    for i, rec in enumerate(make_records(1500)):
        eng.submit(i * 4e-4, rec)       # arrivals span [0, 0.6]
    m = eng.run()
    assert m.hedges_issued > 0          # hedging really armed
    assert delivered_ids(eng) == list(range(1500))
    assert m.duplicates_delivered == 0
    # the single accounting choke point held across crash + hedges:
    # cluster-led GETs match the store's billed GET count exactly
    assert sum(c.stats.store_gets for c in eng.caches) \
        == eng.store.stats.gets
