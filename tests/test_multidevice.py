"""Multi-device (8 fake CPU devices) tests, run in subprocesses so the
device count can be set before jax initializes.

Covers: MoE dispatch equivalence (dense oracle vs flat vs blob-hierarchical,
values AND gradients), token conservation, DCN-bytes accounting, the
blob-bucketed hierarchical grad sync (exact + int8 + error feedback), and
the partial-auto shard_map train step.
"""

import os
import subprocess
import sys
import textwrap

import pytest

from repro import jaxcompat

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow   # subprocess multi-device: deselected in CI


def run_py(body: str, devices: int = 8) -> str:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_moe_dispatch_modes_agree():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.shuffle.api import ShuffleConfig, dense_moe_ffn, ep_moe_ffn

    mesh = make_test_mesh(devices=8)   # (pod=2, data=2, model=2)
    E, k, d, de, T = 8, 2, 16, 32, 64
    ks = jax.random.split(jax.random.key(0), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.5
    wg = jax.random.normal(ks[2], (E, d, de)) / jnp.sqrt(d)
    wu = jax.random.normal(ks[3], (E, d, de)) / jnp.sqrt(d)
    wd = jax.random.normal(ks[4], (E, de, d)) / jnp.sqrt(de)

    # capacity high enough that nothing drops -> all modes exact-equal
    y_ref, aux_ref, _ = dense_moe_ffn(x, wr, wg, wu, wd, top_k=k,
                                      capacity_factor=16.0,
                                      compute_dtype=jnp.float32)
    outs = {}
    for mode in ("direct", "blob"):
        cfg = ShuffleConfig(mode=mode, token_axes=("pod","data","model"),
                            expert_axes=("pod","model"),
                            capacity_factor=16.0)
        y, aux, diag = jax.jit(lambda x: ep_moe_ffn(
            x, wr, wg, wu, wd, top_k=k, cfg=cfg, mesh=mesh,
            compute_dtype=jnp.float32))(x)
        outs[mode] = (y, aux, diag)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-4)
        assert int(diag.dropped) == 0
        # token conservation: selections == T*k
        assert int(jnp.sum(diag.expert_load)) == T * k
    # blob mode crossed the pod boundary; direct reports its payload too
    assert float(outs["blob"][2].dcn_bytes) > 0
    print("MODES-AGREE-OK")
    """)


def test_moe_dispatch_gradients_agree():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import make_test_mesh
    from repro.shuffle.api import ShuffleConfig, dense_moe_ffn, ep_moe_ffn

    mesh = make_test_mesh(devices=8)
    E, k, d, de, T = 8, 2, 12, 16, 32
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.5
    wg = jax.random.normal(ks[2], (E, d, de)) / jnp.sqrt(d)
    wu = jax.random.normal(ks[3], (E, d, de)) / jnp.sqrt(d)
    wd = jax.random.normal(ks[4], (E, de, d)) / jnp.sqrt(de)

    def loss_dense(x, wr, wg, wu, wd):
        y, aux, _ = dense_moe_ffn(x, wr, wg, wu, wd, top_k=k,
                                  capacity_factor=16.0,
                                  compute_dtype=jnp.float32)
        return jnp.sum(jnp.tanh(y)) + aux

    def make_loss(mode):
        cfg = ShuffleConfig(mode=mode, token_axes=("pod","data","model"),
                            expert_axes=("pod","model"),
                            capacity_factor=16.0)
        def loss(x, wr, wg, wu, wd):
            y, aux, _ = ep_moe_ffn(x, wr, wg, wu, wd, top_k=k, cfg=cfg,
                                   mesh=mesh, compute_dtype=jnp.float32)
            return jnp.sum(jnp.tanh(y)) + aux
        return loss

    g_ref = jax.grad(loss_dense, argnums=(0,1,2,3,4))(x, wr, wg, wu, wd)
    for mode in ("direct", "blob"):
        g = jax.jit(jax.grad(make_loss(mode), argnums=(0,1,2,3,4)))(
            x, wr, wg, wu, wd)
        for a, b in zip(g, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=2e-4)
    print("GRADS-AGREE-OK")
    """)


def test_blob_pools_capacity_smaller_dcn():
    """The hierarchical mode's pooled stage-2 capacity sends fewer bytes
    across the pod axis than flat per-(src,expert) lanes."""
    run_py("""
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_test_mesh
    from repro.shuffle.api import ShuffleConfig, ep_moe_ffn

    mesh = make_test_mesh(devices=8)
    E, k, d, de, T = 16, 2, 8, 8, 256
    ks = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    wr = jax.random.normal(ks[1], (d, E)) * 0.1
    wg = jax.random.normal(ks[2], (E, d, de))
    wu = jax.random.normal(ks[3], (E, d, de))
    wd = jax.random.normal(ks[4], (E, de, d))
    dcn = {}
    for mode in ("direct", "blob"):
        cfg = ShuffleConfig(mode=mode, token_axes=("pod","data","model"),
                            expert_axes=("pod","model"),
                            capacity_factor=1.5)
        _, _, diag = jax.jit(lambda x: ep_moe_ffn(
            x, wr, wg, wu, wd, top_k=k, cfg=cfg, mesh=mesh,
            compute_dtype=jnp.float32))(x)
        dcn[mode] = float(diag.dcn_bytes)
    assert dcn["blob"] < dcn["direct"], dcn
    print("DCN", dcn)
    """)


@pytest.mark.skipif(not jaxcompat.NEW_SHARD_MAP,
                    reason="partial-auto shard_map + axis_index needs the "
                    "current partitioner (PartitionId unimplemented on 0.4.x)")
def test_grad_sync_exact_and_compressed():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_test_mesh
    from repro.shuffle import grad_sync as GS

    mesh = make_test_mesh(devices=8)
    grads = {"a": jnp.arange(1000, dtype=jnp.float32).reshape(10, 100),
             "b": jnp.ones((37,), jnp.float32)}

    def pod_fn(g):
        g = jax.tree.map(lambda x: x * (1 + jax.lax.axis_index("pod")), g)
        out, _ = GS.blob_allreduce_grads(g, blob_bytes=512, average=True)
        return out

    out = jax.jit(jax.shard_map(pod_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads),),
        out_specs=jax.tree.map(lambda _: P(), grads),
        check_vma=False,
        axis_names={"pod"}))(grads)
    # mean over pods of (1x, 2x) = 1.5x
    np.testing.assert_allclose(np.asarray(out["a"]),
                               np.asarray(grads["a"]) * 1.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out["b"]), 1.5, rtol=1e-6)

    # int8-compressed path: small relative error
    def pod_fn_c(g):
        out, _ = GS.blob_allreduce_grads(g, blob_bytes=512, average=True,
                                         compress=True)
        return out
    outc = jax.jit(jax.shard_map(pod_fn_c, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), grads),),
        out_specs=jax.tree.map(lambda _: P(), grads),
        check_vma=False,
        axis_names={"pod"}))(grads)
    err = np.abs(np.asarray(outc["a"]) - np.asarray(grads["a"]))
    rel = err.max() / np.abs(np.asarray(grads["a"])).max()
    assert rel < 0.02, rel
    print("GRAD-SYNC-OK", rel)
    """)


def test_error_feedback_reduces_bias():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.shuffle import compression as C

    # repeated compression of the same gradient: EF makes the *running sum*
    # of transmitted payloads converge to the true sum (unbiased).
    g = jnp.asarray(np.random.default_rng(0).normal(size=4096) * 1e-3,
                    jnp.float32)
    resid = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        payload, resid = C.with_error_feedback(g, resid)
        acc = acc + payload
    err_ef = float(jnp.max(jnp.abs(acc / 50 - g)))
    naive = C.compress_decompress(g)
    err_naive = float(jnp.max(jnp.abs(naive - g)))
    assert err_ef < err_naive * 0.2, (err_ef, err_naive)
    print("EF-OK", err_ef, err_naive)
    """)


def test_train_step_blob_grad_sync_matches_auto():
    run_py("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import lm
    from repro.models.common import init_params
    from repro.training import OptConfig, TrainConfig, adamw_init, \\
        make_train_step

    mesh = make_test_mesh(devices=8)
    cfg = get_config("granite-3-2b", smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    B, S = 8, 16
    batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.key(2), (B, S), 0,
                                          cfg.vocab_size)}
    outs = {}
    for sync in ("auto", "blob", "blob_int8"):
        tcfg = TrainConfig(opt=OptConfig(learning_rate=1e-3),
                           grad_sync=sync, grad_sync_blob_bytes=4096)
        step = make_train_step(cfg, tcfg, mesh=mesh)
        p2, o2, m = jax.jit(step)(params, opt, batch)
        outs[sync] = (m["loss"], m["grad_norm"], p2)
    # loss equal up to bf16 reduction-order noise (pod-local vs global mean)
    np.testing.assert_allclose(float(outs["blob"][0]),
                               float(outs["auto"][0]), rtol=1e-4)
    np.testing.assert_allclose(float(outs["blob"][1]),
                               float(outs["auto"][1]), rtol=1e-3)
    # updated params match between auto and exact blob sync
    for a, b in zip(jax.tree.leaves(outs["auto"][2]),
                    jax.tree.leaves(outs["blob"][2])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-5, rtol=5e-4)
    # int8 path close but not exact
    np.testing.assert_allclose(float(outs["blob_int8"][1]),
                               float(outs["auto"][1]), rtol=0.05)
    print("TRAIN-SYNC-OK")
    """)
