"""Paper validation: §4 analytical equations, cost anchors, simulator vs
the paper's measured results (Figs. 5–9). See EXPERIMENTS.md §Paper."""


import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, strategies as st

from repro.core import (CapacityModel,
                        ModelParams,
                        SimConfig,
                        blobshuffle_cost_per_hour,
                        kafka_shuffle_cost_per_hour,
                        simulate)
from repro.core import analytical as A

MiB = 1024 ** 2
GiB = 1024 ** 3

params_st = st.builds(
    ModelParams,
    n_inst=st.integers(1, 64),
    n_az=st.integers(1, 5),
    rate=st.floats(1e3, 1e7),
    s_rec=st.floats(64, 1e5),
    s_batch=st.floats(1e5, 2e8),
)


@given(params_st)
def test_analytical_identities(p):
    """The §4 equations are internally consistent."""
    assert A.batches_per_second(p) == pytest.approx(
        A.batches_per_second_per_instance(p) * p.n_inst)
    # μ_batch × S_batch == λ·s_rec (byte conservation)
    assert A.batches_per_second(p) * p.s_batch == pytest.approx(
        p.rate * p.s_rec, rel=1e-9)
    # μ_get/μ_put == (N_az−1)/N_az
    assert A.get_rate(p) / A.put_rate(p) == pytest.approx(
        (p.n_az - 1) / p.n_az)
    # T_batch == S_batch·N_az / b_inst
    assert A.t_batch(p) == pytest.approx(
        p.s_batch * p.n_az / A.bytes_per_instance(p), rel=1e-9)
    # latency bound dominates the mean
    assert A.shuffle_latency_max(p) >= A.shuffle_latency_mean(p)


def _params(s_batch_mib, rate_gib=1.0):
    return ModelParams(n_inst=24, n_az=3, rate=rate_gib * GiB / 1024,
                       s_rec=1024, s_batch=s_batch_mib * MiB)


def test_get_put_ratio_matches_fig6f():
    assert A.get_put_ratio(_params(16)) == pytest.approx(2 / 3)


def test_s3_cost_anchor_1mib():
    """Paper Fig. 6h: 20.63 USD/h at 1 MiB batches, 1 GiB/s, 1 h retention."""
    c = blobshuffle_cost_per_hour(_params(1), actual_batch_frac=0.95)
    assert c.s3_total == pytest.approx(20.63, rel=0.05)


def test_s3_cost_anchor_128mib():
    """Paper Fig. 6h: 0.29 USD/h at 128 MiB."""
    c = blobshuffle_cost_per_hour(_params(128), actual_batch_frac=0.90)
    assert c.s3_total == pytest.approx(0.29, rel=0.08)


def test_kafka_baseline_cost():
    """Paper §5.3: ≈192 USD/h for native Kafka shuffling (per GB/s the
    model gives $0.0533/GB·3600 = 192; at 1 GiB/s that is 206)."""
    per_gb = kafka_shuffle_cost_per_hour(
        ModelParams(n_inst=24, n_az=3, rate=1e9 / 1024, s_rec=1024,
                    s_batch=16 * MiB))
    assert per_gb == pytest.approx(192.0, rel=0.01)


def test_40x_saving_claim():
    """Paper headline: > 40× cheaper than native Kafka shuffling @16 MiB."""
    r = simulate(SimConfig())
    assert r.kafka_cost_per_hour_at_1gib / r.total_cost_at_1gib > 40


def test_simulator_latency_distribution_fig5():
    """p50/p95/p99 = 1.07/1.73/2.24 s ±10% (24 inst, 16 MiB)."""
    r = simulate(SimConfig())
    assert r.latency_p(50) == pytest.approx(1.07, rel=0.10)
    assert r.latency_p(95) == pytest.approx(1.73, rel=0.10)
    assert r.latency_p(99) == pytest.approx(2.24, rel=0.12)


def test_simulator_put_get_ratio_fig5b():
    """PUT ≈ 7–9× slower than GET (paper Fig. 5b/5c)."""
    r = simulate(SimConfig())
    ratio = float(np.median(r.put_latencies) / np.median(r.get_latencies))
    assert 7.0 <= ratio <= 9.0


def test_simulator_get_put_request_ratio_fig6f():
    r = simulate(SimConfig())
    assert r.gets_per_s / r.puts_per_s == pytest.approx(2 / 3, rel=0.05)


def test_capacity_peak_fig6a():
    """Throughput peaks near 32 MiB at ≈1.43 GiB/s (24 inst, 216 parts)."""
    cap = CapacityModel()
    t32 = cap.max_throughput_gib(32, 216, 24)
    assert t32 == pytest.approx(1.43, rel=0.10)
    assert cap.max_throughput_gib(1, 216, 24) < t32
    assert cap.max_throughput_gib(128, 216, 24) < t32


def test_capacity_partition_scaling_fig8():
    """3× partitions ⇒ ≈26% lower throughput (paper Fig. 8a) — we accept
    the fitted model's 20–30% band."""
    cap = CapacityModel()
    drop = 1 - cap.max_throughput_gib(16, 432, 24) \
        / cap.max_throughput_gib(16, 144, 24)
    assert 0.15 <= drop <= 0.35


def test_capacity_cluster_scaling_fig9():
    """0.37→2.39 GiB/s from 3→24 nodes; near-linear, per-node declining."""
    cap = CapacityModel()
    t = {n: cap.max_throughput_gib(16, 6 * 2 * n, 2 * n) for n in (3, 24)}
    # paper ratio is 6.5 (its 3-node point suffers an extra small-cluster
    # penalty the linear model does not capture — see benchmarks/fig9)
    assert t[24] / t[3] > 4.0            # scales, sub-linear per node
    per_node_3 = t[3] / 3
    per_node_24 = t[24] / 24
    assert per_node_24 < per_node_3      # declining per-node throughput
    assert t[24] == pytest.approx(2.39, rel=0.15)


def test_simulator_commit_shortens_batches_fig6g():
    """Actual batch ≈97–98% of target ≤32 MiB, ≈90% at 128 MiB (Fig. 6g).
    The batch-size sweep keeps max batch duration large (paper §5.3), so
    truncation comes from commits only."""
    r = simulate(SimConfig(batch_bytes=128 * MiB, max_interval_s=1e9))
    assert 0.80 <= r.mean_actual_batch <= 0.97
    r16 = simulate(SimConfig(batch_bytes=16 * MiB, max_interval_s=1e9))
    assert r16.mean_actual_batch > max(r.mean_actual_batch, 0.95)
