"""bin_pack / scatter / gather properties (the Batcher-analogue core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.shuffle.binning import (bin_pack, dropped_units,
                                   gather_from_bins, scatter_to_bins)


@settings(deadline=None, max_examples=30)
@given(st.lists(st.integers(0, 7), min_size=1, max_size=64),
       st.integers(1, 12))
def test_pack_scatter_gather_roundtrip(keys, capacity):
    keys = jnp.asarray(keys, jnp.int32)
    U = keys.shape[0]
    vals = jnp.arange(U, dtype=jnp.float32)[:, None] + 1.0
    pack = bin_pack(keys, 8, capacity)
    buf = scatter_to_bins(vals, pack, 8, capacity)
    back = gather_from_bins(buf, pack)
    # valid units roundtrip exactly; dropped units read zero
    np.testing.assert_array_equal(
        np.asarray(back[pack.valid]), np.asarray(vals[pack.valid]))
    assert np.all(np.asarray(back[~pack.valid]) == 0)
    # counts == true demand
    np.testing.assert_array_equal(
        np.asarray(pack.counts), np.bincount(np.asarray(keys), minlength=8))
    # drops = sum of overflow
    assert int(dropped_units(pack, capacity)) == int(
        np.maximum(np.asarray(pack.counts) - capacity, 0).sum())


@settings(deadline=None, max_examples=20)
@given(st.lists(st.integers(0, 3), min_size=1, max_size=40))
def test_bins_are_contiguous_and_ordered(keys):
    """Valid slots for bin k lie in [k·cap, k·cap + count_k) — the blob
    layout invariant (records per partition are contiguous)."""
    keys = jnp.asarray(keys, jnp.int32)
    cap = 64  # no drops
    pack = bin_pack(keys, 4, cap)
    assert bool(jnp.all(pack.valid))
    slots = np.asarray(pack.slot)
    counts = np.asarray(pack.counts)
    for k in range(4):
        sel = np.asarray(keys) == k
        got = np.sort(slots[sel])
        expect = np.arange(k * cap, k * cap + counts[k])
        np.testing.assert_array_equal(got, expect)


def test_no_collisions_among_valid():
    keys = jnp.asarray([0, 0, 0, 1, 1, 2] * 10, jnp.int32)
    pack = bin_pack(keys, 3, 8)
    slots = np.asarray(pack.slot)[np.asarray(pack.valid)]
    assert len(np.unique(slots)) == len(slots)


def test_scatter_gather_multidim_payload():
    keys = jnp.asarray([2, 0, 1, 2, 0], jnp.int32)
    vals = jnp.arange(5 * 3, dtype=jnp.bfloat16).reshape(5, 3)
    pack = bin_pack(keys, 3, 4)
    buf = scatter_to_bins(vals, pack, 3, 4)
    assert buf.shape == (3, 4, 3)
    back = gather_from_bins(buf, pack)
    np.testing.assert_array_equal(np.asarray(back, np.float32),
                                  np.asarray(vals, np.float32))
