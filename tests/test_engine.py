"""Event-driven async engine: virtual-clock determinism, I/O overlap,
commit gating, and exactly-once under duplicated/reordered notifications."""

import numpy as np
import pytest

from repro.core import (AsyncShuffleEngine, BlobShuffleConfig, EngineConfig,
                        EventLoop, Record, WorkloadConfig, drive, generate)
from repro.core.stores import LatencyModel

CFG = BlobShuffleConfig(batch_bytes=64 * 1024, max_interval_s=0.5,
                        num_partitions=9, num_az=3)
DET = LatencyModel(sigma=0.0)   # lognormal degenerates to the exact median


def make_records(n, vsize=200, seed=0):
    rng = np.random.default_rng(seed)
    return [Record(rng.bytes(8), rng.bytes(vsize), timestamp_us=i)
            for i in range(n)]


def run_engine(ecfg, n=600, exactly_once=True, seed=0, cfg=CFG):
    eng = AsyncShuffleEngine(cfg, ecfg, n_instances=6, seed=seed,
                             exactly_once=exactly_once)
    for i, rec in enumerate(make_records(n)):
        eng.submit(i * 1e-4, rec)
    metrics = eng.run()
    return eng, metrics


# -- event loop ------------------------------------------------------------

def test_event_loop_orders_by_time_then_insertion():
    loop, seen = EventLoop(), []
    loop.at(2.0, seen.append, "c")
    loop.at(1.0, seen.append, "a")
    loop.at(1.0, seen.append, "b")   # tie: insertion order
    loop.after(0.5, seen.append, "first")
    assert loop.run() == 2.0
    assert seen == ["first", "a", "b", "c"]


def test_event_loop_time_never_goes_backwards():
    loop, times = EventLoop(), []
    def late():
        loop.at(0.0, lambda: times.append(loop.now))  # in the past: clamps
    loop.at(5.0, late)
    loop.run()
    assert times == [5.0]


# -- delivery + determinism ------------------------------------------------

def test_engine_delivers_every_record_exactly_once():
    eng, m = run_engine(EngineConfig())
    flat = [r.timestamp_us for rs in eng.out.values() for r in rs]
    assert sorted(flat) == list(range(600))
    assert m.records_delivered == m.records_in == 600


def test_engine_is_deterministic_for_fixed_seed():
    _, m1 = run_engine(EngineConfig(), seed=3)
    _, m2 = run_engine(EngineConfig(), seed=3)
    assert m1.makespan_s == m2.makespan_s
    assert m1.record_latencies == m2.record_latencies


# -- overlap (the point of the async refactor) -----------------------------

def test_prefetching_debatcher_overlaps_gets():
    """With deterministic latencies, K prefetched GETs must finish in less
    virtual time than the sum of their serial latencies."""
    cfg = BlobShuffleConfig(batch_bytes=32 * 1024, max_interval_s=0.2,
                            num_partitions=9, num_az=3,
                            cache_on_write=False)  # force store GETs
    par = AsyncShuffleEngine(cfg, EngineConfig(fetch_parallelism=8),
                             n_instances=3, seed=0, exactly_once=False)
    par.store.latency = DET
    for i, rec in enumerate(make_records(400)):
        par.submit(i * 1e-5, rec)
    m = par.run()
    serial_sum = sum(m.get_latencies)
    assert len(m.get_latencies) >= 4
    # GETs overlap: total elapsed time beats even just the serial GET sum
    assert m.makespan_s < serial_sum


def test_upload_parallelism_beats_single_in_flight():
    """Acceptance gate: upload parallelism >= 4 yields a measurably lower
    makespan than the synchronous single-in-flight configuration."""
    _, serial = run_engine(EngineConfig(upload_parallelism=1,
                                        fetch_parallelism=1), n=900)
    _, overlap = run_engine(EngineConfig(upload_parallelism=4,
                                         fetch_parallelism=8), n=900)
    assert overlap.records_delivered == serial.records_delivered == 900
    assert overlap.makespan_s < 0.9 * serial.makespan_s


# -- commit protocol + exactly-once ----------------------------------------

def test_commit_blocks_until_outstanding_uploads_drain():
    eng, _ = run_engine(EngineConfig())
    stats = [c.stats for c in eng.coordinators]
    assert sum(s.commits for s in stats) >= 1
    assert max(s.commit_block_s for s in stats) > 0   # waited on PUTs
    for c in eng.coordinators:
        assert not c.outstanding and not c.unpublished


def test_duplicate_and_reordered_notifications_do_not_double_deliver():
    """Replay every published notification through the CommitCoordinator's
    publish path in reverse order: the Debatcher's claim-on-begin dedup
    must drop all of them, even racing in-flight fetches."""
    eng, _ = run_engine(EngineConfig())
    baseline = {p: list(rs) for p, rs in eng.out.items()}
    originals = list(eng.published)
    for note in reversed(originals):
        eng.coordinators[0].publish(note)
        eng.coordinators[0].publish(note)   # and duplicated
    eng.loop.run()
    assert {p: list(rs) for p, rs in eng.out.items()} == baseline
    dropped = sum(d.stats.duplicates_dropped for d in eng.debatchers)
    assert dropped == 2 * len(originals)
    assert eng.metrics.duplicates_delivered == 0


def test_failure_replay_preserves_exactly_once_through_engine():
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=4, seed=0,
                             exactly_once=True)
    recs = make_records(400)
    for i, rec in enumerate(recs):
        eng.submit(i * 1e-6, rec, inst=i % 4)
    eng.fail_at(150 * 1e-6, 2)       # crash mid-stream, before any commit
    eng.commit_at(200 * 1e-6)
    m = eng.run()
    flat = [r.timestamp_us for rs in eng.out.values() for r in rs]
    assert sorted(flat) == list(range(400))   # no loss, no duplicates
    assert m.records_replayed > 0


# -- workload driver -------------------------------------------------------

def test_workload_rate_size_and_determinism():
    wl = WorkloadConfig(arrival_rate=2000, duration_s=1.0,
                        record_bytes=512, key_skew=1.1, seed=5)
    stream = generate(wl)
    assert len(stream) == 2000
    times = [t for t, _ in stream]
    assert times == sorted(times) and times[-1] == pytest.approx(1.0,
                                                                 rel=0.2)
    assert all(rec.size == 512 for _, rec in stream)
    assert stream == generate(wl)             # seeded: reproducible
    # skewed keys: the hottest key dominates a uniform draw
    top = max(np.unique([rec.key for _, rec in stream],
                        return_counts=True)[1])
    assert top > 3 * (2000 / wl.num_keys)


def test_workload_drive_end_to_end_latency_percentiles():
    eng = AsyncShuffleEngine(CFG, EngineConfig(), n_instances=6, seed=0,
                             exactly_once=False)
    drive(eng, WorkloadConfig(arrival_rate=1000, duration_s=1.0,
                              record_bytes=512, seed=2))
    m = eng.run()
    s = m.summary(eng.store)
    assert m.records_delivered == 1000
    assert 0 < s["p50_s"] <= s["p95_s"] <= s["p99_s"]
    assert s["cost_per_gib"] > 0 and s["makespan_s"] > 0
