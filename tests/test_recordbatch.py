"""Columnar RecordBatch: round-trips, partitioner bit-equality, and
legacy-vs-columnar blob payload bit-identity (the tentpole invariants),
exercised over a deterministic corpus that covers both the generic and
the fixed-width fast paths. ``test_recordbatch_props.py`` fuzzes the same
invariants with hypothesis where it is installed."""

import numpy as np
import pytest

from repro.core import (Batcher, BlobShuffleConfig, DistributedCache,
                        Record, RecordBatch, SimulatedS3,
                        default_partitioner, default_partitioner_batch,
                        serialize)
from repro.core.recordbatch import fnv1a_batch
from repro.core.workload import WorkloadConfig, generate, generate_batch


def _random_records(rng, n, with_headers=False, uniform=False):
    out = []
    for _ in range(n):
        if uniform:
            key = rng.bytes(8)
            value = rng.bytes(24)
        else:
            key = rng.bytes(int(rng.integers(0, 33)))
            value = rng.bytes(int(rng.integers(0, 257)))
        headers = ()
        if with_headers and rng.random() < 0.5:
            headers = tuple(
                (rng.bytes(int(rng.integers(0, 9))),
                 rng.bytes(int(rng.integers(0, 17))))
                for _ in range(int(rng.integers(1, 4))))
        out.append(Record(key, value, int(rng.integers(0, 2**63)), headers))
    return out


def _corpus():
    rng = np.random.default_rng(0)
    yield "empty", []
    yield "single", [Record(b"k", b"v", 7)]
    yield "empty-fields", [Record(b"", b"", 0), Record(b"", b"x", 1),
                           Record(b"y", b"", 2**63 - 1)]
    yield "headers", [Record(b"a", b"b", 3, ((b"h", b"v"), (b"", b""))),
                      Record(b"c", b"d", 4)]
    yield "mixed", _random_records(rng, 40, with_headers=True)
    yield "uniform", _random_records(rng, 64, uniform=True)
    yield "big", _random_records(rng, 300)


CORPUS = list(_corpus())
IDS = [name for name, _ in CORPUS]
LISTS = [recs for _, recs in CORPUS]


@pytest.mark.parametrize("recs", LISTS, ids=IDS)
def test_batch_wire_roundtrip(recs):
    """from_records -> serialize_rows is bit-exact with the scalar
    serializer; from_buffer recovers the records (incl. headers)."""
    batch = RecordBatch.from_records(recs)
    assert len(batch) == len(recs)
    assert batch.to_records() == recs
    wire = bytes(batch.serialize_rows())
    assert wire == b"".join(serialize(r) for r in recs)
    assert RecordBatch.from_buffer(wire).to_records() == recs
    assert list(batch.serialized_sizes()) == [r.size for r in recs]


def test_uniform_fast_paths_engage_and_agree():
    rng = np.random.default_rng(1)
    recs = _random_records(rng, 50, uniform=True)
    batch = RecordBatch.from_records(recs)
    assert batch._uniform_widths() == (8, 24)
    wire = bytes(batch.serialize_rows())
    assert wire == b"".join(serialize(r) for r in recs)
    parsed = RecordBatch.from_buffer(wire)
    assert parsed._uniform_widths() == (8, 24)   # vectorized parse path
    assert parsed.to_records() == recs
    # a non-uniform stream must NOT be claimed by the fast parse
    recs2 = recs + [Record(b"odd", b"sized", 1)]
    wire2 = b"".join(serialize(r) for r in recs2)
    assert RecordBatch.from_buffer(wire2).to_records() == recs2


@pytest.mark.parametrize("recs", LISTS[1:], ids=IDS[1:])
def test_batch_select_slice_and_partial_serialize(recs):
    batch = RecordBatch.from_records(recs)
    n = len(recs)
    rng = np.random.default_rng(2)
    idx = rng.integers(0, n, size=min(n, 10))
    assert batch.select(idx).to_records() == [recs[i] for i in idx]
    s, e = n // 3, 2 * n // 3 + 1
    assert batch.slice_rows(s, e).to_records() == recs[s:e]
    assert bytes(batch.serialize_rows(idx)) == \
        b"".join(serialize(recs[i]) for i in idx)
    # zero-copy slices still serialize bit-exact (rebased offsets)
    sub = batch.slice_rows(s, e)
    assert bytes(sub.serialize_rows()) == \
        b"".join(serialize(r) for r in recs[s:e])


@pytest.mark.parametrize("num_partitions", [1, 9, 216, 2**31 - 1])
def test_partitioner_bit_equality(num_partitions):
    """Vectorized FNV-1a == scalar FNV-1a, byte for byte, key by key —
    over ragged keys (masked path) and empty keys."""
    rng = np.random.default_rng(3)
    keys = [b"", b"a", bytes(range(256))] + \
        [rng.bytes(int(rng.integers(0, 25))) for _ in range(64)]
    batch = RecordBatch.from_records([Record(k, b"") for k in keys])
    got = default_partitioner_batch(batch, num_partitions)
    assert got.dtype == np.int32
    assert list(got) == [default_partitioner(k, num_partitions)
                         for k in keys]


def test_partitioner_uniform_fast_path_matches_scalar():
    # 8-byte keys over a packed arena take the mask-free column path
    keys = np.arange(4096, dtype=np.uint64) * np.uint64(2654435761)
    batch = RecordBatch.from_fixed(keys, 4, np.zeros(4096, np.uint64))
    got = fnv1a_batch(batch.key_arena, batch.key_offsets)
    for i in (0, 1, 17, 4095):
        h = 0xCBF29CE484222325
        for b in int(keys[i]).to_bytes(8, "little"):
            h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
        assert int(got[i]) == h


def _make_batcher(num_partitions=16, num_az=2, batch_bytes=1 << 62):
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    blobs = []
    b = Batcher(
        BlobShuffleConfig(batch_bytes=batch_bytes,
                          num_partitions=num_partitions, num_az=num_az),
        lambda p: p % num_az,
        lambda k: default_partitioner(k, num_partitions),
        cache,
        uploader=lambda blob, notes, counts, now: blobs.append(
            (blob, notes, counts)),
        name="t",
        partitioner_batch=lambda bt: default_partitioner_batch(
            bt, num_partitions))
    return b, blobs


@pytest.mark.parametrize("recs", LISTS[1:], ids=IDS[1:])
def test_legacy_vs_columnar_blob_bit_identity(recs):
    """The tentpole acceptance invariant: per-record ``process`` and bulk
    columnar ``ingest`` of the same records finalize blobs with
    bit-identical payloads, ranges, and per-partition counts."""
    legacy, lblobs = _make_batcher()
    columnar, cblobs = _make_batcher()
    for r in recs:
        legacy.process(r, 0.0)
    columnar.ingest(RecordBatch.from_records(recs), 0.0)
    legacy.flush_all(0.0)
    columnar.flush_all(0.0)
    assert len(lblobs) == len(cblobs)
    for (lb, ln, lc), (cb, cn, cc) in zip(
            sorted(lblobs, key=lambda x: x[0].target_az),
            sorted(cblobs, key=lambda x: x[0].target_az)):
        assert lb.payload == cb.payload
        assert lb.index == cb.index
        # blob ids are sequence-numbered in finalize order, which may
        # differ between the paths — compare everything but the id
        assert [(n.partition, n.byte_range, n.target_az) for n in ln] == \
            [(n.partition, n.byte_range, n.target_az) for n in cn]
        assert lc == cc


@pytest.mark.parametrize("recs", LISTS[1:], ids=IDS[1:])
def test_partitions_unique_key_fallback_matches_rowwise(recs):
    """Without a vectorized partitioner, ``compute_partitions`` applies
    the scalar partitioner once per *unique* key — the result must be
    bit-equal to applying it per row, on both the fixed-width (void-view
    dedup) and ragged (dict-memo) key shapes."""
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    P = 16
    b = Batcher(BlobShuffleConfig(batch_bytes=1 << 62, num_partitions=P,
                                  num_az=2),
                lambda p: p % 2, lambda k: default_partitioner(k, P),
                cache, name="u")          # no partitioner_batch: fallback
    batch = RecordBatch.from_records(recs)
    got = b.compute_partitions(batch)
    rowwise = np.fromiter(
        (default_partitioner(batch.key(i), P) for i in range(len(batch))),
        np.int32, len(batch))
    assert got.dtype == rowwise.dtype
    np.testing.assert_array_equal(got, rowwise)


def test_generate_batch_matches_generate():
    wl = WorkloadConfig(arrival_rate=2000, duration_s=0.5,
                        record_bytes=128, key_skew=0.7, seed=3)
    legacy = generate(wl)
    arrivals, batch = generate_batch(wl)
    assert len(legacy) == len(batch)
    assert [r for _, r in legacy] == batch.to_records()
    np.testing.assert_allclose([t for t, _ in legacy], arrivals)


def test_pending_uploads_drain_in_completion_order():
    """poll() pops the completion heap in ``completes_at`` order and only
    past-due entries — no O(n) rescan of still-pending uploads."""
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    P = 4
    b = Batcher(BlobShuffleConfig(batch_bytes=1 << 62, num_partitions=P,
                                  num_az=1),
                lambda p: 0, lambda k: default_partitioner(k, P), cache,
                name="h")
    # arrivals close together so no upload completes before the last
    # flush (process() itself polls at each ``now``)
    for i, t in enumerate([0.0, 0.001, 0.002]):
        b.process(Record(f"k{i}".encode(), b"v" * 64), now=t)
        b.flush_all(t)
    assert len(b.pending) == 3
    heap_times = sorted(c for c, _, _ in b.pending)
    # nothing due before the first completion
    assert b.poll(heap_times[0] - 1e-9) == []
    first = b.poll(heap_times[0])
    assert len(first) >= 1 and len(b.pending) == 2
    notes, blocked = b.on_commit(heap_times[0])
    assert not b.pending and blocked > 0
    assert len(notes) >= 2


def test_record_size_cached_and_correct():
    r = Record(b"key", b"value" * 10, 5, ((b"h", b"x"),))
    assert r.size == len(serialize(r))
    assert "size" in r.__dict__          # cached after first access
    assert r.size == len(serialize(r))
