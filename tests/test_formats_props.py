"""Property tests for the blob wire formats (hypothesis).

Bit-exact round-trip across the registered lossless formats for
arbitrary record batches, and typed-error behavior under arbitrary
truncation and single-byte mutation of framed v2 blocks. Skipped when
hypothesis is not installed (it is a dev extra; CI installs it).
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.formats import (COLUMNAR_V2, COLUMNAR_V2_INT8, RAW_V1,
                                WIRE_MAGIC, BlobFormatError,
                                CorruptBlobError, detect_format)  # noqa: E402
from repro.core.formats.codecs import (decode_section,  # noqa: E402
                                       encode_section)
from repro.core.records import Record, serialize  # noqa: E402

LOSSLESS = [RAW_V1, COLUMNAR_V2]

# timestamps cross 2**63 so both the delta and the raw-u64 encodings run
records_st = st.lists(
    st.builds(Record,
              key=st.binary(max_size=24),
              value=st.binary(max_size=96),
              timestamp_us=st.integers(min_value=0,
                                       max_value=2 ** 64 - 1)),
    max_size=40)


@st.composite
def wire_st(draw):
    return b"".join(serialize(r) for r in draw(records_st))


@st.composite
def framed_v2_block_st(draw):
    """A v2 block that is guaranteed framed: hot keys + runs of one byte
    compress well, so the encoder never takes the raw fallback."""
    n = draw(st.integers(min_value=8, max_value=32))
    keys = draw(st.lists(st.binary(min_size=8, max_size=8),
                         min_size=1, max_size=4))
    recs = [Record(key=keys[draw(st.integers(0, len(keys) - 1))],
                   value=bytes([draw(st.integers(0, 255))]) *
                   draw(st.integers(16, 64)),
                   timestamp_us=draw(st.integers(0, 2 ** 40)))
            for _ in range(n)]
    out = COLUMNAR_V2.encode_block([b"".join(serialize(r) for r in recs)])
    block = bytes(out[0])
    assert block[:4] == WIRE_MAGIC, "fallback despite compressible input"
    return block


@settings(max_examples=60, deadline=None)
@given(wire=wire_st(), fmt=st.sampled_from(LOSSLESS))
def test_lossless_round_trip_bit_exact(wire, fmt):
    out = fmt.encode_block([wire])
    block = b"".join(bytes(c) for c in out)
    sniffed = detect_format(block)
    assert bytes(sniffed.decode_block(block)) == wire
    batch = sniffed.decode_block_batch(block)
    assert bytes(batch.serialize_rows()) == wire


@settings(max_examples=40, deadline=None)
@given(wire=wire_st())
def test_int8_variant_keys_and_timestamps_survive(wire):
    """The lossy variant quantizes only the value column — keys and
    timestamps must round-trip exactly for any input (including the raw
    fallback and the not-uniform-float32 value shapes)."""
    block = b"".join(bytes(c)
                     for c in COLUMNAR_V2_INT8.encode_block([wire]))
    batch = detect_format(block).decode_block_batch(block)
    ref = RAW_V1.decode_block_batch(wire)
    assert len(batch) == len(ref)
    assert bytes(batch.key_arena) == bytes(ref.key_arena)
    assert batch.timestamps.tolist() == ref.timestamps.tolist()


@settings(max_examples=60, deadline=None)
@given(block=framed_v2_block_st(),
       cut=st.integers(min_value=0, max_value=10 ** 6))
def test_truncated_framed_block_raises_typed_error(block, cut):
    cut = cut % len(block)
    truncated = block[:cut]
    if truncated[:5] == block[:5]:
        # still sniffs as v2 -> decoding must fail with the typed error
        assert detect_format(truncated) is COLUMNAR_V2
        with pytest.raises(CorruptBlobError):
            COLUMNAR_V2.decode_block_batch(truncated)
    else:
        # header gone -> sniffs as headerless raw v1
        assert detect_format(truncated) is RAW_V1


@settings(max_examples=60, deadline=None)
@given(block=framed_v2_block_st(),
       pos=st.integers(min_value=0, max_value=10 ** 6),
       delta=st.integers(min_value=1, max_value=255))
def test_mutated_framed_block_fails_typed_or_decodes(block, pos, delta):
    """Change one byte anywhere in a framed block: the reader must either
    reject it with a typed BlobFormatError (corruption, unknown version,
    unknown flags) or decode *some* batch — never escape with an untyped
    exception from deep inside the column decoders."""
    pos = pos % len(block)
    mutated = block[:pos] + bytes([(block[pos] + delta) % 256]) \
        + block[pos + 1:]
    try:
        fmt = detect_format(mutated)
        if fmt.format_id == 2:
            fmt.decode_block_batch(mutated)
    except BlobFormatError:
        pass                        # typed rejection is the contract
    except Exception as e:          # pragma: no cover — the property
        pytest.fail(f"untyped decode failure: {type(e).__name__}: {e}")


@settings(max_examples=60, deadline=None)
@given(raw=st.binary(max_size=512),
       level=st.integers(min_value=1, max_value=9))
def test_section_codec_round_trip(raw, level):
    framed = encode_section(raw, level=level)
    got, off = decode_section(memoryview(framed), 0)
    assert got == raw and off == len(framed)
    with pytest.raises(CorruptBlobError):
        decode_section(memoryview(framed[:len(framed) - 1]), 0)
