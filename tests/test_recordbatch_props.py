"""Hypothesis fuzzing of the RecordBatch invariants (serialize/parse
round-trips, partitioner bit-equality, legacy-vs-columnar blob payload
bit-identity). The deterministic corpus versions live in
``test_recordbatch.py``; this file widens them to arbitrary inputs."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Batcher, BlobShuffleConfig, DistributedCache,
                        Record, RecordBatch, SimulatedS3,
                        default_partitioner, default_partitioner_batch,
                        serialize)


def _make_batcher(num_partitions=16, num_az=2):
    store = SimulatedS3(seed=0)
    cache = DistributedCache(0, 1, 1 << 30, store)
    blobs = []
    b = Batcher(
        BlobShuffleConfig(batch_bytes=1 << 62,
                          num_partitions=num_partitions, num_az=num_az),
        lambda p: p % num_az,
        lambda k: default_partitioner(k, num_partitions),
        cache,
        uploader=lambda blob, notes, counts, now: blobs.append(
            (blob, notes, counts)),
        name="t",
        partitioner_batch=lambda bt: default_partitioner_batch(
            bt, num_partitions))
    return b, blobs

rec_st = st.builds(
    Record,
    key=st.binary(min_size=0, max_size=32),
    value=st.binary(min_size=0, max_size=256),
    timestamp_us=st.integers(min_value=0, max_value=2**63 - 1),
    headers=st.lists(
        st.tuples(st.binary(max_size=8), st.binary(max_size=16)),
        max_size=3).map(tuple),
)

# records that hit the uniform (fixed-width, header-free) fast paths
uniform_rec_st = st.builds(
    Record,
    key=st.binary(min_size=8, max_size=8),
    value=st.binary(min_size=24, max_size=24),
    timestamp_us=st.integers(min_value=0, max_value=2**63 - 1),
)


@settings(deadline=None)
@given(st.lists(rec_st, max_size=20))
def test_batch_wire_roundtrip(recs):
    batch = RecordBatch.from_records(recs)
    assert batch.to_records() == recs
    wire = bytes(batch.serialize_rows())
    assert wire == b"".join(serialize(r) for r in recs)
    assert RecordBatch.from_buffer(wire).to_records() == recs
    assert list(batch.serialized_sizes()) == [r.size for r in recs]


@settings(deadline=None)
@given(st.lists(uniform_rec_st, min_size=1, max_size=20))
def test_batch_wire_roundtrip_uniform_fast_path(recs):
    batch = RecordBatch.from_records(recs)
    assert batch._uniform_widths() == (8, 24)
    wire = bytes(batch.serialize_rows())
    assert wire == b"".join(serialize(r) for r in recs)
    assert RecordBatch.from_buffer(wire).to_records() == recs


@settings(deadline=None)
@given(st.lists(rec_st, min_size=1, max_size=20), st.data())
def test_batch_select_and_slice(recs, data):
    batch = RecordBatch.from_records(recs)
    n = len(recs)
    idx = data.draw(st.lists(st.integers(0, n - 1), max_size=10))
    got = batch.select(np.asarray(idx, np.int64)).to_records()
    assert got == [recs[i] for i in idx]
    s = data.draw(st.integers(0, n))
    e = data.draw(st.integers(s, n))
    assert batch.slice_rows(s, e).to_records() == recs[s:e]
    assert bytes(batch.serialize_rows(np.asarray(idx, np.int64))) == \
        b"".join(serialize(recs[i]) for i in idx)


@settings(deadline=None)
@given(st.lists(st.binary(min_size=0, max_size=24), max_size=32),
       st.integers(1, 2**31 - 1))
def test_partitioner_bit_equality(keys, num_partitions):
    batch = RecordBatch.from_records([Record(k, b"") for k in keys])
    got = default_partitioner_batch(batch, num_partitions)
    assert list(got) == [default_partitioner(k, num_partitions)
                         for k in keys]


@settings(deadline=None, max_examples=25)
@given(st.lists(rec_st, min_size=1, max_size=40))
def test_legacy_vs_columnar_blob_bit_identity(recs):
    legacy, lblobs = _make_batcher()
    columnar, cblobs = _make_batcher()
    for r in recs:
        legacy.process(r, 0.0)
    columnar.ingest(RecordBatch.from_records(recs), 0.0)
    legacy.flush_all(0.0)
    columnar.flush_all(0.0)
    assert len(lblobs) == len(cblobs)
    for (lb, ln, lc), (cb, cn, cc) in zip(
            sorted(lblobs, key=lambda x: x[0].target_az),
            sorted(cblobs, key=lambda x: x[0].target_az)):
        assert lb.payload == cb.payload
        assert lb.index == cb.index
        # blob ids are sequence-numbered in finalize order, which may
        # differ between the paths — compare everything but the id
        assert [(n.partition, n.byte_range, n.target_az) for n in ln] == \
            [(n.partition, n.byte_range, n.target_az) for n in cn]
        assert lc == cc
