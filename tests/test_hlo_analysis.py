"""HLO analyzer: trip-count-aware flops/bytes/collectives (the roofline
backbone) validated on programs with known costs."""


import jax
import jax.numpy as jnp
import pytest

from repro import jaxcompat
from repro.launch import hlo_analysis as H

pytestmark = pytest.mark.slow   # XLA compile sweeps: deselected in CI


def _compile(f, *abstract):
    return jax.jit(f).lower(*abstract).compile()


def test_scan_flops_multiplied_by_trip_count():
    L, M, K, N = 12, 64, 128, 96

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    comp = _compile(f, jax.ShapeDtypeStruct((L, K, K), jnp.float32),
                    jax.ShapeDtypeStruct((M, K), jnp.float32))
    st = H.analyze(comp.as_text())
    expect = 2 * M * K * K * L
    assert st.flops == pytest.approx(expect, rel=0.01)
    # XLA's own analysis counts the loop body once — ours must be larger
    xla = jaxcompat.cost_analysis(comp).get("flops", 0)
    assert st.flops > xla * (L / 2)


def test_nested_scans_multiply():
    Lo, Li, M, K = 3, 5, 16, 32

    def f(w, x):
        def outer(h, wo):
            def inner(h2, _):
                return jnp.tanh(h2 @ wo), None
            h2, _ = jax.lax.scan(inner, h, None, length=Li)
            return h2, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    comp = _compile(f, jax.ShapeDtypeStruct((Lo, K, K), jnp.float32),
                    jax.ShapeDtypeStruct((M, K), jnp.float32))
    st = H.analyze(comp.as_text())
    expect = 2 * M * K * K * Lo * Li
    assert st.flops == pytest.approx(expect, rel=0.02)


def test_grad_flops_about_3x_forward():
    M, K = 64, 128

    def fwd(w, x):
        return jnp.sum(jnp.tanh(x @ w))

    c_f = _compile(fwd, jax.ShapeDtypeStruct((K, K), jnp.float32),
                   jax.ShapeDtypeStruct((M, K), jnp.float32))
    c_g = _compile(jax.grad(fwd, argnums=(0, 1)),
                   jax.ShapeDtypeStruct((K, K), jnp.float32),
                   jax.ShapeDtypeStruct((M, K), jnp.float32))
    f = H.analyze(c_f.as_text()).flops
    g = H.analyze(c_g.as_text()).flops
    assert 2.5 <= g / f <= 3.5


def test_bytes_scale_with_trip_count():
    def make(n):
        def f(x):
            def body(h, _):
                return jnp.sin(h) * 1.0001, None
            h, _ = jax.lax.scan(body, x, None, length=n)
            return h
        return _compile(f, jax.ShapeDtypeStruct((1024, 256), jnp.float32))

    b2 = H.analyze(make(2).as_text()).bytes_accessed
    b20 = H.analyze(make(20).as_text()).bytes_accessed
    assert 6 <= b20 / b2 <= 14  # ~10x (loop-invariant overhead dilutes)


def test_replica_group_parsers():
    explicit = "all-gather(%x), replica_groups={{0,2},{1,3}}, dims"
    g = H.parse_replica_groups(explicit, 4)
    assert g == [[0, 2], [1, 3]]
    iota = "all-reduce(%x), replica_groups=[4,2]<=[8], more"
    g = H.parse_replica_groups(iota, 8)
    assert g == [[0, 1], [2, 3], [4, 5], [6, 7]]
    iota_t = "all-gather(%x), replica_groups=[4,2]<=[2,4]T(1,0), dims"
    g = H.parse_replica_groups(iota_t, 8)
    # arange(8).reshape(2,4).T.flatten() = [0,4,1,5,2,6,3,7]
    assert g == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_pod_crossing_classification():
    assert H._crosses_pod([[0, 255], [256, 511]], 256) is False
    assert H._crosses_pod([[0, 256]], 256) is True
    assert H._crosses_pod([[5, 6, 7]], 256) is False
