"""Crash-mid-checkpoint semantics of the blob checkpointer.

The commit protocol (blobs first, manifest last) must guarantee:

* a crash between blob upload and manifest write leaves **orphans** —
  unreachable from any restore path and collected by retention;
* restore trusts **manifests only** (stray objects in the store never
  surface);
* an async-upload save followed by an immediate crash restores the
  *previous* checkpoint, never a partial one.

Exercised over both backends: ``FileStore`` (filesystem) and
``TieredCheckpointStore`` over the simulated multi-tier stores,
including fault injection (``FaultyStore``).
"""

import numpy as np
import pytest

from repro.checkpoint import (BlobCheckpointer, FileStore,
                              TieredCheckpointStore, latest_step)
from repro.core.stores import ExpressOneZoneStore, FaultyStore, SimulatedS3


def _tree(seed, n=3):
    rng = np.random.default_rng(seed)
    return {"w": [rng.standard_normal((4, 5)).astype(np.float32)
                  for _ in range(n)],
            "count": np.asarray(seed, np.int32)}


def _stores(tmp_path):
    return {
        "file": FileStore(str(tmp_path / "ckpt")),
        "tiered-s3": TieredCheckpointStore(SimulatedS3(seed=1)),
        "tiered-faulty": TieredCheckpointStore(
            FaultyStore(ExpressOneZoneStore(seed=2, num_az=3), seed=3,
                        transient_p=0.25)),
    }


@pytest.mark.parametrize("kind", ["file", "tiered-s3", "tiered-faulty"])
def test_crash_before_manifest_is_invisible_and_collected(tmp_path, kind):
    store = _stores(tmp_path)[kind]
    ck = BlobCheckpointer(store, async_upload=False)
    ck.save(1, _tree(1))
    ck.save(2, _tree(2), crash_before_manifest=True)  # orphaned blobs

    # the half-written step is invisible: manifests only
    assert latest_step(store) == 1
    assert ck.manifest(2) is None
    with pytest.raises(FileNotFoundError):
        ck.restore(2, _tree(0))

    # retention collects exactly the orphans; the committed step survives
    removed = store.run_retention()
    assert removed == len(_tree(2)["w"]) + 1
    restored = ck.restore(1, _tree(0))
    for a, b in zip(restored["w"], _tree(1)["w"]):
        np.testing.assert_array_equal(a, b)
    assert store.run_retention() == 0  # idempotent


def test_restore_trusts_manifests_only(tmp_path):
    store = FileStore(str(tmp_path / "ckpt"))
    ck = BlobCheckpointer(store, async_upload=False)
    ck.save(5, _tree(5))
    # stray objects in the store (a concurrent writer's debris) must not
    # surface through any read path
    store.put("step00000007_leaf00000.npy", b"\x00" * 80)
    store.put("unrelated-junk.bin", b"junk")
    assert latest_step(store) == 5
    with pytest.raises(FileNotFoundError):
        ck.restore(7, _tree(0))
    removed = store.run_retention()
    assert removed == 2  # both strays collected, step-5 blobs kept
    restored = ck.restore(5, _tree(0))
    np.testing.assert_array_equal(restored["count"], np.asarray(5, np.int32))


def test_async_save_then_crash_restores_previous(tmp_path):
    store = TieredCheckpointStore(SimulatedS3(seed=9))
    ck = BlobCheckpointer(store, async_upload=True)
    ck.save(1, _tree(1))
    ck.wait()
    # async upload in flight, process dies before the manifest commit
    ck.save(2, _tree(2), crash_before_manifest=True)
    ck.wait()

    ck2 = BlobCheckpointer(store, async_upload=True)  # "restarted" process
    assert latest_step(store) == 1
    restored = ck2.restore(1, _tree(0))
    for a, b in zip(restored["w"], _tree(1)["w"]):
        np.testing.assert_array_equal(a, b)


def test_tiered_store_retries_transient_faults_and_bills_time():
    base = SimulatedS3(seed=11)
    store = TieredCheckpointStore(FaultyStore(base, seed=13,
                                              transient_p=0.4),
                                  clock=lambda: 42.0)
    ck = BlobCheckpointer(store, async_upload=False)
    tree = {"x": np.arange(12, dtype=np.float32).reshape(3, 4)}
    for step in range(1, 4):       # enough ops that faults certainly hit
        ck.save(step, tree, extra={"next_step": step, "offsets": {0: 7}})
        ck.restore(step, {"x": np.zeros((3, 4), np.float32)})
    ck.save(3, tree, extra={"next_step": 3, "offsets": {0: 7}})
    assert store.retries > 0  # fault injection was actually live
    m = ck.manifest(3)
    assert m["extra"]["next_step"] == 3
    restored = ck.restore(3, {"x": np.zeros((3, 4), np.float32)})
    np.testing.assert_array_equal(restored["x"], tree["x"])


def test_manifest_extra_roundtrip_and_default(tmp_path):
    store = FileStore(str(tmp_path / "ckpt"))
    ck = BlobCheckpointer(store, async_upload=False)
    ck.save(1, _tree(1))                       # no extra given
    ck.save(2, _tree(2), extra={"offsets": {"3": 14}})
    assert ck.manifest(1)["extra"] == {}
    assert ck.manifest(2)["extra"] == {"offsets": {"3": 14}}
