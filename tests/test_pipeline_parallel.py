"""GPipe over the pod axis == sequential stack (8 host devices)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro import jaxcompat

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.slow   # subprocess multi-device: deselected in CI


@pytest.mark.skipif(not jaxcompat.NEW_SHARD_MAP,
                    reason="partial-auto shard_map + axis_index needs the "
                    "current partitioner (PartitionId unimplemented on 0.4.x)")
def test_gpipe_matches_sequential():
    code = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline_parallel import gpipe_apply
    from repro.launch.mesh import make_test_mesh

    mesh = make_test_mesh(devices=8)      # pod=2 -> 2 pipeline stages
    n_stages, d, B = 2, 32, 16
    ks = jax.random.split(jax.random.key(0), 2)
    params = {"w": jax.random.normal(ks[0], (n_stages, d, d)) / jnp.sqrt(d),
              "b": jax.random.normal(ks[1], (n_stages, d)) * 0.1}
    x = jax.random.normal(jax.random.key(1), (B, d))

    def stage_fn(p, xm):
        return jnp.tanh(xm @ p["w"] + p["b"])

    # sequential reference
    ref = x
    for s in range(n_stages):
        ref = stage_fn(jax.tree.map(lambda a: a[s], params), ref)

    out = jax.jit(lambda p, x: gpipe_apply(
        stage_fn, p, x, mesh=mesh, n_micro=4))(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)
    print("GPIPE-OK")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ,
                                PYTHONPATH=os.path.join(ROOT, "src")),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "GPIPE-OK" in r.stdout
