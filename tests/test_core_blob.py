"""Blob format + record serialization: unit + property tests."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (Record,
                        build_blob,
                        deserialize,
                        deserialize_all,
                        extract,
                        serialize,
                        default_partitioner)

rec_st = st.builds(
    Record,
    key=st.binary(min_size=0, max_size=32),
    value=st.binary(min_size=0, max_size=256),
    timestamp_us=st.integers(min_value=0, max_value=2**63 - 1),
    headers=st.lists(
        st.tuples(st.binary(max_size=8), st.binary(max_size=16)),
        max_size=3).map(tuple),
)


@given(rec_st)
def test_record_roundtrip(rec):
    buf = serialize(rec)
    out, consumed = deserialize(buf)
    assert out == rec
    assert consumed == len(buf) == rec.size


@given(st.lists(rec_st, max_size=20))
def test_record_stream_roundtrip(recs):
    buf = b"".join(serialize(r) for r in recs)
    assert deserialize_all(buf) == recs


@settings(deadline=None)
@given(st.dictionaries(st.integers(0, 63),
                       st.lists(rec_st, min_size=1, max_size=8),
                       min_size=1, max_size=8))
def test_blob_roundtrip(per_partition):
    """Pack per-partition buffers into a blob; extract via notifications."""
    blob, notes = build_blob(per_partition, target_az=1)
    assert len(notes) == len(per_partition)
    seen = set()
    for note in notes:
        assert note.blob_id == blob.blob_id
        assert note.target_az == 1
        recs = extract(blob.payload, note.byte_range)
        assert recs == per_partition[note.partition]
        seen.add(note.partition)
    assert seen == set(per_partition)


def test_blob_ranges_contiguous_and_ordered():
    """Records for a partition appear sequentially; ranges tile the blob."""
    per = {p: [Record(bytes([p]), b"x" * (10 + p))] for p in (5, 1, 9)}
    blob, notes = build_blob(per, target_az=0)
    ranges = sorted((n.byte_range.offset, n.byte_range.end) for n in notes)
    assert ranges[0][0] == 0
    for (_, e1), (o2, _) in zip(ranges, ranges[1:]):
        assert e1 == o2
    assert ranges[-1][1] == blob.size
    # sorted by partition id
    assert [n.partition for n in notes] == [1, 5, 9]


def test_partitioner_stable_and_in_range():
    for key in (b"", b"a", b"hello", bytes(range(256))):
        p = default_partitioner(key, 216)
        assert 0 <= p < 216
        assert p == default_partitioner(key, 216)
