"""The shuffle engine as a training data source (``repro.train_input``).

Covers the pieces the benchmark gates lean on, individually:

* the step-keyed record codec roundtrips and the assembled batch equals
  the engine-free reference;
* ``ShuffleFedInput`` serves every step's batch exactly once, in order,
  bit-equal to the reference, with committed offsets accounting for
  every delivered record;
* ``fast_forward`` resumes a fresh engine replay to the committed
  boundary: identical batches, cross-checked offsets, and a loud
  failure on a manifest/replay mismatch;
* delivery stays exactly-once through fault injection and an AZ outage;
* the sharded input specs validate on a real device batch.
"""

import numpy as np
import pytest

from repro.core import AsyncShuffleEngine, BlobShuffleConfig, EngineConfig
from repro.core.stores import ExpressOneZoneStore, FaultyStore, SimulatedS3
from repro.train_input import (ShuffleFedInput, TokenStreamConfig,
                               assemble_batch, decode_record,
                               reference_batch, step_records, step_tokens)

STREAM = TokenStreamConfig(vocab_size=997, batch=4, seq_len=16, seed=3)


def _engine(store=None, **kw):
    bcfg = BlobShuffleConfig(batch_bytes=2048, max_interval_s=0.02,
                             num_partitions=5, num_az=3)
    return AsyncShuffleEngine(
        bcfg, EngineConfig(commit_interval_s=0.05), n_instances=2,
        store=store or SimulatedS3(seed=1), seed=2, exactly_once=True, **kw)


# -- codec ---------------------------------------------------------------


def test_record_codec_roundtrip():
    recs = step_records(STREAM, step=6).to_records()
    assert len(recs) == STREAM.batch
    toks = step_tokens(STREAM, 6)
    for row, rec in enumerate(recs):
        s, r, vals = decode_record(rec)
        assert (s, r) == (6, row)
        np.testing.assert_array_equal(vals, toks[row])


def test_assemble_matches_reference_and_shifts_labels():
    rows = {r: step_tokens(STREAM, 2)[r] for r in range(STREAM.batch)}
    batch = assemble_batch(STREAM, rows)
    ref = reference_batch(STREAM, 2)
    np.testing.assert_array_equal(batch["tokens"], ref["tokens"])
    np.testing.assert_array_equal(batch["labels"], ref["labels"])
    # next-token prediction: labels are the tokens shifted by one
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


def test_assemble_rejects_missing_rows():
    rows = {0: step_tokens(STREAM, 0)[0]}
    with pytest.raises(ValueError, match="missing"):
        assemble_batch(STREAM, rows)


# -- pipeline ------------------------------------------------------------


def test_pipeline_serves_reference_batches_exactly_once():
    pipe = ShuffleFedInput(_engine(), STREAM, steps=6, step_interval_s=0.05)
    pipe.submit()
    for s in range(6):
        got, batch, _ = pipe.next_batch()
        assert got == s
        ref = reference_batch(STREAM, s)
        np.testing.assert_array_equal(batch["tokens"], ref["tokens"])
        np.testing.assert_array_equal(batch["labels"], ref["labels"])
    with pytest.raises(StopIteration):
        pipe.next_batch()
    pipe.commit(6)
    # offsets account for every delivered record exactly once
    assert sum(pipe.offsets().values()) == 6 * STREAM.batch
    assert pipe.duplicate_rows == 0
    pipe.finish()


def test_pipeline_overlap_prefetch():
    pipe = ShuffleFedInput(_engine(), STREAM, steps=6, prefetch_steps=3,
                           step_interval_s=0.05)
    pipe.submit()
    hits = sum(pipe.next_batch()[2] for _ in range(6))
    assert pipe.requests == 6
    # first request blocks; the double buffer should absorb most others
    assert hits >= 3
    assert pipe.prefetch_hits == hits


def test_fast_forward_resume_is_bit_identical():
    first = ShuffleFedInput(_engine(), STREAM, steps=6, step_interval_s=0.05)
    first.submit()
    batches = [first.next_batch()[1] for _ in range(6)]
    first.commit(4)
    offsets = first.offsets()

    # "restart": fresh engine from the same factory, replay and drop the
    # committed prefix, cross-check offsets against the "manifest"
    second = ShuffleFedInput(_engine(), STREAM, steps=6,
                             step_interval_s=0.05)
    second.submit()
    second.fast_forward(4, offsets)
    assert second.skipped_rows == 4 * STREAM.batch
    for s in (4, 5):
        got, batch, _ = second.next_batch()
        assert got == s
        np.testing.assert_array_equal(batch["tokens"],
                                      batches[s]["tokens"])


def test_fast_forward_detects_offset_divergence():
    pipe = ShuffleFedInput(_engine(), STREAM, steps=6, step_interval_s=0.05)
    pipe.submit()
    with pytest.raises(RuntimeError, match="diverged"):
        pipe.fast_forward(4, {0: 9999})


def test_fast_forward_requires_fresh_pipeline():
    pipe = ShuffleFedInput(_engine(), STREAM, steps=4, step_interval_s=0.05)
    pipe.submit()
    pipe.next_batch()
    with pytest.raises(RuntimeError, match="before consumption"):
        pipe.fast_forward(2)


def test_pipeline_exactly_once_through_faults_and_outage():
    from repro.cluster import ElasticCluster

    def make():
        store = FaultyStore(ExpressOneZoneStore(seed=5, num_az=3), seed=7,
                            transient_p=0.05)
        eng = _engine(store=store)
        cluster = ElasticCluster(eng, mode="cooperative")
        cluster.az_outage_at(0.12, 1)
        return eng

    pipe = ShuffleFedInput(make(), STREAM, steps=8, step_interval_s=0.05)
    pipe.submit()
    for s in range(8):
        got, batch, _ = pipe.next_batch()
        assert got == s
        np.testing.assert_array_equal(batch["tokens"],
                                      reference_batch(STREAM, s)["tokens"])
    pipe.commit(8)
    assert sum(pipe.offsets().values()) == 8 * STREAM.batch


# -- sharded input specs -------------------------------------------------


def test_device_batch_validates_against_input_specs():
    from repro.configs import get_config
    from repro.launch import make_test_mesh
    from repro.train_input import input_spec_report, validate_device_batch

    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    stream = TokenStreamConfig(vocab_size=cfg.vocab_size, batch=4,
                               seq_len=16, seed=0)
    mesh = make_test_mesh(devices=1)
    pipe = ShuffleFedInput(_engine(), stream, steps=1, mesh=mesh,
                           model_cfg=cfg, step_interval_s=0.05)
    pipe.submit()
    _, batch, _ = pipe.next_batch()
    report = validate_device_batch(batch, cfg, pipe.shape, mesh)
    assert report == input_spec_report(cfg, pipe.shape, mesh)
    assert report["tokens"]["global_shape"] == [4, 16]

    # a wrongly-shaped batch must fail loudly
    with pytest.raises(AssertionError):
        validate_device_batch({"tokens": batch["tokens"]}, cfg,
                              pipe.shape, mesh)
