"""Checkpoint/restart, commit protocol at the storage layer, elastic
restore, straggler hedging."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import BlobCheckpointer, FileStore, latest_step
from repro.configs import get_config
from repro.models import lm
from repro.models.common import init_params
from repro.runtime import FaultTolerantTrainer, HedgedFetcher
from repro.training import OptConfig, TrainConfig, adamw_init, \
    make_train_step

pytestmark = pytest.mark.slow   # full-model train/restore: slow in CI


def make_setup(tmp_path, arch="granite-3-2b"):
    cfg = get_config(arch, smoke=True)
    params = init_params(lm.param_defs(cfg), jax.random.key(0))
    opt = adamw_init(params)
    tcfg = TrainConfig(opt=OptConfig(learning_rate=1e-3))
    step = jax.jit(make_train_step(cfg, tcfg))

    def batch_fn(i):  # deterministic, step-keyed
        k = jax.random.key(1000 + i)
        toks = jax.random.randint(k, (2, 16), 0, cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    store = FileStore(str(tmp_path / "ckpt"))
    return cfg, params, opt, step, batch_fn, store


def test_checkpoint_roundtrip_and_async(tmp_path):
    store = FileStore(str(tmp_path / "s"))
    ckpt = BlobCheckpointer(store, async_upload=True)
    tree = {"w": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16)}
    ckpt.save(7, tree)
    ckpt.wait()
    out = ckpt.restore(7, tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert latest_step(store) == 7


def test_crash_before_manifest_leaves_no_checkpoint(tmp_path):
    """Blobs without a manifest are invisible (commit protocol) and are
    collected as orphans by retention."""
    store = FileStore(str(tmp_path / "s"))
    ckpt = BlobCheckpointer(store, async_upload=False)
    tree = {"w": jnp.ones((4,))}
    ckpt.save(1, tree)
    ckpt.save(2, tree, crash_before_manifest=True)
    assert latest_step(store) == 1
    with pytest.raises(FileNotFoundError):
        ckpt.restore(2, tree)
    removed = store.run_retention()
    assert removed == 1  # step-2 orphan blob GC'd
    ckpt.restore(1, tree)  # step-1 untouched


def test_restart_is_bit_identical(tmp_path):
    """Training with injected failures reproduces the no-failure run."""
    cfg, params, opt, step, batch_fn, store = make_setup(tmp_path)
    t1 = FaultTolerantTrainer(FileStore(str(tmp_path / "a")), step,
                              batch_fn, ckpt_every=4, async_upload=False)
    p_ref, _, losses_ref = t1.run(params, opt, steps=12)
    t2 = FaultTolerantTrainer(FileStore(str(tmp_path / "b")), step,
                              batch_fn, ckpt_every=4, async_upload=False)
    p_ft, _, losses_ft = t2.run(params, opt, steps=12,
                                fail_at={6: 1, 10: 2})
    assert losses_ft == pytest.approx(losses_ref, rel=1e-6)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_ft)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_different_mesh(tmp_path):
    """Save on one topology, restore onto another (8 -> 4 devices)."""
    import subprocess, sys, textwrap
    code = textwrap.dedent(f"""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp, numpy as np
    from repro.checkpoint import BlobCheckpointer, FileStore
    from repro.configs import get_config
    from repro.distributed.sharding import DEFAULT_RULES, named_shardings
    from repro.launch.mesh import make_test_mesh
    from repro.models import lm
    from repro.models.common import init_params
    from repro.runtime import elastic_restore_plan

    cfg = get_config('granite-3-2b', smoke=True)
    defs = lm.param_defs(cfg)
    mesh8 = make_test_mesh(devices=8)
    sh8 = named_shardings(defs, DEFAULT_RULES, mesh8)
    params = jax.tree.map(jax.device_put, init_params(defs,
                          jax.random.key(0)), sh8)
    store = FileStore({str(tmp_path / 'e')!r})
    ck = BlobCheckpointer(store, async_upload=False)
    ck.save(3, params)

    mesh4 = make_test_mesh(devices=4)      # different topology
    plan = elastic_restore_plan(defs, DEFAULT_RULES, mesh4)
    restored = ck.restore(3, params, shardings=plan['shardings'])
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert len(a.sharding.device_set) <= 4
    print('ELASTIC-OK', plan['dp_degree'])
    """)
    import subprocess, sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", code],
                       env=dict(os.environ,
                                PYTHONPATH=os.path.join(root, "src")),
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "ELASTIC-OK" in r.stdout


def test_hedged_fetch_improves_heavy_tail():
    """Hedging pays off under degraded-store incidents (heavy tail σ=0.8);
    under the calibrated steady-state σ=0.42 the gain at p99 is marginal —
    an honest modeling result recorded in EXPERIMENTS.md."""
    from repro.core.stores import LatencyModel
    h = HedgedFetcher(LatencyModel(sigma=0.8), hedge_quantile=0.95, seed=0)
    base, hedged = h.tail_improvement(16 * 1024 * 1024, n=30000, pct=99.9)
    assert hedged < base * 0.75                   # ≥25% p99.9 cut
    assert h.stats.hedges / h.stats.requests < 0.12  # ≤12% extra requests
