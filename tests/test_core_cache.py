"""Cache layers: LRU bound, single-flight, per-AZ ≤1 store GET invariant."""

import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DistributedCache, LocalCache, LRUCache,
                        SimulatedS3, SingleFlight)


@settings(deadline=None)
@given(st.lists(st.tuples(st.text(min_size=1, max_size=4),
                          st.integers(1, 64)), max_size=60),
       st.integers(16, 128))
def test_lru_never_exceeds_capacity(ops, capacity):
    lru = LRUCache(capacity)
    for key, size in ops:
        lru.put(key, b"x" * size)
        assert lru.size <= capacity
        assert lru.size == sum(len(v) for v in lru.entries.values())


def test_lru_evicts_least_recent():
    lru = LRUCache(30)
    lru.put("a", b"x" * 10)
    lru.put("b", b"x" * 10)
    lru.put("c", b"x" * 10)
    assert lru.get("a") is not None      # refresh a
    lru.put("d", b"x" * 10)              # evicts b (LRU)
    assert "b" not in lru and "a" in lru and "d" in lru


def test_single_flight_one_leader():
    sf = SingleFlight()
    assert sf.begin("k") is True
    assert sf.begin("k") is False
    assert sf.begin("k") is False
    sf.complete("k", b"v")
    assert sf.begin("k") is True  # new round after completion


def test_distributed_cache_one_get_per_az():
    """Paper §3.3: a blob is downloaded from the store at most once per AZ
    while cached — the core cost invariant behind GET:PUT = 2:3."""
    store = SimulatedS3(seed=0)
    store.put("blob1", b"payload" * 100)
    store.stats.gets = 0
    cache = DistributedCache(az=0, members=4, capacity_per_member=1 << 20,
                             store=store, cache_on_write=True)
    for _ in range(50):
        payload, _, _ = cache.read("blob1")
        assert payload == b"payload" * 100
    assert store.stats.gets == 1
    assert cache.stats.misses == 1
    assert cache.stats.hits == 49


def test_cache_on_write_serves_same_az_reads_without_get():
    store = SimulatedS3(seed=0)
    cache = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                             store=store, cache_on_write=True)
    cache.write("b", b"x" * 64)
    before = store.stats.gets
    _, _, src = cache.read("b")
    assert src == "cache"
    assert store.stats.gets == before


def test_local_cache_avoids_remote_lookups():
    store = SimulatedS3(seed=0)
    dist = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                            store=store, cache_on_write=False)
    store.put("b", b"y" * 128)
    local = LocalCache(1 << 20, dist)
    local.read("b")
    hits_before = dist.stats.hits + dist.stats.misses
    for _ in range(10):
        _, _, src = local.read("b")
        assert src == "local"
    assert dist.stats.hits + dist.stats.misses == hits_before


def test_eviction_causes_refetch():
    store = SimulatedS3(seed=0)
    cache = DistributedCache(az=0, members=1, capacity_per_member=100,
                             store=store, cache_on_write=False)
    store.put("a", b"x" * 80)
    store.put("b", b"x" * 80)
    cache.read("a")
    cache.read("b")   # evicts a
    gets = store.stats.gets
    cache.read("a")   # refetch
    assert store.stats.gets == gets + 1
