"""Cache layers: LRU bound, single-flight, per-AZ ≤1 store GET invariant,
eviction under byte pressure, and leader-failure behavior on a faulty
store."""

import pytest

from repro.core import (DistributedCache, FaultyStore, LocalCache, LRUCache,
                        SimulatedS3, SingleFlight, TransientStoreError)


try:
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None)
    @given(st.lists(st.tuples(st.text(min_size=1, max_size=4),
                              st.integers(1, 64)), max_size=60),
           st.integers(16, 128))
    def test_lru_never_exceeds_capacity(ops, capacity):
        lru = LRUCache(capacity)
        for key, size in ops:
            lru.put(key, b"x" * size)
            assert lru.size <= capacity
            assert lru.size == sum(len(v) for v in lru.entries.values())
except ImportError:          # hypothesis optional: property test skipped
    pass


def test_lru_evicts_least_recent():
    lru = LRUCache(30)
    lru.put("a", b"x" * 10)
    lru.put("b", b"x" * 10)
    lru.put("c", b"x" * 10)
    assert lru.get("a") is not None      # refresh a
    lru.put("d", b"x" * 10)              # evicts b (LRU)
    assert "b" not in lru and "a" in lru and "d" in lru


def test_single_flight_one_leader():
    sf = SingleFlight()
    assert sf.begin("k") is True
    assert sf.begin("k") is False
    assert sf.begin("k") is False
    sf.complete("k", b"v")
    assert sf.begin("k") is True  # new round after completion


def test_distributed_cache_one_get_per_az():
    """Paper §3.3: a blob is downloaded from the store at most once per AZ
    while cached — the core cost invariant behind GET:PUT = 2:3."""
    store = SimulatedS3(seed=0)
    store.put("blob1", b"payload" * 100)
    store.stats.gets = 0
    cache = DistributedCache(az=0, members=4, capacity_per_member=1 << 20,
                             store=store, cache_on_write=True)
    for _ in range(50):
        payload, _, _ = cache.read("blob1")
        assert payload == b"payload" * 100
    assert store.stats.gets == 1
    assert cache.stats.misses == 1
    assert cache.stats.hits == 49


def test_cache_on_write_serves_same_az_reads_without_get():
    store = SimulatedS3(seed=0)
    cache = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                             store=store, cache_on_write=True)
    cache.write("b", b"x" * 64)
    before = store.stats.gets
    _, _, src = cache.read("b")
    assert src == "cache"
    assert store.stats.gets == before


def test_local_cache_avoids_remote_lookups():
    store = SimulatedS3(seed=0)
    dist = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                            store=store, cache_on_write=False)
    store.put("b", b"y" * 128)
    local = LocalCache(1 << 20, dist)
    local.read("b")
    hits_before = dist.stats.hits + dist.stats.misses
    for _ in range(10):
        _, _, src = local.read("b")
        assert src == "local"
    assert dist.stats.hits + dist.stats.misses == hits_before


def test_eviction_causes_refetch():
    store = SimulatedS3(seed=0)
    cache = DistributedCache(az=0, members=1, capacity_per_member=100,
                             store=store, cache_on_write=False)
    store.put("a", b"x" * 80)
    store.put("b", b"x" * 80)
    cache.read("a")
    cache.read("b")   # evicts a
    gets = store.stats.gets
    cache.read("a")   # refetch
    assert store.stats.gets == gets + 1


def test_lru_eviction_under_byte_pressure_counts_and_bounds():
    lru = LRUCache(100)
    lru.put("a", b"x" * 40)
    lru.put("b", b"x" * 40)
    lru.put("c", b"x" * 40)            # evicts a (40+40+40 > 100)
    assert "a" not in lru and "b" in lru and "c" in lru
    assert lru.size == 80 and lru.stats.evictions == 1
    lru.put("d", b"x" * 90)            # evicts b AND c
    assert lru.size == 90 and list(lru.entries) == ["d"]
    assert lru.stats.evictions == 3
    assert lru.stats.insertions == 4


def test_lru_oversized_value_is_skipped_and_displaces_stale_entry():
    lru = LRUCache(100)
    lru.put("k", b"x" * 50)
    lru.put("k", b"x" * 200)           # oversized replacement: skipped...
    assert "k" not in lru              # ...and the stale value is dropped
    assert lru.size == 0
    lru.put("big", b"x" * 101)
    assert "big" not in lru and lru.size == 0
    assert lru.stats.insertions == 1   # only the original 50-byte put
    assert lru.stats.evictions == 0    # skips are not evictions


def test_coalesced_read_is_served_from_payload_without_store_stats():
    """Satellite fix: a coalesced read must not touch (or mutate-and-undo)
    the store's request accounting."""
    store = SimulatedS3(seed=0)
    store.put("blob", b"p" * 64)
    store.stats.gets = 0
    store.stats.get_bytes = 0
    cache = DistributedCache(az=0, members=2, capacity_per_member=1 << 20,
                             store=store, cache_on_write=False)
    assert cache.flight.begin("blob")          # simulate in-flight leader
    payload, _, src = cache.read("blob")       # this caller coalesces
    assert src == "coalesced" and payload == b"p" * 64
    assert store.stats.gets == 0 and store.stats.get_bytes == 0
    assert cache.stats.coalesced == 1
    assert cache.stats.store_gets == 0


def test_single_flight_leader_failure_releases_flight_and_fills_once():
    """Leader GET fails on a FaultyStore: leadership must be released so
    the retry can lead a fresh download — which fills exactly once."""
    inner = SimulatedS3(seed=0)
    inner.put("blob", b"v" * 32)
    inner.stats.gets = 0
    store = FaultyStore(inner, seed=1, transient_p=0.999)
    cache = DistributedCache(az=0, members=1, capacity_per_member=1 << 20,
                             store=store, cache_on_write=False)
    with pytest.raises(TransientStoreError):
        cache.read("blob")
    assert cache.flight.begin("blob")          # leadership was released
    cache.flight.complete("blob", b"")
    store.transient_p = 0.0                    # store recovers; retry
    payload, _, src = cache.read("blob")
    assert payload == b"v" * 32 and src == "store"
    member = cache.members[cache.owner_of("blob")]
    assert member.stats.insertions == 1        # no double-fill
    assert inner.stats.gets == 1               # failed attempt not billed
    assert cache.stats.store_gets == 1
    assert cache.stats.misses == 2             # both attempts were misses
