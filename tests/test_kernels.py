"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.blob_codec.kernel import (compress_pack_fused_pallas,
                                             unpack_decompress_fused_pallas)
from repro.kernels.blob_codec.ops import (compress_pack_fused,
                                          unpack_decompress_fused)
from repro.kernels.blob_codec.ref import (compress_pack_ref,
                                          unpack_decompress_ref)
from repro.kernels.blob_codec.host import compress_pack_fused_host
from repro.kernels.blob_pack.host import (blob_pack_fused_host,
                                          sorted_order_np)
from repro.kernels.blob_pack.kernel import (SWEEP_ROW_TILES,
                                            blob_pack_fused_pallas,
                                            blob_pack_pallas)
from repro.kernels.blob_pack.ops import blob_pack_fused, pack_from_keys
from repro.kernels.blob_pack.ref import blob_pack_ref
from repro.kernels.blob_unpack.kernel import (blob_unpack_fused_pallas,
                                              blob_unpack_pallas)
from repro.kernels.blob_unpack.ops import unpack_from_keys
from repro.kernels.blob_unpack.ref import blob_unpack_ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_ref
from repro.kernels.ssd_scan.ops import ssd_scan_op
from repro.models.ssm import ssd_reference
from repro.shuffle.binning import bin_pack, sorted_order


# --- blob_pack ------------------------------------------------------------

@pytest.mark.parametrize("T,d,bins,cap,dtype", [
    (64, 32, 8, 16, jnp.float32),
    (100, 16, 4, 8, jnp.float32),       # drops (cap < demand)
    (64, 128, 8, 16, jnp.bfloat16),
    (7, 8, 3, 4, jnp.float32),          # tiny / ragged
    (128, 64, 16, 8, jnp.int32),        # integer payload (metadata)
])
def test_blob_pack_matches_ref(T, d, bins, cap, dtype):
    key = jax.random.key(0)
    if jnp.issubdtype(dtype, jnp.integer):
        x = jax.random.randint(key, (T, d), 0, 100).astype(dtype)
    else:
        x = jax.random.normal(key, (T, d)).astype(dtype)
    keys = jax.random.randint(jax.random.key(1), (T,), 0, bins)
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    counts = jnp.bincount(keys, length=bins).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    ref = blob_pack_ref(x, order, starts, counts, capacity=cap)
    out = blob_pack_pallas(x, order, starts, counts, capacity=cap,
                           interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_pack_from_keys_consistent_with_binning():
    x = jax.random.normal(jax.random.key(2), (50, 8))
    keys = jax.random.randint(jax.random.key(3), (50,), 0, 4)
    buf, (order, starts, counts) = pack_from_keys(
        x, keys, num_bins=4, capacity=32, use_pallas=True)
    pack = bin_pack(keys, 4, 32)
    from repro.shuffle.binning import scatter_to_bins
    expect = scatter_to_bins(x, pack, 4, 32)
    np.testing.assert_allclose(np.asarray(buf), np.asarray(expect))


# --- blob_unpack ------------------------------------------------------------

@pytest.mark.parametrize("U,bins,cap,d,dtype", [
    (64, 8, 16, 32, jnp.float32),
    (33, 4, 8, 16, jnp.bfloat16),
    (8, 2, 4, 8, jnp.float32),
])
def test_blob_unpack_matches_ref(U, bins, cap, d, dtype):
    buf = jax.random.normal(jax.random.key(4), (bins, cap, d)).astype(dtype)
    slot = jax.random.randint(jax.random.key(5), (U,), 0, bins * cap)
    valid = jax.random.bernoulli(jax.random.key(6), 0.8, (U,))
    ref = blob_unpack_ref(buf, slot, valid)
    out = blob_unpack_pallas(buf, slot, valid, interpret=True)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(ref, np.float32))


def test_pack_unpack_roundtrip():
    """Kernel-level Batcher→Debatcher roundtrip (no drops)."""
    x = jax.random.normal(jax.random.key(7), (40, 16))
    keys = jax.random.randint(jax.random.key(8), (40,), 0, 4)
    pack = bin_pack(keys, 4, 64)
    order = jnp.argsort(keys, stable=True).astype(jnp.int32)
    counts = pack.counts
    starts = jnp.concatenate(
        [jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    buf = blob_pack_pallas(x, order, starts, counts, capacity=64,
                           interpret=True)
    back = blob_unpack_pallas(buf, pack.slot, pack.valid, interpret=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-6)


# --- fused single-pass kernels ----------------------------------------------

@pytest.mark.parametrize("T,d,bins,cap,dtype", [
    (64, 32, 8, 16, jnp.float32),
    (100, 16, 4, 8, jnp.float32),       # drops (cap < demand)
    (64, 128, 8, 16, jnp.bfloat16),
    (7, 8, 3, 4, jnp.float32),          # tiny / ragged
    (50, 8, 4, 200, jnp.float32),       # capacity > FUSED tile, uneven
    (128, 64, 16, 8, jnp.int32),        # integer payload (metadata)
])
def test_blob_pack_fused_matches_ref(T, d, bins, cap, dtype):
    key = jax.random.key(0)
    if jnp.issubdtype(dtype, jnp.integer):
        x = jax.random.randint(key, (T, d), 0, 100).astype(dtype)
    else:
        x = jax.random.normal(key, (T, d)).astype(dtype)
    keys = jax.random.randint(jax.random.key(1), (T,), 0, bins)
    order, starts, counts = sorted_order(keys, bins)
    ref = blob_pack_ref(x, order, starts, counts, capacity=cap)
    out = blob_pack_fused_pallas(x, order, starts, counts, capacity=cap,
                                 interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    # the jit-fused front half (sort/rank + gather in one pass) agrees too
    fused, (o2, s2, c2) = blob_pack_fused(x, keys, num_bins=bins,
                                          capacity=cap, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(o2), np.asarray(order))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))


@pytest.mark.parametrize("U,bins,cap,d", [
    (64, 8, 16, 32),
    (33, 4, 8, 16),       # U not a multiple of the tile
    (8, 2, 4, 8),
    (300, 4, 128, 8),     # U > FUSED tile
])
def test_blob_unpack_fused_matches_ref(U, bins, cap, d):
    buf = jax.random.normal(jax.random.key(4), (bins, cap, d))
    slot = jax.random.randint(jax.random.key(5), (U,), 0, bins * cap)
    valid = jax.random.bernoulli(jax.random.key(6), 0.8, (U,))
    ref = blob_unpack_ref(buf, slot, valid)
    out = blob_unpack_fused_pallas(buf, slot, valid, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_fused_pack_unpack_roundtrip():
    """Fused-kernel Batcher→Debatcher roundtrip (no drops)."""
    x = jax.random.normal(jax.random.key(7), (40, 16))
    keys = jax.random.randint(jax.random.key(8), (40,), 0, 4)
    buf, _ = blob_pack_fused(x, keys, num_bins=4, capacity=64,
                             use_pallas=True)
    back = unpack_from_keys(buf, keys, num_bins=4, capacity=64,
                            use_pallas=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-6)


# --- blob_codec (fused compress+pack) ----------------------------------------

@pytest.mark.parametrize("T,d,bins,cap", [
    (64, 32, 8, 16),
    (100, 16, 4, 8),       # drops (cap < demand)
    (7, 8, 3, 4),          # tiny / ragged
    (50, 8, 4, 200),       # capacity > FUSED tile, uneven
])
def test_compress_pack_fused_matches_ref(T, d, bins, cap):
    x = jax.random.normal(jax.random.key(11), (T, d))
    keys = jax.random.randint(jax.random.key(12), (T,), 0, bins)
    order, starts, counts = sorted_order(keys, bins)
    q_ref, s_ref = compress_pack_ref(x, order, starts, counts, capacity=cap)
    q, s = compress_pack_fused_pallas(x, order, starts, counts,
                                      capacity=cap, interpret=True)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    # jit-fused front half (sort/rank + gather+quantize) agrees too
    (qf, sf), (o2, _, c2) = compress_pack_fused(
        x, keys, num_bins=bins, capacity=cap, use_pallas=True)
    np.testing.assert_array_equal(np.asarray(qf), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(sf), np.asarray(s_ref))
    np.testing.assert_array_equal(np.asarray(o2), np.asarray(order))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(counts))


@pytest.mark.parametrize("U,bins,cap,d", [
    (64, 8, 16, 32),
    (33, 4, 8, 16),        # U not a multiple of the tile
    (300, 4, 128, 8),      # U > FUSED tile
])
def test_unpack_decompress_fused_matches_ref(U, bins, cap, d):
    q = jax.random.randint(jax.random.key(13), (bins, cap, d),
                           -127, 128).astype(jnp.int8)
    scales = jnp.abs(jax.random.normal(jax.random.key(14),
                                       (bins, cap))) + 1e-3
    slot = jax.random.randint(jax.random.key(15), (U,), 0, bins * cap)
    valid = jax.random.bernoulli(jax.random.key(16), 0.8, (U,))
    ref = unpack_decompress_ref(q, scales, slot, valid)
    out = unpack_decompress_fused_pallas(q, scales, slot, valid,
                                         interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_compress_pack_roundtrip_within_int8_error():
    """Fused Batcher→Debatcher roundtrip through the compressed layout:
    lossy, but bounded by the per-row quantization step (absmax/127)."""
    x = jax.random.normal(jax.random.key(17), (40, 16))
    keys = jax.random.randint(jax.random.key(18), (40,), 0, 4)
    (q, s), _ = compress_pack_fused(x, keys, num_bins=4, capacity=64,
                                    use_pallas=True)
    back = unpack_decompress_fused(q, s, keys, num_bins=4, capacity=64,
                                   use_pallas=True)
    step = np.abs(np.asarray(x)).max(axis=-1, keepdims=True) / 127.0
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(step.max()) * 0.51 + 1e-7)


# --- tile-geometry edge cases -----------------------------------------------

#: geometries that stress the grid/tile math: capacity below the tile,
#: capacity not a multiple of the tile, single-lane features (d == 1),
#: and bins the keys never hit (empty bins must stay zero / padding)
EDGE_GEOMS = [
    pytest.param(64, 16, 4, 3, 128, id="capacity-lt-row-tile"),
    pytest.param(64, 16, 4, 37, 8, id="capacity-not-tile-multiple"),
    pytest.param(100, 1, 8, 32, 16, id="d-eq-1"),
    pytest.param(50, 8, 16, 8, 8, id="empty-bins"),
    pytest.param(3, 1, 5, 7, 256, id="tiny-everything"),
]


def _edge_inputs(T, bins, seed=21):
    # draw keys from the lower half of the bin range so the upper half
    # is guaranteed empty (covers the empty-bins contract everywhere)
    hi = max(1, bins // 2)
    return jax.random.randint(jax.random.key(seed), (T,), 0, hi)


@pytest.mark.parametrize("T,d,bins,cap,row_tile", EDGE_GEOMS)
def test_pack_tile_geometry_edges(T, d, bins, cap, row_tile):
    x = jax.random.normal(jax.random.key(20), (T, d))
    keys = _edge_inputs(T, bins)
    order, starts, counts = sorted_order(keys, bins)
    ref = blob_pack_ref(x, order, starts, counts, capacity=cap)
    for rt in (None, row_tile):
        out = blob_pack_pallas(x, order, starts, counts, capacity=cap,
                               interpret=True, row_tile=rt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        fused = blob_pack_fused_pallas(x, order, starts, counts,
                                       capacity=cap, interpret=True,
                                       row_tile=rt)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(ref))
    # bins beyond the key range really are empty
    assert not np.asarray(ref)[bins // 2 + 1:].any()


@pytest.mark.parametrize("T,d,bins,cap,row_tile", EDGE_GEOMS)
def test_codec_tile_geometry_edges(T, d, bins, cap, row_tile):
    x = jax.random.normal(jax.random.key(22), (T, d))
    keys = _edge_inputs(T, bins)
    order, starts, counts = sorted_order(keys, bins)
    q_ref, s_ref = compress_pack_ref(x, order, starts, counts, capacity=cap)
    for rt in (None, row_tile):
        q, s = compress_pack_fused_pallas(x, order, starts, counts,
                                          capacity=cap, interpret=True,
                                          row_tile=rt)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))
    # empty bins carry the quantizer's padding identity (q=0, scale=1)
    assert not np.asarray(q_ref)[bins // 2 + 1:].any()
    np.testing.assert_array_equal(np.asarray(s_ref)[bins // 2 + 1:], 1.0)


def test_row_tile_sweep_parity():
    """Every candidate in the device benchmark's row-tile sweep produces
    bit-identical output — tile geometry is a pure perf knob."""
    T, d, bins, cap = 200, 24, 8, 48
    x = jax.random.normal(jax.random.key(23), (T, d))
    keys = jax.random.randint(jax.random.key(24), (T,), 0, bins)
    order, starts, counts = sorted_order(keys, bins)
    ref = blob_pack_ref(x, order, starts, counts, capacity=cap)
    for rt in SWEEP_ROW_TILES:
        out = blob_pack_fused_pallas(x, order, starts, counts,
                                     capacity=cap, interpret=True,
                                     row_tile=rt)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# --- host fast paths ---------------------------------------------------------

@pytest.mark.parametrize("dtype", [np.float32, np.int32, "bfloat16"])
def test_blob_pack_host_bit_parity(dtype):
    """Host numpy pack is bit-exact with the oracle, both into a fresh
    output and into a dirty reused arena (padding must be re-zeroed)."""
    if dtype == "bfloat16":
        dtype = np.asarray(jnp.zeros(0, jnp.bfloat16)).dtype
    rng = np.random.default_rng(5)
    T, d, bins, cap = 150, 12, 8, 24
    x = rng.standard_normal((T, d)).astype(np.float32).astype(dtype)
    keys = rng.integers(0, bins, T).astype(np.int32)
    order, starts, counts = sorted_order(jnp.asarray(keys), bins)
    ref = np.asarray(blob_pack_ref(jnp.asarray(x), order, starts, counts,
                                   capacity=cap))
    out, (o, s, c) = blob_pack_fused_host(x, keys, num_bins=bins,
                                          capacity=cap)
    np.testing.assert_array_equal(out.view(np.uint8), ref.view(np.uint8))
    np.testing.assert_array_equal(o, np.asarray(order))
    np.testing.assert_array_equal(s, np.asarray(starts))
    np.testing.assert_array_equal(c, np.asarray(counts))
    arena = np.ones((bins, cap, d), dtype)       # dirty arena
    out2, _ = blob_pack_fused_host(x, keys, num_bins=bins, capacity=cap,
                                   out=arena)
    assert out2 is arena
    np.testing.assert_array_equal(out2.view(np.uint8), ref.view(np.uint8))


def test_compress_pack_host_bit_parity():
    rng = np.random.default_rng(6)
    T, d, bins, cap = 150, 12, 8, 24
    x = rng.standard_normal((T, d)).astype(np.float32)
    keys = rng.integers(0, bins, T).astype(np.int32)
    order, starts, counts = sorted_order(jnp.asarray(keys), bins)
    q_ref, s_ref = compress_pack_ref(jnp.asarray(x), order, starts, counts,
                                     capacity=cap)
    (q, s), _ = compress_pack_fused_host(x, keys, num_bins=bins,
                                         capacity=cap)
    np.testing.assert_array_equal(q, np.asarray(q_ref))
    np.testing.assert_array_equal(s, np.asarray(s_ref))
    arenas = (np.full((bins, cap, d), 3, np.int8),
              np.full((bins, cap), 9.0, np.float32))
    (q2, s2), _ = compress_pack_fused_host(x, keys, num_bins=bins,
                                           capacity=cap, out=arenas)
    assert q2 is arenas[0] and s2 is arenas[1]
    np.testing.assert_array_equal(q2, np.asarray(q_ref))
    np.testing.assert_array_equal(s2, np.asarray(s_ref))


def test_sorted_order_np_matches_jnp():
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 11, 500).astype(np.int32)
    o, s, c = sorted_order_np(keys, 16)          # some bins empty
    oj, sj, cj = sorted_order(jnp.asarray(keys), 16)
    np.testing.assert_array_equal(o, np.asarray(oj))
    np.testing.assert_array_equal(s, np.asarray(sj))
    np.testing.assert_array_equal(c, np.asarray(cj))


# --- flash attention ---------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KVH,D,causal,dtype", [
    (2, 256, 4, 4, 64, True, jnp.float32),
    (1, 256, 4, 2, 64, True, jnp.float32),    # GQA
    (1, 128, 2, 1, 32, True, jnp.float32),    # MQA
    (2, 256, 4, 4, 64, False, jnp.float32),   # encoder
    (1, 200, 2, 2, 64, True, jnp.float32),    # ragged seq (padding)
    (1, 256, 2, 2, 64, True, jnp.bfloat16),
])
def test_flash_kernel_matches_dense(B, S, H, KVH, D, causal, dtype):
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KVH, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KVH, D)).astype(dtype)
    ref = flash_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                    v.astype(jnp.float32), causal=causal)
    out = flash_attention_pallas(q, k, v, causal=causal, q_tile=64,
                                 kv_tile=64, interpret=True)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol)


# --- ssd_scan ----------------------------------------------------------------

@pytest.mark.parametrize("b,S,H,P,G,N,chunk", [
    (1, 64, 2, 8, 1, 16, 16),
    (2, 60, 4, 8, 2, 16, 16),    # ragged + groups
    (1, 128, 4, 16, 1, 32, 64),
])
def test_ssd_kernel_matches_reference(b, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.key(10), 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)) - 1.0)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    B = jax.random.normal(ks[3], (b, S, G, N))
    C = jax.random.normal(ks[4], (b, S, G, N))
    y_ref, st_ref = ssd_reference(x, dt, A, B, C)
    y, st = ssd_scan_op(x, dt, A, B, C, chunk=chunk, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-4)
