"""Launch layer: input_specs, per-cell sharding rules, analytic FLOPs.

(Pure functions — no 512-device init; the dry-run itself is exercised via
the results JSONs and subprocess runs.)
"""

import jax.numpy as jnp
import pytest

from repro.configs import all_cells, all_skips, get_config, get_shape
from repro.launch.specs import input_specs


def test_cell_count_matches_assignment():
    cells = list(all_cells())
    skips = list(all_skips())
    assert len(cells) + len(skips) == 10 * 4  # 40 assigned cells
    assert len(cells) == 31
    assert len(skips) == 9


@pytest.mark.parametrize("arch,shape_name", list(all_cells()))
def test_input_specs_shapes(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = input_specs(cfg, shape)
    if shape.is_decode:
        assert specs["tokens"].shape == (shape.global_batch, 1)
        assert specs["pos"].shape == ()
        return
    B, S = shape.global_batch, shape.seq_len
    mm = cfg.multimodal
    if mm is not None and mm.kind == "audio":
        assert specs["frames"].shape == (B, S, cfg.d_model)
        assert specs["frames"].dtype == jnp.bfloat16  # stub frontend
    elif mm is not None and mm.kind == "vision":
        P = mm.num_patches
        assert specs["patches"].shape == (B, P, cfg.d_model)
        assert specs["tokens"].shape == (B, S - P)
        # patches + text tokens tile the full sequence budget
        assert specs["patches"].shape[1] + specs["tokens"].shape[1] == S
    else:
        assert specs["tokens"].shape == (B, S)
    if shape.step == "train":
        assert specs["labels"].shape == (B, S)
    else:
        assert "labels" not in specs


def test_model_flops_orders_of_magnitude():
    from repro.launch.dryrun import model_flops  # env var already set is ok
    cfg = get_config("qwen2-72b")
    f = model_flops(cfg, get_shape("train_4k"))
    # 6 * ~71e9 non-embed params * 1.048e6 tokens ≈ 4.5e17
    assert 2e17 < f < 8e17
    moe = get_config("deepseek-v2-lite-16b")
    f_act = model_flops(moe, get_shape("train_4k"))
    # active ≈ 2.7e9 of 16e9 params — MoE flops must use the active count
    assert f_act < 6 * 16e9 * 1.05e6 * 0.4


def test_mamba2_active_equals_total():
    cfg = get_config("mamba2-130m")
    assert cfg.active_param_count() == cfg.param_count()
    assert 1.1e8 < cfg.param_count() < 1.6e8  # ≈130M


def test_moe_active_param_count():
    cfg = get_config("deepseek-v2-lite-16b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 14e9 < total < 18e9       # ≈16B total
    assert active < total * 0.25     # top-6 of 64 experts + shared
